"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,collectives,bytes_moved,rounds,derived`` CSV
rows (benchmarks/util.emit); modules that predate the cost columns leave
them empty.

  micro_hashmap   paper Fig. 9   (insert / insert_buffer / find variants)
  micro_queue     paper Fig. 10/11 (CircularQueue vs FastQueue, promises)
  isx             paper Fig. 5   (bucket sort, aggregation sweep)
  meraculous      paper Fig. 6/7 (contig-generation build + traversal)
  kmer            paper Fig. 8   (k-mer counting +/- Bloom filter)
  lm_step         framework-side step throughput (reduced configs)

``--smoke`` runs each benchmark at tiny sizes (seconds, not minutes) so
the tier-1 suite can exercise the full benchmark path and its cost
accounting; timings from a smoke run are not meaningful.

``--fused`` adds the plan/commit-fusion arms (fused vs Promise.FINE
schedules) to the modules that have them, so the rounds_per_op column
shows the collective-count reduction side by side with wall time.

``--skew zipf`` adds the skewed-traffic arms (drop-mode vs carryover
retry rounds at mean-load capacity) to the modules that have them; the
retry_rounds and dropped columns track skew tolerance over time.  The
retry arms pick their round count with ``exchange.suggest_rounds`` over
the observed wave loads.

``--transport {dense,hier}`` re-runs the exchange-layer arms over the
named physical transport (DESIGN.md section 1.7); hierarchical rows are
suffixed ``_hier`` and the ``hops`` column shows the two-stage launches.

``--faults`` adds the fault-injection arms (DESIGN.md section 1.8) to
the modules that have them: a seeded FaultSpec corrupts wire segments
under the integrity checksum, the carry retry heals the loss, and a
degraded commit masks a dead rank — the lost_bytes / recovered /
unreachable columns track the robustness observables over time.

``--async`` adds the split-phase arms (DESIGN.md section 1.9) to the
modules that have them: the same ops issued via commit_async, completed
via finish after an overlap window — the overlap_launches column counts
the deferred launches while every other cost column matches the sync
row (the charge-once-at-wait attribution rule).

``--wire {scatter,fused}`` pins the send-buffer construction path
(DESIGN.md section 1.10) on the modules that have wire arms: ``scatter``
forces the documented scatter_rows fallback (impl="jnp"), ``fused`` the
one-kernel Pallas pack (impl="pallas"); rows are suffixed ``_scatter`` /
``_fused`` and the hbm_passes column reports the traced call's
standalone scatter-op count — fewer on the fused path, same bytes and
collectives everywhere.
"""

from __future__ import annotations

import inspect
import sys


def main() -> None:
    from benchmarks import isx, kmer, lm_step, meraculous, micro_hashmap, \
        micro_queue
    from benchmarks.util import HEADER
    mods = {
        "micro_hashmap": micro_hashmap,
        "micro_queue": micro_queue,
        "isx": isx,
        "meraculous": meraculous,
        "kmer": kmer,
        "lm_step": lm_step,
    }
    args = [a for a in sys.argv[1:]]
    smoke = "--smoke" in args
    fused = "--fused" in args
    faults = "--faults" in args
    async_ = "--async" in args
    skew = "none"
    if "--skew" in args:
        i = args.index("--skew")
        skew = args[i + 1] if i + 1 < len(args) else ""
        if skew not in ("zipf",):
            sys.exit(f"--skew takes a distribution name (zipf), "
                     f"got {skew!r}")
        del args[i:i + 2]
    transport = "dense"
    if "--transport" in args:
        i = args.index("--transport")
        transport = args[i + 1] if i + 1 < len(args) else ""
        if transport not in ("dense", "hier"):
            sys.exit(f"--transport takes dense or hier, got {transport!r}")
        del args[i:i + 2]
    wire = "auto"
    if "--wire" in args:
        i = args.index("--wire")
        wire = args[i + 1] if i + 1 < len(args) else ""
        if wire not in ("scatter", "fused"):
            sys.exit(f"--wire takes scatter or fused, got {wire!r}")
        del args[i:i + 2]
    args = [a for a in args if a not in ("--smoke", "--fused", "--faults", "--async")]
    only = args[0] if args else None
    print(HEADER)
    for name, mod in mods.items():
        if only and name != only:
            continue
        params = inspect.signature(mod.run).parameters
        kw = {}
        if smoke and "smoke" in params:
            kw["smoke"] = True
        if fused and "fused" in params:
            kw["fused"] = True
        if skew != "none" and "skew" in params:
            kw["skew"] = skew
        if transport != "dense" and "transport" in params:
            kw["transport"] = transport
        if faults and "faults" in params:
            kw["faults"] = True
        if async_ and "async_" in params:
            kw["async_"] = True
        if wire != "auto" and "wire" in params:
            kw["wire"] = wire
        try:
            if smoke and "smoke" not in params:
                print(f"{name},SKIPPED,,,,,,,,,,,,,no smoke mode yet")
            elif transport != "dense" and "transport" not in params:
                print(f"{name},SKIPPED,,,,,,,,,,,,,no transport arm yet")
            elif wire != "auto" and "wire" not in params:
                print(f"{name},SKIPPED,,,,,,,,,,,,,no wire arm yet")
            else:
                mod.run(**kw)
        except Exception as e:  # keep the harness going; report the row
            print(f"{name},ERROR,,,,,,,,,,,,,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
