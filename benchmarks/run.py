"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/util.emit).

  micro_hashmap   paper Fig. 9   (insert / insert_buffer / find variants)
  micro_queue     paper Fig. 10/11 (CircularQueue vs FastQueue, promises)
  isx             paper Fig. 5   (bucket sort, aggregation sweep)
  meraculous      paper Fig. 6/7 (contig-generation build + traversal)
  kmer            paper Fig. 8   (k-mer counting +/- Bloom filter)
  lm_step         framework-side step throughput (reduced configs)
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import isx, kmer, lm_step, meraculous, micro_hashmap, \
        micro_queue
    mods = {
        "micro_hashmap": micro_hashmap,
        "micro_queue": micro_queue,
        "isx": isx,
        "meraculous": meraculous,
        "kmer": kmer,
        "lm_step": lm_step,
    }
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for name, mod in mods.items():
        if only and name != only:
            continue
        try:
            mod.run()
        except Exception as e:  # keep the harness going; report the row
            print(f"{name},ERROR,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
