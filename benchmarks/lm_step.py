"""Framework-side benchmark: LM train/decode step throughput (reduced
configs on CPU; the full-size numbers live in the dry-run roofline)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit, time_fn
from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import init_state, make_serve_step, make_train_step
from repro.models import lm
from repro.models.sharding import Axes


def run(smoke: bool = False):
    mesh = make_test_mesh(1, 1)
    axes = Axes.from_mesh(mesh)
    rng = jax.random.PRNGKey(0)
    results = {}
    archs = ("stablelm-1.6b",) if smoke else \
        ("stablelm-1.6b", "arctic-480b", "rwkv6-1.6b")
    for arch in archs:
        cfg = reduced(get_config(arch))
        params, opt, _, _ = init_state(cfg, mesh, rng)
        b, t = (2, 32) if smoke else (4, 128)
        batch = {"tokens": jax.random.randint(rng, (b, t + 1), 0, cfg.vocab),
                 "loss_mask": jnp.ones((b, t), jnp.float32)}
        step = jax.jit(make_train_step(cfg, mesh))
        dt = time_fn(step, params, opt, batch, warmup=1, iters=3)
        toks_s = b * t / dt
        results[f"train_{arch}"] = dt * 1e6
        emit(f"lm_train_{arch}", dt * 1e6, f"{toks_s/1e3:.1f}ktok/s")

        cache, _ = jax.jit(lambda p, bb: lm.prefill(
            p, cfg, bb, cache_len=t + 8, mesh=mesh, axes=axes))(
            params, {"tokens": batch["tokens"][:, :t]})
        dstep = jax.jit(make_serve_step(cfg, mesh))
        tok = jnp.zeros((b, 1), jnp.int32)
        dt = time_fn(lambda c: dstep(params, c, tok)[1], cache,
                     warmup=1, iters=3)
        results[f"decode_{arch}"] = dt * 1e6
        emit(f"lm_decode_{arch}", dt * 1e6, f"{b/dt:.0f}tok/s")
    return results


if __name__ == "__main__":
    run()
