"""Framework-side benchmark: LM train/decode step throughput (reduced
configs on CPU; the full-size numbers live in the dry-run roofline).

The ``--skew zipf`` arm exercises MoE dispatch under zipf-routed tokens
(a rigged router bias concentrates every token's top-k on the first
experts — the hottest expert histogram zipf routing can produce):

  lm_moe_skew_drop    one dispatch round at uniform expert capacity:
                      the hot experts overflow and tokens are dropped
                      (counted via the stats flow's served counts)
  lm_moe_skew_retry   ``exchange.suggest_rounds`` picks the dispatch
                      round count from the observed expert_load
                      trajectory; every token is served

The ``--async`` arm (DESIGN.md section 1.9) runs the reduced MoE step
with sync vs split-phase dispatch (``cfg.moe_async_dispatch``): the
async row's overlap_launches column counts the deferred dispatch
launches and every other cost column matches the sync row.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.util import emit, time_fn
from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import init_state, make_serve_step, make_train_step
from repro.models import lm
from repro.models.sharding import Axes


def _moe_skew_arm(results: dict, smoke: bool):
    """MoE dispatch under maximal routing skew (ROADMAP item: lm_step
    skew arm): drop-mode vs suggest_rounds-driven retry rounds."""
    from benchmarks.util import bench_skew_arm
    from repro.core import suggest_rounds
    from repro.models import moe as moe_mod

    b, t = (2, 16) if smoke else (4, 64)
    cfg = reduced(get_config("arctic-480b"), d_model=32, vocab=256)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2,
                                     expert_d_ff=16,
                                     bias_update_rate=0.01),
        moe_capacity_slack=1.0)
    mesh = make_test_mesh(1, 1)
    axes = Axes.from_mesh(mesh)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    e = cfg.moe.n_experts
    # zipf-routed tokens: a dominant router bias pins every token's
    # top-k on experts 0..k-1 — the degenerate zipf head
    params["moe_bias"] = jnp.arange(e, 0, -1).astype(jnp.float32) * 100.0
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model))
    n_assign = b * t * cfg.moe.top_k
    uniform_cap = max(1, n_assign // e)

    def arm(rounds, tag):
        cfg_r = dataclasses.replace(cfg, moe_dispatch_rounds=rounds)

        @jax.jit
        def step(params, x):
            y, _, stats = moe_mod.moe_apply(params, x, cfg_r, mesh, axes)
            served = stats["expert_load"].sum().astype(jnp.int32)
            return y, jnp.int32(n_assign) - served

        bench_skew_arm(step, tag, rounds, n_assign, results, params, x,
                       derived="zipf-routed tokens @ uniform expert cap")

    arm(1, "lm_moe_skew_drop")
    # observed load trajectory: the drop arm's served counts understate
    # the hot load, so feed the routing histogram itself (every token's
    # k assignments land on the bias head)
    hot_loads = [n_assign // cfg.moe.top_k] * 2
    arm(suggest_rounds(hot_loads, uniform_cap), "lm_moe_skew_retry")


def _moe_async_arm(results: dict, smoke: bool):
    """Split-phase MoE dispatch (DESIGN.md section 1.9): the sync and
    async arms run the identical reduced MoE step; the async row's
    overlap_launches column counts the dispatch launches whose
    completion was deferred past the overlap window, and every other
    cost column matches the sync row exactly (the attribution rule:
    deferred launches are charged once, at the wait)."""
    from repro.core import costs
    from repro.models import moe as moe_mod

    b, t = (2, 16) if smoke else (4, 64)
    cfg = reduced(get_config("arctic-480b"), d_model=32, vocab=256)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2,
                                     expert_d_ff=16))
    mesh = make_test_mesh(1, 1)
    axes = Axes.from_mesh(mesh)
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model))
    n_tok = b * t

    def arm(split, tag):
        cfg_a = dataclasses.replace(cfg, moe_async_dispatch=split)

        @jax.jit
        def step(params, x):
            y, _, _ = moe_mod.moe_apply(params, x, cfg_a, mesh, axes)
            return y

        with costs.recording() as log:
            jax.block_until_ready(step(params, x))
        dt = time_fn(step, params, x, warmup=1, iters=3)
        results[tag] = dt / n_tok * 1e6
        c = log.total()
        results[tag + "_overlap"] = c.overlap_launches
        emit(tag, results[tag],
             "split-phase dispatch" if split else "sync dispatch baseline",
             cost=c, n_ops=n_tok)

    arm(False, "lm_moe_dispatch_sync")
    arm(True, "lm_moe_dispatch_async")


def run(smoke: bool = False, skew: str = "none", async_: bool = False):
    mesh = make_test_mesh(1, 1)
    axes = Axes.from_mesh(mesh)
    rng = jax.random.PRNGKey(0)
    results = {}
    if skew == "zipf":
        _moe_skew_arm(results, smoke)
    if async_:
        _moe_async_arm(results, smoke)
    archs = ("stablelm-1.6b",) if smoke else \
        ("stablelm-1.6b", "arctic-480b", "rwkv6-1.6b")
    for arch in archs:
        cfg = reduced(get_config(arch))
        params, opt, _, _ = init_state(cfg, mesh, rng)
        b, t = (2, 32) if smoke else (4, 128)
        batch = {"tokens": jax.random.randint(rng, (b, t + 1), 0, cfg.vocab),
                 "loss_mask": jnp.ones((b, t), jnp.float32)}
        step = jax.jit(make_train_step(cfg, mesh))
        dt = time_fn(step, params, opt, batch, warmup=1, iters=3)
        toks_s = b * t / dt
        results[f"train_{arch}"] = dt * 1e6
        emit(f"lm_train_{arch}", dt * 1e6, f"{toks_s/1e3:.1f}ktok/s")

        cache, _ = jax.jit(lambda p, bb: lm.prefill(
            p, cfg, bb, cache_len=t + 8, mesh=mesh, axes=axes))(
            params, {"tokens": batch["tokens"][:, :t]})
        dstep = jax.jit(make_serve_step(cfg, mesh))
        tok = jnp.zeros((b, 1), jnp.int32)
        dt = time_fn(lambda c: dstep(params, c, tok)[1], cache,
                     warmup=1, iters=3)
        results[f"decode_{arch}"] = dt * 1e6
        emit(f"lm_decode_{arch}", dt * 1e6, f"{b/dt:.0f}tok/s")
    return results


if __name__ == "__main__":
    run()
