"""Paper Figures 10/11: CircularQueue and FastQueue microbenchmarks.

Variants (paper naming):
  push_pushpop / pop_pushpop    CircularQueue fully atomic (2A + nW/nR)
  push_push / pop_pop           CircularQueue phase-relaxed
  fq_push / fq_pop              FastQueue (A + nW/nR)
  *_many                        one queue per rank, all ranks pushing

The ``--fused`` arm adds the ExchangePlan fusion pair:
  cq_push_pop_fused             push + pop flows sharing one plan (2
                                collectives per wave)
  cq_push_pop_fine              the Promise.FINE sequential oracle (3)

The ``--skew zipf`` arm adds the skew-tolerance pair (mean-load wire
capacity, zipf-sized waves into one hot ring — the hottest (src,dst)
bucket the paper's aggregation can produce):
  fq_push_skew_drop             drop-mode: overflow is counted data loss
  fq_push_skew_retry            carryover retry rounds: zero drops at
                                the same per-round capacity

The ``--async`` arm adds the split-phase pair (DESIGN.md section 1.9):
  cq_push_pop_sync              one-shot commit baseline
  cq_push_pop_async             commit_async/finish: identical results
                                and cost columns, plus the
                                overlap_launches observable

The ``--wire {scatter,fused}`` arm re-runs every variant with the
send-buffer construction pinned (DESIGN.md section 1.10): rows gain the
``_scatter`` / ``_fused`` suffix and the hbm_passes column reports the
traced call's standalone scatter-op count.

The ``--faults`` arm (DESIGN.md section 1.8) pushes through a
FaultInjectingTransport with a seeded corrupt spec under the integrity
checksum, heals the invalidated arrivals with a carry re-push, and
probes a degraded commit; the lost_bytes / recovered / unreachable
columns report the loss, the heal, and the dead-rank mask.

Each row carries the collective/bytes/rounds observables (and
rounds_per_op) of one jitted call so exchange-layer regressions show up
next to wall time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from benchmarks.util import (count_hbm_passes, emit, resolve_transport,
                             resolve_wire, time_fn, trace_costs)
from repro.core import ConProm, Promise, get_backend
from repro.containers import queue as q

N_OPS = 1 << 14
WAVES = 8


def run(smoke: bool = False, fused: bool = False, skew: str = "none",
        transport: str = "dense", faults: bool = False,
        async_: bool = False, wire: str = "auto"):
    tr, sfx = resolve_transport(transport)
    impl, wsfx = resolve_wire(wire)
    sfx = sfx + wsfx
    n_ops = 1 << 8 if smoke else N_OPS
    bk = get_backend(None)
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.integers(0, 1 << 30, n_ops), jnp.uint32)
    dest = jnp.zeros(n_ops, jnp.int32)
    wave = n_ops // WAVES
    results = {}
    obs = {}
    passes = {}

    def bench_push(circular, promise, tag):
        spec, st0 = q.queue_create(bk, n_ops * 2, SDS((), jnp.uint32),
                                   circular=circular)

        @jax.jit
        def pushes(st, vals, dest):
            for i in range(WAVES):
                st, _, _ = q.push(bk, spec, st,
                                  vals[i * wave:(i + 1) * wave],
                                  dest[i * wave:(i + 1) * wave],
                                  capacity=wave, promise=promise,
                                  transport=tr, impl=impl)
            return st

        obs[tag] = trace_costs(pushes, st0, vals, dest)
        passes[tag] = count_hbm_passes(pushes, st0, vals, dest)
        t = time_fn(pushes, st0, vals, dest)
        results[tag] = t / n_ops * 1e6
        return spec, pushes

    bench_push(True, ConProm.CircularQueue.push_pop, "cq_push_pushpop")
    bench_push(True, ConProm.CircularQueue.push, "cq_push_push")
    bench_push(False, ConProm.FastQueue.push, "fq_push")

    def bench_pop(circular, promise, tag):
        spec, st0 = q.queue_create(bk, n_ops * 2, SDS((), jnp.uint32),
                                   circular=circular)
        st0, _, _ = q.push(bk, spec, st0, vals, dest, capacity=n_ops)

        @jax.jit
        def pops(st):
            outs = []
            for _ in range(WAVES):
                st, out, got = q.pop(bk, spec, st, wave, 0, promise=promise,
                                     transport=tr, impl=impl)
                outs.append(out)
            return st, outs

        obs[tag] = trace_costs(pops, st0)
        passes[tag] = count_hbm_passes(pops, st0)
        t = time_fn(pops, st0)
        results[tag] = t / n_ops * 1e6

    bench_pop(True, ConProm.CircularQueue.push_pop, "cq_pop_pushpop")
    bench_pop(True, ConProm.CircularQueue.pop, "cq_pop_pop")
    bench_pop(False, ConProm.FastQueue.pop, "fq_pop")

    # local nonatomic pop (Table 2: l)
    spec, st0 = q.queue_create(bk, n_ops * 2, SDS((), jnp.uint32))
    st0, _, _ = q.push(bk, spec, st0, vals, dest, capacity=n_ops)

    @jax.jit
    def local_pops(st):
        for _ in range(WAVES):
            st, out, got = q.local_nonatomic_pop(spec, st, wave)
        return st, out

    obs["fq_local_pop"] = trace_costs(local_pops, st0)
    passes["fq_local_pop"] = count_hbm_passes(local_pops, st0)
    results["fq_local_pop"] = time_fn(local_pops, st0) / n_ops * 1e6

    # --- fused arm: push+pop sharing one plan vs the FINE oracle ---
    if fused:
        def pp(promise, tag):
            spec, st0 = q.queue_create(bk, n_ops * 2, SDS((), jnp.uint32),
                                       circular=True)

            @jax.jit
            def waves(st, vals, dest):
                outs = []
                for i in range(WAVES):
                    sl = slice(i * wave, (i + 1) * wave)
                    st, _, _, out, _ = q.push_pop(
                        bk, spec, st, vals[sl], dest[sl], wave, wave, 0,
                        promise=promise, transport=tr)
                    outs.append(out)
                return st, outs

            obs[tag] = trace_costs(waves, st0, vals, dest)
            # 2 ops (one push + one pop) per wave item
            results[tag] = time_fn(waves, st0, vals, dest) \
                / (2 * n_ops) * 1e6

        pp(ConProm.CircularQueue.push_pop, "cq_push_pop_fused")
        pp(ConProm.CircularQueue.push_pop | Promise.FINE, "cq_push_pop_fine")

    # --- async arm: split-phase push_pop (DESIGN.md section 1.9) ---
    if async_:
        def ppa(split, tag):
            spec, st0 = q.queue_create(bk, n_ops * 2, SDS((), jnp.uint32),
                                       circular=True)

            @jax.jit
            def waves(st, vals, dest):
                outs = []
                for i in range(WAVES):
                    sl = slice(i * wave, (i + 1) * wave)
                    if split:
                        pend = q.push_pop(
                            bk, spec, st, vals[sl], dest[sl], wave, wave, 0,
                            promise=ConProm.CircularQueue.push_pop,
                            transport=tr, async_=True)
                        st, _, _, out, _ = pend.finish()
                    else:
                        st, _, _, out, _ = q.push_pop(
                            bk, spec, st, vals[sl], dest[sl], wave, wave, 0,
                            promise=ConProm.CircularQueue.push_pop,
                            transport=tr)
                    outs.append(out)
                return st, outs

            obs[tag] = trace_costs(waves, st0, vals, dest)
            results[tag] = time_fn(waves, st0, vals, dest) \
                / (2 * n_ops) * 1e6

        ppa(False, "cq_push_pop_sync")
        ppa(True, "cq_push_pop_async")

    # --- skew arm: mean-load capacity, drop-mode vs carryover retries ---
    if skew == "zipf":
        from benchmarks.util import (bench_skew_arm, mean_load_cap,
                                     skew_retry_rounds, zipf_wave_mask)
        zcap = mean_load_cap(wave)
        valid = zipf_wave_mask(WAVES, wave, n_ops)         # (WAVES, wave)
        n_skew = int(valid.sum())      # actual ops (hot waves saturate)
        # observed trajectory: the all-to-one hot bucket's load is each
        # wave's valid count; suggest_rounds picks R off the peak
        rr = skew_retry_rounds(
            [int(x) for x in np.asarray(valid.sum(axis=1))], zcap)

        def bench_skew(rounds, tag):
            spec, st0 = q.queue_create(bk, n_ops * 2, SDS((), jnp.uint32))

            @jax.jit
            def pushes(st, vals, dest):
                dropped = jnp.int32(0)
                for i in range(WAVES):
                    sl = slice(i * wave, (i + 1) * wave)
                    st, _, d = q.push(bk, spec, st, vals[sl], dest[sl],
                                      capacity=zcap, valid=valid[i],
                                      max_rounds=rounds, transport=tr)
                    dropped = dropped + d
                return st, dropped

            bench_skew_arm(pushes, tag, rounds, n_skew, results,
                           st0, vals, dest,
                           derived="zipf waves @ mean-load capacity")

        bench_skew(1, "fq_push_skew_drop" + sfx)
        bench_skew(rr, "fq_push_skew_retry" + sfx)

    # --- faults arm: seeded corruption healed by integrity + carry ---
    if faults:
        from repro.core import FaultInjectingTransport, FaultSpec, costs
        fspec = FaultSpec(seed=7, corrupt=((0, 0, 0),))
        ftr = FaultInjectingTransport(tr, fspec)
        spec_f, st_f = q.queue_create(bk, n_ops * 2, SDS((), jnp.uint32))

        @jax.jit
        def faulty_push(st, vals, dest):
            # first shot over the faulty fabric: the corrupted segment's
            # arrivals fail their checksum, get no ack, land in carry
            st, _, _, carry = q.push(
                bk, spec_f, st, vals, dest, capacity=n_ops,
                overflow="carry", transport=ftr, integrity=True)
            # heal: re-inject exactly the carried rows over a clean wire
            st, _, _, carry2 = q.push(
                bk, spec_f, st, vals, dest, capacity=n_ops, valid=carry,
                overflow="carry", transport=tr, integrity=True)
            return st, carry.sum().astype(jnp.int32), \
                carry2.sum().astype(jnp.int32)

        with costs.recording() as flog:
            out = faulty_push(st_f, vals, dest)
            # degraded-commit probe: rank 0 declared dead at admission
            q.push(bk, spec_f, out[0], vals[:8], dest[:8], capacity=8,
                   dead_ranks=(0,))
            jax.block_until_ready(out)
        lost_items = int(out[1])
        recovered = lost_items - int(out[2])
        row_bytes = 4 * (spec_f.lanes + 1)       # payload + meta lane
        t = time_fn(faulty_push, st_f, vals, dest, warmup=1, iters=3)
        emit("fq_push_faults" + sfx, t / n_ops * 1e6,
             "seeded corrupt + carry heal + degraded probe",
             cost=flog.total(), n_ops=n_ops,
             lost_bytes=lost_items * row_bytes, recovered=recovered,
             unreachable=int(flog.total().unreachable))

    for k in ("cq_push_pushpop", "cq_push_push", "fq_push",
              "cq_pop_pushpop", "cq_pop_pop", "fq_pop", "fq_local_pop"):
        emit(k + sfx, results[k],
             "2A" if "pushpop" in k else ("A" if k.startswith("fq") else "2A"),
             cost=obs[k], n_ops=n_ops, hbm_passes=passes[k])
    if fused:
        emit("cq_push_pop_fused" + sfx, results["cq_push_pop_fused"],
             "2 collectives/wave", cost=obs["cq_push_pop_fused"],
             n_ops=2 * n_ops)
        emit("cq_push_pop_fine" + sfx, results["cq_push_pop_fine"],
             "FINE oracle: 3 collectives", cost=obs["cq_push_pop_fine"],
             n_ops=2 * n_ops)
    if async_:
        emit("cq_push_pop_sync" + sfx, results["cq_push_pop_sync"],
             "one-shot commit", cost=obs["cq_push_pop_sync"],
             n_ops=2 * n_ops)
        emit("cq_push_pop_async" + sfx, results["cq_push_pop_async"],
             "split-phase commit_async/finish",
             cost=obs["cq_push_pop_async"], n_ops=2 * n_ops)
    return results


if __name__ == "__main__":
    run()
