"""Paper Figure 5: ISx bucket sort via queue exchange.

Measures keys/second through distribute(queue push with aggregation) +
local sort, sweeping the aggregation message size — the paper's central
claim is that aggregation turns latency-bound pushes into bandwidth-
bound ones and that larger messages amortize slow transports.

The ``--skew zipf`` arm distributes zipf-sized key waves at mean-load
wire capacity (the ISx distribution stage under a skewed key histogram):
  isx_skew_drop     drop-mode: overflowed keys are counted data loss
  isx_skew_retry    carryover retry rounds keep the sort lossless at
                    the same per-round wire footprint
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from benchmarks.util import emit, resolve_transport, time_fn
from repro.core import get_backend
from repro.containers import queue as q

N_KEYS = 1 << 16


def bucket_sort(message_size: int, n_keys: int = N_KEYS, tr=None):
    """The paper's Fig. 3 program: buffer locally per destination, push
    full buckets, barrier, local sort."""
    bk = get_backend(None)
    spec, st0 = q.queue_create(bk, n_keys * 2, SDS((), jnp.uint32))
    n_msgs = n_keys // message_size

    @jax.jit
    def sort_fn(st, keys):
        dest = jnp.zeros(message_size, jnp.int32)
        for i in range(n_msgs):
            st, _, _ = q.push(bk, spec, st,
                              keys[i * message_size:(i + 1) * message_size],
                              dest, capacity=message_size, transport=tr)
        bk.barrier()
        rows, got = q.local_drain(spec, st)
        return jnp.sort(jnp.where(got, rows, jnp.uint32(0xFFFFFFFF)))

    return sort_fn, st0


def run(smoke: bool = False, skew: str = "none",
        transport: str = "dense"):
    tr, sfx = resolve_transport(transport)
    n_keys = 1 << 10 if smoke else N_KEYS
    sweep = (256,) if smoke else (256, 1024, 4096, 16384)
    check_msg = 256 if smoke else 4096
    rng = np.random.default_rng(2)
    keys = jnp.asarray(rng.integers(0, 1 << 28, n_keys), jnp.uint32)
    results = {}
    for msg in sweep:
        fn, st0 = bucket_sort(msg, n_keys, tr)
        t = time_fn(fn, st0, keys, warmup=1, iters=3)
        keys_per_s = n_keys / t
        results[f"isx_msg{msg}"] = t * 1e6
        emit(f"isx_msg{msg}{sfx}", t * 1e6, f"{keys_per_s/1e6:.2f}Mkeys/s")
    # correctness spot check
    fn, st0 = bucket_sort(check_msg, n_keys, tr)
    out = np.asarray(fn(st0, keys))[:n_keys]
    assert np.array_equal(out, np.sort(np.asarray(keys))), "sort wrong!"

    # --- skew arm: zipf-sized waves at mean-load wire capacity ---
    if skew == "zipf":
        from benchmarks.util import (bench_skew_arm, mean_load_cap,
                                     skew_retry_rounds, zipf_wave_mask)
        bk = get_backend(None)
        waves = 8
        wave = n_keys // waves
        zcap = mean_load_cap(wave)      # ceil: rounds x cap covers a wave
        valid = zipf_wave_mask(waves, wave, n_keys)
        n_skew = int(valid.sum())
        rr = skew_retry_rounds(
            [int(x) for x in np.asarray(valid.sum(axis=1))], zcap)

        def bench_skew(rounds, tag):
            spec, st0 = q.queue_create(bk, n_keys * 2, SDS((), jnp.uint32))

            @jax.jit
            def distribute(st, keys):
                dest = jnp.zeros(wave, jnp.int32)
                dropped = jnp.int32(0)
                for i in range(waves):
                    st, _, d = q.push(
                        bk, spec, st, keys[i * wave:(i + 1) * wave], dest,
                        capacity=zcap, valid=valid[i], max_rounds=rounds,
                        transport=tr)
                    dropped = dropped + d
                bk.barrier()
                rows, got = q.local_drain(spec, st)
                return jnp.sort(
                    jnp.where(got, rows, jnp.uint32(0xFFFFFFFF))), dropped

            bench_skew_arm(distribute, tag, rounds, n_skew, results,
                           st0, keys,
                           derived="zipf waves @ mean-load capacity")

        bench_skew(1, "isx_skew_drop" + sfx)
        bench_skew(rr, "isx_skew_retry" + sfx)
    return results


if __name__ == "__main__":
    run()
