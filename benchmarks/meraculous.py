"""Paper Figures 6/7: Meraculous contig generation.

Two phases over a synthetic genome (the chr14 workflow shape):
  build      k-mer -> next-base de Bruijn table via HashMapBuffer
             (staged inserts + flush with local fast inserts)
  traverse   batched walks with phase-local finds (Table 3d promise)

Reported as k-mers/s per phase; the BCL claims under test are that the
buffered build beats direct atomic insertion and that the relaxed
traversal beats atomic finds (benchmarks/micro_hashmap.py isolates the
per-op ratios; this one shows them inside the real pipeline).

The ``--skew zipf`` arm runs the buffered build's flush at mean-load
wire capacity:
  meraculous_build_skew_drop    drop-mode: spilled k-mers past capacity
                                are counted data loss
  meraculous_build_skew_retry   carryover retry rounds make the one-shot
                                flush lossless
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from benchmarks.util import emit, time_fn
from repro.core import ConProm, get_backend
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb
from repro.data.genomics import extract_kmers, pack_kmers

K = 15


def run(smoke: bool = False, skew: str = "none"):
    bk = get_backend(None)
    rng = np.random.default_rng(4)
    genome = rng.integers(0, 4, 1 << 10 if smoke else 1 << 13).astype(np.uint8)
    kmers = pack_kmers(extract_kmers(genome[None], K))[:-1]
    next_base = jnp.asarray(genome[K:].astype(np.uint32))
    n = kmers.shape[0]
    kspec = {"hi": SDS((), jnp.uint32), "lo": SDS((), jnp.uint32)}
    keys = {"hi": jnp.asarray(kmers[:, 0]), "lo": jnp.asarray(kmers[:, 1])}
    n_walks, steps = (64, 8) if smoke else (256, 64)

    # ---- build phase: buffered vs direct ----
    def fresh():
        return hm.hashmap_create(bk, 1 << (12 if smoke else 15), kspec,
                                 SDS((), jnp.uint32), block_size=64)

    @jax.jit
    def build_direct(keys, vals):
        spec, st = fresh()
        st, ok = hm.insert(bk, spec, st, keys, vals, capacity=n, attempts=2)
        return st, ok

    @jax.jit
    def build_buffered(keys, vals):
        spec, st = fresh()
        bspec, bst = hb.create(bk, spec, st, queue_capacity=2 * n,
                               buffer_cap=2 * n)
        bst, _ = hb.insert(bspec, bst, keys, vals)
        bst, dropped = hb.flush(bk, bspec, bst, capacity=2 * n)
        return bst.map, dropped

    t_direct = time_fn(build_direct, keys, next_base, warmup=1, iters=3)
    t_buf = time_fn(build_buffered, keys, next_base, warmup=1, iters=3)

    # ---- traversal phase: batched de Bruijn walk ----
    spec, _ = fresh()
    state, ok = build_direct(keys, next_base)
    assert bool(np.asarray(ok).all())

    starts = kmers[rng.integers(0, n, n_walks)]

    @jax.jit
    def traverse(state, start_hi, start_lo):
        cur_hi, cur_lo = start_hi, start_lo
        total = jnp.zeros((), jnp.uint32)
        for _ in range(steps):
            st2, v, found = hm.find(bk, spec, state,
                                    {"hi": cur_hi, "lo": cur_lo},
                                    capacity=cur_hi.shape[0],
                                    promise=ConProm.HashMap.find,
                                    attempts=2)
            b = v & jnp.uint32(3)
            # advance kmer: (cur << 2 | b) mod 4^K   on u32-pair lanes
            new_hi = ((cur_hi << 2) | (cur_lo >> 30)) & \
                jnp.uint32((1 << (2 * K - 32)) - 1 if 2 * K > 32 else 0)
            new_lo = (cur_lo << 2) | b
            cur_hi = jnp.where(found, new_hi, cur_hi)
            cur_lo = jnp.where(found, new_lo, cur_lo)
            total = total + found.sum().astype(jnp.uint32)
        return total

    t_walk = time_fn(traverse, state, jnp.asarray(starts[:, 0]),
                     jnp.asarray(starts[:, 1]), warmup=1, iters=3)
    walked = int(traverse(state, jnp.asarray(starts[:, 0]),
                          jnp.asarray(starts[:, 1])))

    emit("meraculous_build_direct", t_direct / n * 1e6,
         f"{n/t_direct/1e6:.2f}Mkmer/s")
    emit("meraculous_build_buffered", t_buf / n * 1e6,
         f"speedup={t_direct/t_buf:.2f}x")
    emit("meraculous_traverse", t_walk / (n_walks * steps) * 1e6,
         f"extended={walked}")
    results = {"build_direct": t_direct, "build_buffered": t_buf,
               "traverse": t_walk}

    # --- skew arm: buffered flush at mean-load wire capacity ---
    if skew == "zipf":
        from benchmarks.util import (bench_skew_arm, mean_load_cap,
                                     skew_retry_rounds)
        zcap = mean_load_cap(n)      # ceil: rounds x cap covers n
        # worst observable bucket load is the whole batch (one hot
        # owner); suggest_rounds turns it into the minimal cover
        rr = skew_retry_rounds([n], zcap)

        def bench_skew(rounds, tag):
            @jax.jit
            def build_skew(keys, vals):
                # roomier table than the timing arms: the pin isolates
                # WIRE loss, so attempt-0 block overflow must stay out
                spec2, st2 = hm.hashmap_create(
                    bk, 1 << (14 if smoke else 17), kspec,
                    SDS((), jnp.uint32), block_size=128)
                bspec, bst = hb.create(bk, spec2, st2, queue_capacity=2 * n,
                                       buffer_cap=2 * n)
                bst, _ = hb.insert(bspec, bst, keys, vals)
                bst, dropped = hb.flush(bk, bspec, bst, capacity=zcap,
                                        max_rounds=rounds)
                return bst.map, dropped

            bench_skew_arm(build_skew, tag, rounds, n, results,
                           keys, next_base)

        bench_skew(1, "meraculous_build_skew_drop")
        bench_skew(rr, "meraculous_build_skew_retry")
    return results


if __name__ == "__main__":
    run()
