"""Shared benchmark timing utilities."""

from __future__ import annotations

import time

import jax

from repro.core import costs


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw):
    """Median wall time per call (seconds) of a jit-compatible fn."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def trace_costs(fn, *args, **kw):
    """Cost observables of one call of ``fn`` (collectives, bytes, rounds).

    Costs are recorded at trace time, so this must run on a FRESH jit
    wrapper (an already-compiled fn records nothing).  Call it before
    ``time_fn``; the traced call doubles as warmup.
    """
    with costs.recording() as log:
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return log.total()


#: the one CSV schema every benchmark row follows (schema-checked by
#: tests/test_benchmarks_smoke.py).  ``hops`` counts physical exchange
#: stages (1 per dense launch, 2 per hierarchical launch) so the
#: ``--transport`` arms' extra stage shows up next to wall time.
#: ``hbm_passes`` counts standalone XLA scatter-family ops in the traced
#: call (launch/jaxpr_stats.op_counts) — the ``--wire`` arms' structural
#: observable: the fused Pallas wire path writes each send buffer once
#: in-kernel, so its rows report strictly fewer passes than the
#: scatter_rows fallback (DESIGN.md section 1.10).
HEADER = ("name,us_per_call,collectives,bytes_moved,rounds,"
          "rounds_per_op,retry_rounds,dropped,hops,"
          "lost_bytes,recovered,unreachable,overlap_launches,"
          "hbm_passes,derived")


def count_hbm_passes(fn, *args) -> int:
    """Standalone scatter-family op count of ONE traced call of ``fn``.

    Pallas kernel bodies are opaque (their in-kernel stores are vector
    writes, not HBM scatter passes), so this is exactly the number of
    XLA gather/scatter wire passes the call pays — the ``hbm_passes``
    CSV column.
    """
    from repro.launch import jaxpr_stats
    counts = jaxpr_stats.op_counts(fn, *args)
    return sum(v for k, v in counts.items() if k.startswith("scatter"))


def resolve_wire(name: str):
    """Shared ``--wire {scatter,fused}`` plumbing: impl + row-name tag.

    Returns ``(impl, suffix)`` — the kernel-dispatch impl to thread into
    container calls ("jnp" keeps the documented scatter_rows fallback,
    "pallas" takes the one-kernel wire path) and the row-name suffix
    ("" for the backend default, so existing arms keep their names).
    """
    if name not in ("auto", "scatter", "fused"):
        raise ValueError(f"--wire takes scatter or fused, got {name!r}")
    impl = {"auto": "auto", "scatter": "jnp", "fused": "pallas"}[name]
    return impl, "" if name == "auto" else f"_{name}"


def resolve_transport(name: str):
    """Shared ``--transport {dense,hier}`` plumbing: transport + tag.

    Returns ``(transport, suffix)`` — the transport instance to thread
    into container calls and the row-name suffix ("" for dense, so the
    default arms keep their historical names).
    """
    from repro.core import make_transport
    return make_transport(name), "" if name == "dense" else f"_{name}"

#: the --skew arms' virtual peer count: ceil(wave / SKEW_PEERS) is the
#: uniform per-bucket expectation ("mean-load capacity")
SKEW_PEERS = 4


def skew_retry_rounds(loads, capacity: int) -> int:
    """The ``--skew`` retry arms' round pick (ROADMAP adaptive rounds).

    Feeds the observed per-wave peak bucket loads into
    ``exchange.suggest_rounds`` instead of hardcoding
    :data:`SKEW_PEERS`: the arm runs exactly as many carryover rounds
    as the hottest observed bucket needs at the given per-round
    capacity, so the losslessness pins hold by construction and the
    ``retry_rounds`` CSV column tracks the heuristic's actual pick.
    """
    from repro.core import suggest_rounds
    return suggest_rounds(loads, capacity, limit=2 * SKEW_PEERS)


def mean_load_cap(n: int) -> int:
    """Per-round wire capacity at the uniform per-peer expectation.

    Ceil division, so ``SKEW_PEERS`` retry rounds always cover ``n``
    exactly — the retry arms' losslessness pins depend on it.  Every
    benchmark's skew arm uses THIS definition, so drop/retry rows are
    comparable across micro and application workloads.
    """
    return max(1, -(-n // SKEW_PEERS))


def zipf_wave_mask(n_waves: int, wave: int, total: int, s: float = 1.2):
    """Shared --skew workload shape: valid masks (n_waves, wave) whose
    wave sizes follow ~ total/(w+1)^s (hot waves saturate at ``wave``),
    so early waves hammer the hot bucket far past mean-load capacity.
    One definition keeps the micro_hashmap and micro_queue skew arms
    comparable; callers normalize per-op timings by the mask's actual
    ``sum()``, not ``total``, because of the saturation."""
    import jax.numpy as jnp
    import numpy as np
    zw = np.array([1.0 / (w + 1) ** s for w in range(n_waves)])
    sizes = np.maximum((zw / zw.sum() * total).astype(int), 1)
    return jnp.asarray(np.arange(wave)[None, :] < sizes[:, None])


def bench_skew_arm(fn, tag: str, rounds: int, n_ops: int, results: dict,
                   *args, derived: str = "mean-load wire capacity"):
    """Shared ``--skew`` arm protocol: trace the cost observables on a
    fresh jit, time the arm, read its dropped count, and emit ONE
    schema-complete CSV row (retry_rounds + dropped columns filled).
    ``fn(*args)`` must return ``(_, dropped)``; timings and the drop
    count land in ``results[tag]`` / ``results[tag + "_dropped"]``.
    One definition keeps every benchmark's skew rows on the schema that
    tests/test_benchmarks_smoke.py pins.
    """
    # one call serves as cost trace, dropped-count read, AND warmup —
    # costs record at trace time, so this must be fn's first execution
    with costs.recording() as log:
        out = fn(*args)
        jax.block_until_ready(out)
    d = int(out[-1])
    t = time_fn(fn, *args, warmup=1, iters=3)
    results[tag] = t / n_ops * 1e6
    results[tag + "_dropped"] = d
    emit(tag, results[tag], derived, cost=log.total(), n_ops=n_ops,
         retry_rounds=rounds, dropped=d)


def emit(name: str, us_per_call: float, derived: str = "",
         cost=None, n_ops: int | None = None,
         retry_rounds: int | None = None, dropped: int | None = None,
         lost_bytes: int | None = None, recovered: int | None = None,
         unreachable: int | None = None, hbm_passes: int | None = None):
    """CSV row following :data:`HEADER`.

    ``rounds_per_op`` (rounds amortized over ``n_ops`` data-structure
    ops) is the collective-count observable of the plan/commit fusion:
    fused schedules cut it without touching bytes, so BENCH trajectories
    show the aggregation win directly.  ``retry_rounds``/``dropped``
    track skew tolerance: the ``--skew`` arms report how many carryover
    rounds they ran and how many items still fell off the wire, so the
    perf trajectory covers skewed traffic, not just uniform.
    ``lost_bytes``/``recovered``/``unreachable`` are the ``--faults``
    arms' observables (DESIGN.md section 1.8): wire bytes invalidated by
    injected faults, items healed by the integrity+carry retry, and dead
    destination ranks masked by a degraded commit; cost rows default the
    lost_bytes/unreachable columns from the recorded Cost fields.
    ``overlap_launches`` is the ``--async`` arms' observable (DESIGN.md
    section 1.9): collective launches issued split-phase whose
    completion was deferred past an overlap window.
    """
    rr = "" if retry_rounds is None else str(retry_rounds)
    dr = "" if dropped is None else str(dropped)
    lb = "" if lost_bytes is None else str(lost_bytes)
    rc = "" if recovered is None else str(recovered)
    un = "" if unreachable is None else str(unreachable)
    hp = "" if hbm_passes is None else str(hbm_passes)
    if cost is None:
        print(f"{name},{us_per_call:.2f},,,,,{rr},{dr},,"
              f"{lb},{rc},{un},,{hp},{derived}")
        return
    if lost_bytes is None:
        lb = str(cost.lost_bytes)
    if unreachable is None:
        un = str(cost.unreachable)
    rpo = f"{cost.rounds / n_ops:.6f}" if n_ops else ""
    print(f"{name},{us_per_call:.2f},{cost.collectives},"
          f"{cost.bytes_moved},{cost.rounds},{rpo},{rr},{dr},"
          f"{cost.hops},{lb},{rc},{un},{cost.overlap_launches},{hp},"
          f"{derived}")
