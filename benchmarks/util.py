"""Shared benchmark timing utilities."""

from __future__ import annotations

import time

import jax

from repro.core import costs


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw):
    """Median wall time per call (seconds) of a jit-compatible fn."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def trace_costs(fn, *args, **kw):
    """Cost observables of one call of ``fn`` (collectives, bytes, rounds).

    Costs are recorded at trace time, so this must run on a FRESH jit
    wrapper (an already-compiled fn records nothing).  Call it before
    ``time_fn``; the traced call doubles as warmup.
    """
    with costs.recording() as log:
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return log.total()


#: the one CSV schema every benchmark row follows (schema-checked by
#: tests/test_benchmarks_smoke.py)
HEADER = ("name,us_per_call,collectives,bytes_moved,rounds,"
          "rounds_per_op,retry_rounds,dropped,derived")

#: the --skew arms' virtual peer count: wave // SKEW_PEERS is the
#: uniform per-bucket expectation ("mean-load capacity")
SKEW_PEERS = 4


def zipf_wave_mask(n_waves: int, wave: int, total: int, s: float = 1.2):
    """Shared --skew workload shape: valid masks (n_waves, wave) whose
    wave sizes follow ~ total/(w+1)^s (hot waves saturate at ``wave``),
    so early waves hammer the hot bucket far past mean-load capacity.
    One definition keeps the micro_hashmap and micro_queue skew arms
    comparable; callers normalize per-op timings by the mask's actual
    ``sum()``, not ``total``, because of the saturation."""
    import jax.numpy as jnp
    import numpy as np
    zw = np.array([1.0 / (w + 1) ** s for w in range(n_waves)])
    sizes = np.maximum((zw / zw.sum() * total).astype(int), 1)
    return jnp.asarray(np.arange(wave)[None, :] < sizes[:, None])


def emit(name: str, us_per_call: float, derived: str = "",
         cost=None, n_ops: int | None = None,
         retry_rounds: int | None = None, dropped: int | None = None):
    """CSV row following :data:`HEADER`.

    ``rounds_per_op`` (rounds amortized over ``n_ops`` data-structure
    ops) is the collective-count observable of the plan/commit fusion:
    fused schedules cut it without touching bytes, so BENCH trajectories
    show the aggregation win directly.  ``retry_rounds``/``dropped``
    track skew tolerance: the ``--skew`` arms report how many carryover
    rounds they ran and how many items still fell off the wire, so the
    perf trajectory covers skewed traffic, not just uniform.
    """
    rr = "" if retry_rounds is None else str(retry_rounds)
    dr = "" if dropped is None else str(dropped)
    if cost is None:
        print(f"{name},{us_per_call:.2f},,,,,{rr},{dr},{derived}")
        return
    rpo = f"{cost.rounds / n_ops:.6f}" if n_ops else ""
    print(f"{name},{us_per_call:.2f},{cost.collectives},"
          f"{cost.bytes_moved},{cost.rounds},{rpo},{rr},{dr},{derived}")
