"""Shared benchmark timing utilities."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw):
    """Median wall time per call (seconds) of a jit-compatible fn."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.2f},{derived}")
