"""Shared benchmark timing utilities."""

from __future__ import annotations

import time

import jax

from repro.core import costs


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, **kw):
    """Median wall time per call (seconds) of a jit-compatible fn."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def trace_costs(fn, *args, **kw):
    """Cost observables of one call of ``fn`` (collectives, bytes, rounds).

    Costs are recorded at trace time, so this must run on a FRESH jit
    wrapper (an already-compiled fn records nothing).  Call it before
    ``time_fn``; the traced call doubles as warmup.
    """
    with costs.recording() as log:
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return log.total()


def emit(name: str, us_per_call: float, derived: str = "",
         cost=None, n_ops: int | None = None):
    """CSV row: name,us_per_call,collectives,bytes_moved,rounds,
    rounds_per_op,derived.

    ``rounds_per_op`` (rounds amortized over ``n_ops`` data-structure
    ops) is the collective-count observable of the plan/commit fusion:
    fused schedules cut it without touching bytes, so BENCH trajectories
    show the aggregation win directly.
    """
    if cost is None:
        print(f"{name},{us_per_call:.2f},,,,,{derived}")
        return
    rpo = f"{cost.rounds / n_ops:.6f}" if n_ops else ""
    print(f"{name},{us_per_call:.2f},{cost.collectives},"
          f"{cost.bytes_moved},{cost.rounds},{rpo},{derived}")
