"""Paper Figure 8: k-mer counting, with and without the blocked Bloom
filter pre-pass (the filter keeps singletons out of the hash table).

The ``--skew zipf`` arm counts at mean-load wire capacity (coverage
hotspots routinely skew k-mer traffic onto few owner ranks):
  kmer_insert_skew_drop     drop-mode: overflowed count updates are lost
  kmer_insert_skew_retry    carryover retry rounds land every update
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from benchmarks.util import emit, time_fn
from repro.core import get_backend
from repro.containers import bloom as bl
from repro.containers import hashmap as hm
from repro.data.genomics import GenomeSim, extract_kmers, pack_kmers
from repro.kernels.ops import MODE_ADD

K = 21


def run(smoke: bool = False, skew: str = "none"):
    bk = get_backend(None)
    glen = 1 << 10 if smoke else 1 << 13
    table_bits = 14 if smoke else 18
    bloom_bits = 17 if smoke else 21
    sim = GenomeSim(genome_len=glen, coverage=8, error_rate=0.01, seed=3)
    kmers = pack_kmers(extract_kmers(sim.reads(), K))
    n = kmers.shape[0]
    items = {"hi": jnp.asarray(kmers[:, 0]), "lo": jnp.asarray(kmers[:, 1])}
    kspec = {"hi": SDS((), jnp.uint32), "lo": SDS((), jnp.uint32)}
    ones = jnp.ones(n, jnp.uint32)
    results = {}

    @jax.jit
    def count_plain(items):
        spec, st = hm.hashmap_create(bk, 1 << table_bits, kspec,
                                     SDS((), jnp.uint32), block_size=64)
        st, ok = hm.insert(bk, spec, st, items, ones, capacity=n,
                           mode=MODE_ADD, attempts=2)
        return st, ok

    @jax.jit
    def count_bloom(items):
        bspec, bst = bl.bloom_create(bk, 1 << bloom_bits, kspec, k=4)
        bst, seen = bl.insert(bk, bspec, bst, items, capacity=n)
        spec, st = hm.hashmap_create(bk, 1 << table_bits, kspec,
                                     SDS((), jnp.uint32), block_size=64)
        st, ok = hm.insert(bk, spec, st, items, ones, capacity=n,
                           valid=seen, mode=MODE_ADD, attempts=2)
        return st, ok, seen

    t_plain = time_fn(count_plain, items, warmup=1, iters=3)
    t_bloom = time_fn(count_bloom, items, warmup=1, iters=3)
    results["kmer_plain"] = t_plain / n * 1e6
    results["kmer_bloom"] = t_bloom / n * 1e6

    # memory win: table occupancy with vs without the filter
    st_p, _ = count_plain(items)
    st_b, _, _ = count_bloom(items)
    occ_plain = int(hm.count_ready(bk, st_p))
    occ_bloom = int(hm.count_ready(bk, st_b))
    emit("kmer_plain", results["kmer_plain"],
         f"{n/t_plain/1e6:.2f}Mkmer/s occ={occ_plain}")
    emit("kmer_bloom", results["kmer_bloom"],
         f"{n/t_bloom/1e6:.2f}Mkmer/s occ={occ_bloom} "
         f"mem_saved={1-occ_bloom/max(occ_plain,1):.0%}")

    # --- skew arm: counting at mean-load wire capacity ---
    if skew == "zipf":
        from benchmarks.util import (bench_skew_arm, mean_load_cap,
                                     skew_retry_rounds)
        zcap = mean_load_cap(n)      # ceil: rounds x cap covers n
        rr = skew_retry_rounds([n], zcap)

        def bench_skew(rounds, tag):
            @jax.jit
            def count_skew(items):
                spec, st = hm.hashmap_create(bk, 1 << table_bits, kspec,
                                             SDS((), jnp.uint32),
                                             block_size=64)
                st, ok = hm.insert(bk, spec, st, items, ones, capacity=zcap,
                                   mode=MODE_ADD, attempts=1,
                                   max_rounds=rounds)
                return st, n - ok.sum().astype(jnp.int32)

            bench_skew_arm(count_skew, tag, rounds, n, results, items)

        bench_skew(1, "kmer_insert_skew_drop")
        bench_skew(rr, "kmer_insert_skew_retry")
    return results


if __name__ == "__main__":
    run()
