"""Paper Figure 9: HashMap operation microbenchmarks.

Variants (paper naming):
  insert          fully-atomic insert (Table 3a: 2A + W)
  insert_buffer   HashMapBuffer staged insert + flush (the 10x mechanism)
  find_atomic     fully-atomic find (Table 3c: 2A + R)
  find            phase-local find (Table 3d: R)
  find_2attempt   speculative dual-attempt find (2 collectives, not 4)

Reported as microseconds per operation (amortized over the batch) plus
the collective/bytes/rounds observables, so the paper's relative claims
(buffer >> insert; find 2-3x over find_atomic) and the fused wire
format's round reduction are directly checkable from the CSV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from benchmarks.util import emit, time_fn, trace_costs
from repro.core import ConProm, get_backend
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb

N_OPS = 1 << 14
TABLE = 1 << 17
WAVES = 8                      # fine-grained ops issue per-wave


def run(smoke: bool = False):
    n_ops = 1 << 8 if smoke else N_OPS
    table = 1 << 11 if smoke else TABLE
    bk = get_backend(None)
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.permutation(1 << 22)[:n_ops], jnp.uint32)
    vals = keys * 3 + 1
    results = {}
    obs = {}

    def fresh():
        return hm.hashmap_create(bk, table, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=64)

    def bench(tag, fn, *args):
        obs[tag] = trace_costs(fn, *args)
        results[tag] = time_fn(fn, *args) / n_ops * 1e6

    # --- insert (fully atomic), issued in WAVES batches ---
    spec, st0 = fresh()
    wave = n_ops // WAVES

    @jax.jit
    def insert_waves(st, keys, vals):
        for i in range(WAVES):
            st, _ = hm.insert(bk, spec, st, keys[i * wave:(i + 1) * wave],
                              vals[i * wave:(i + 1) * wave], capacity=wave,
                              promise=ConProm.HashMap.find_insert,
                              attempts=1)
        return st

    bench("hashmap_insert", insert_waves, st0, keys, vals)

    # --- insert through the HashMapBuffer ---
    spec, st0 = fresh()
    bspec, bst0 = hb.create(bk, spec, st0, queue_capacity=n_ops,
                            buffer_cap=n_ops)

    @jax.jit
    def insert_buffered(bst, keys, vals):
        for i in range(WAVES):
            bst, _ = hb.insert(bspec, bst, keys[i * wave:(i + 1) * wave],
                               vals[i * wave:(i + 1) * wave])
        bst, _ = hb.flush(bk, bspec, bst, capacity=n_ops)
        return bst

    bench("hashmap_insert_buffer", insert_buffered, bst0, keys, vals)

    # --- finds against a populated table ---
    spec, st = fresh()
    st, _ = hm.insert(bk, spec, st, keys, vals, capacity=n_ops)

    @jax.jit
    def find_atomic(st, keys):
        for i in range(WAVES):
            st, v, f = hm.find(bk, spec, st, keys[i * wave:(i + 1) * wave],
                               capacity=wave,
                               promise=ConProm.HashMap.find_insert,
                               attempts=1)
        return v, f

    @jax.jit
    def find_relaxed(st, keys):
        for i in range(WAVES):
            _, v, f = hm.find(bk, spec, st, keys[i * wave:(i + 1) * wave],
                              capacity=wave, promise=ConProm.HashMap.find,
                              attempts=1)
        return v, f

    @jax.jit
    def find_2attempt(st, keys):
        for i in range(WAVES):
            _, v, f = hm.find(bk, spec, st, keys[i * wave:(i + 1) * wave],
                              capacity=wave, promise=ConProm.HashMap.find,
                              attempts=2)
        return v, f

    bench("hashmap_find_atomic", find_atomic, st, keys)
    bench("hashmap_find", find_relaxed, st, keys)
    bench("hashmap_find_2attempt", find_2attempt, st, keys)

    emit("hashmap_insert", results["hashmap_insert"], "2A+W",
         cost=obs["hashmap_insert"])
    emit("hashmap_insert_buffer", results["hashmap_insert_buffer"],
         f"speedup={results['hashmap_insert'] / results['hashmap_insert_buffer']:.2f}x",
         cost=obs["hashmap_insert_buffer"])
    emit("hashmap_find_atomic", results["hashmap_find_atomic"], "2A+R",
         cost=obs["hashmap_find_atomic"])
    emit("hashmap_find", results["hashmap_find"],
         f"speedup={results['hashmap_find_atomic'] / results['hashmap_find']:.2f}x",
         cost=obs["hashmap_find"])
    emit("hashmap_find_2attempt", results["hashmap_find_2attempt"],
         "2 rounds/wave", cost=obs["hashmap_find_2attempt"])
    return results


if __name__ == "__main__":
    run()
