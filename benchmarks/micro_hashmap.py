"""Paper Figure 9: HashMap operation microbenchmarks.

Variants (paper naming):
  insert          fully-atomic insert (Table 3a: 2A + W)
  insert_buffer   HashMapBuffer staged insert + flush (the 10x mechanism)
  find_atomic     fully-atomic find (Table 3c: 2A + R)
  find            phase-local find (Table 3d: R)
  find_2attempt   speculative dual-attempt find (2 collectives, not 4)

The ``--fused`` arm adds the ExchangePlan fusion pair:
  find_insert_fused   find + insert flows sharing one plan (2 collectives)
  find_insert_fine    the Promise.FINE sequential oracle (4 collectives)

The ``--skew zipf`` arm adds the skew-tolerance pair (zipf-sized waves
at mean-load wire capacity):
  insert_skew_drop    drop-mode: overflowed inserts fail (counted)
  insert_skew_retry   carryover retry rounds: every insert lands

The ``--async`` arm adds the split-phase pair (DESIGN.md section 1.9):
  find_insert_sync    one-shot commit baseline
  find_insert_async   commit_async/finish: identical results and cost
                      columns, plus the overlap_launches observable

The ``--faults`` arm (DESIGN.md section 1.8) inserts through a
FaultInjectingTransport with a seeded corrupt spec under the integrity
checksum, re-sends the unacked inserts over a clean wire, and probes a
degraded commit; the lost_bytes / recovered / unreachable columns
report the loss, the heal, and the dead-rank mask.

The ``--wire {scatter,fused}`` arm re-runs every variant with the
send-buffer construction pinned (DESIGN.md section 1.10): ``scatter``
forces the two-pass scatter_rows fallback, ``fused`` the one-kernel
Pallas pack; rows gain the suffix and the hbm_passes column reports the
traced call's standalone scatter-op count (strictly fewer when fused,
identical bytes/collectives).

Reported as microseconds per operation (amortized over the batch) plus
the collective/bytes/rounds observables and rounds_per_op, so the
paper's relative claims (buffer >> insert; find 2-3x over find_atomic)
and the fused schedules' round reduction are directly checkable from
the CSV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from benchmarks.util import (count_hbm_passes, emit, resolve_transport,
                             resolve_wire, time_fn, trace_costs)
from repro.core import ConProm, Promise, get_backend
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb

N_OPS = 1 << 14
TABLE = 1 << 17
WAVES = 8                      # fine-grained ops issue per-wave


def run(smoke: bool = False, fused: bool = False, skew: str = "none",
        transport: str = "dense", faults: bool = False,
        async_: bool = False, wire: str = "auto"):
    tr, sfx = resolve_transport(transport)
    impl, wsfx = resolve_wire(wire)
    sfx = sfx + wsfx
    n_ops = 1 << 8 if smoke else N_OPS
    table = 1 << 11 if smoke else TABLE
    bk = get_backend(None)
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.permutation(1 << 22)[:n_ops], jnp.uint32)
    vals = keys * 3 + 1
    results = {}
    obs = {}
    passes = {}

    def fresh():
        return hm.hashmap_create(bk, table, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=64,
                                 impl=impl)

    def bench(tag, fn, *args):
        obs[tag] = trace_costs(fn, *args)
        passes[tag] = count_hbm_passes(fn, *args)
        results[tag] = time_fn(fn, *args) / n_ops * 1e6

    # --- insert (fully atomic), issued in WAVES batches ---
    spec, st0 = fresh()
    wave = n_ops // WAVES

    @jax.jit
    def insert_waves(st, keys, vals):
        for i in range(WAVES):
            st, _ = hm.insert(bk, spec, st, keys[i * wave:(i + 1) * wave],
                              vals[i * wave:(i + 1) * wave], capacity=wave,
                              promise=ConProm.HashMap.find_insert,
                              attempts=1, transport=tr)
        return st

    bench("hashmap_insert", insert_waves, st0, keys, vals)

    # --- insert through the HashMapBuffer ---
    spec, st0 = fresh()
    bspec, bst0 = hb.create(bk, spec, st0, queue_capacity=n_ops,
                            buffer_cap=n_ops)

    @jax.jit
    def insert_buffered(bst, keys, vals):
        for i in range(WAVES):
            bst, _ = hb.insert(bspec, bst, keys[i * wave:(i + 1) * wave],
                               vals[i * wave:(i + 1) * wave])
        bst, _ = hb.flush(bk, bspec, bst, capacity=n_ops, transport=tr)
        return bst

    bench("hashmap_insert_buffer", insert_buffered, bst0, keys, vals)

    # --- finds against a populated table ---
    spec, st = fresh()
    st, _ = hm.insert(bk, spec, st, keys, vals, capacity=n_ops)

    @jax.jit
    def find_atomic(st, keys):
        for i in range(WAVES):
            st, v, f = hm.find(bk, spec, st, keys[i * wave:(i + 1) * wave],
                               capacity=wave,
                               promise=ConProm.HashMap.find_insert,
                               attempts=1, transport=tr)
        return v, f

    @jax.jit
    def find_relaxed(st, keys):
        for i in range(WAVES):
            _, v, f = hm.find(bk, spec, st, keys[i * wave:(i + 1) * wave],
                              capacity=wave, promise=ConProm.HashMap.find,
                              attempts=1, transport=tr)
        return v, f

    @jax.jit
    def find_2attempt(st, keys):
        for i in range(WAVES):
            _, v, f = hm.find(bk, spec, st, keys[i * wave:(i + 1) * wave],
                              capacity=wave, promise=ConProm.HashMap.find,
                              attempts=2, transport=tr)
        return v, f

    bench("hashmap_find_atomic", find_atomic, st, keys)
    bench("hashmap_find", find_relaxed, st, keys)
    bench("hashmap_find_2attempt", find_2attempt, st, keys)

    # --- fused arm: find+insert sharing one plan vs the FINE oracle ---
    if fused:
        keys2 = jnp.asarray(rng.permutation(1 << 22)[n_ops:2 * n_ops],
                            jnp.uint32)

        def fi(promise):
            spec_f, st_f = fresh()
            st_f, _ = hm.insert(bk, spec_f, st_f, keys, vals, capacity=n_ops)

            @jax.jit
            def rounds(st, fk, ik, iv):
                for i in range(WAVES):
                    sl = slice(i * wave, (i + 1) * wave)
                    st, _, _, _ = hm.find_insert(
                        bk, spec_f, st, fk[sl], ik[sl], iv[sl],
                        capacity=wave, promise=promise, transport=tr)
                return st

            return rounds, st_f

        for tag, prom in (
                ("hashmap_find_insert_fused", ConProm.HashMap.find_insert),
                ("hashmap_find_insert_fine",
                 ConProm.HashMap.find_insert | Promise.FINE)):
            fn, st_f = fi(prom)
            obs[tag] = trace_costs(fn, st_f, keys, keys2, keys2 * 5 + 1)
            # 2 ops (one find + one insert) per wave item
            results[tag] = time_fn(fn, st_f, keys, keys2, keys2 * 5 + 1) \
                / (2 * n_ops) * 1e6

    # --- async arm: split-phase find_insert (DESIGN.md section 1.9) ---
    if async_:
        keys3 = jnp.asarray(rng.permutation(1 << 22)[2 * n_ops:3 * n_ops],
                            jnp.uint32)

        def fia(split, tag):
            spec_a, st_a = fresh()
            st_a, _ = hm.insert(bk, spec_a, st_a, keys, vals, capacity=n_ops)

            @jax.jit
            def rounds(st, fk, ik, iv):
                for i in range(WAVES):
                    sl = slice(i * wave, (i + 1) * wave)
                    if split:
                        pend = hm.find_insert(
                            bk, spec_a, st, fk[sl], ik[sl], iv[sl],
                            capacity=wave,
                            promise=ConProm.HashMap.find_insert,
                            transport=tr, async_=True)
                        st, _, _, _ = pend.finish()
                    else:
                        st, _, _, _ = hm.find_insert(
                            bk, spec_a, st, fk[sl], ik[sl], iv[sl],
                            capacity=wave,
                            promise=ConProm.HashMap.find_insert,
                            transport=tr)
                return st

            obs[tag] = trace_costs(rounds, st_a, keys, keys3, keys3 * 5 + 1)
            results[tag] = time_fn(rounds, st_a, keys, keys3, keys3 * 5 + 1) \
                / (2 * n_ops) * 1e6

        fia(False, "hashmap_find_insert_sync")
        fia(True, "hashmap_find_insert_async")

    # --- skew arm: mean-load capacity, drop-mode vs carryover retries ---
    if skew == "zipf":
        from benchmarks.util import (bench_skew_arm, mean_load_cap,
                                     skew_retry_rounds, zipf_wave_mask)
        zcap = mean_load_cap(wave)
        zvalid = zipf_wave_mask(WAVES, wave, n_ops)
        n_skew = int(zvalid.sum())     # actual ops (hot waves saturate)
        # observed trajectory: each wave's hot-block load; suggest_rounds
        # picks R off the peak (ROADMAP adaptive rounds)
        rr = skew_retry_rounds(
            [int(x) for x in np.asarray(zvalid.sum(axis=1))], zcap)

        def bench_skew(rounds, tag):
            spec_s, st_s = fresh()

            @jax.jit
            def inserts(st, keys, vals):
                okn = jnp.int32(0)
                nval = jnp.int32(0)
                for i in range(WAVES):
                    sl = slice(i * wave, (i + 1) * wave)
                    st, ok = hm.insert(bk, spec_s, st, keys[sl], vals[sl],
                                       capacity=zcap, valid=zvalid[i],
                                       attempts=1, max_rounds=rounds,
                                       transport=tr)
                    okn = okn + ok.sum().astype(jnp.int32)
                    nval = nval + zvalid[i].sum().astype(jnp.int32)
                return st, nval - okn       # failed == dropped-on-wire

            bench_skew_arm(inserts, tag, rounds, n_skew, results,
                           st_s, keys, vals,
                           derived="zipf waves @ mean-load capacity")

        bench_skew(1, "hashmap_insert_skew_drop" + sfx)
        bench_skew(rr, "hashmap_insert_skew_retry" + sfx)

    # --- faults arm: seeded corruption healed by integrity + re-send ---
    if faults:
        from repro.core import FaultInjectingTransport, FaultSpec, costs
        fspec = FaultSpec(seed=7, corrupt=((0, 0, 0),))
        ftr = FaultInjectingTransport(tr, fspec)
        spec_f, st_f = fresh()

        @jax.jit
        def faulty_insert(st, keys, vals):
            # first shot over the faulty fabric: checksum-failed arrivals
            # never ack, so their inserts come back unsuccessful
            st, ok1 = hm.insert(bk, spec_f, st, keys, vals,
                                capacity=n_ops, attempts=1, transport=ftr,
                                integrity=True)
            lost = (~ok1).sum().astype(jnp.int32)
            # heal: re-send exactly the unacked inserts over a clean wire
            st, ok2 = hm.insert(bk, spec_f, st, keys, vals,
                                capacity=n_ops, valid=~ok1, attempts=1,
                                transport=tr, integrity=True)
            return st, lost, ok2.sum().astype(jnp.int32)

        with costs.recording() as flog:
            out = faulty_insert(st_f, keys, vals)
            # degraded-commit probe: rank 0 declared dead at admission
            hm.insert(bk, spec_f, out[0], keys[:8], vals[:8], capacity=8,
                      attempts=1, dead_ranks=(0,))
            jax.block_until_ready(out)
        lost_items = int(out[1])
        row_bytes = 4 * (1 + spec_f.key_packer.lanes
                         + spec_f.val_packer.lanes + 1)  # body + meta lane
        t = time_fn(faulty_insert, st_f, keys, vals, warmup=1, iters=3)
        emit("hashmap_insert_faults" + sfx, t / n_ops * 1e6,
             "seeded corrupt + clean re-send + degraded probe",
             cost=flog.total(), n_ops=n_ops,
             lost_bytes=lost_items * row_bytes, recovered=int(out[2]),
             unreachable=int(flog.total().unreachable))

    emit("hashmap_insert" + sfx, results["hashmap_insert"], "2A+W",
         cost=obs["hashmap_insert"], n_ops=n_ops,
         hbm_passes=passes["hashmap_insert"])
    emit("hashmap_insert_buffer" + sfx, results["hashmap_insert_buffer"],
         f"speedup={results['hashmap_insert'] / results['hashmap_insert_buffer']:.2f}x",
         cost=obs["hashmap_insert_buffer"], n_ops=n_ops,
         hbm_passes=passes["hashmap_insert_buffer"])
    emit("hashmap_find_atomic" + sfx, results["hashmap_find_atomic"], "2A+R",
         cost=obs["hashmap_find_atomic"], n_ops=n_ops,
         hbm_passes=passes["hashmap_find_atomic"])
    emit("hashmap_find" + sfx, results["hashmap_find"],
         f"speedup={results['hashmap_find_atomic'] / results['hashmap_find']:.2f}x",
         cost=obs["hashmap_find"], n_ops=n_ops,
         hbm_passes=passes["hashmap_find"])
    emit("hashmap_find_2attempt" + sfx, results["hashmap_find_2attempt"],
         "2 rounds/wave", cost=obs["hashmap_find_2attempt"], n_ops=n_ops,
         hbm_passes=passes["hashmap_find_2attempt"])
    if fused:
        emit("hashmap_find_insert_fused" + sfx, results["hashmap_find_insert_fused"],
             "2 collectives/round-trip",
             cost=obs["hashmap_find_insert_fused"], n_ops=2 * n_ops)
        emit("hashmap_find_insert_fine" + sfx, results["hashmap_find_insert_fine"],
             "FINE oracle: 4 collectives",
             cost=obs["hashmap_find_insert_fine"], n_ops=2 * n_ops)
    if async_:
        emit("hashmap_find_insert_sync" + sfx,
             results["hashmap_find_insert_sync"], "one-shot commit",
             cost=obs["hashmap_find_insert_sync"], n_ops=2 * n_ops)
        emit("hashmap_find_insert_async" + sfx,
             results["hashmap_find_insert_async"],
             "split-phase commit_async/finish",
             cost=obs["hashmap_find_insert_async"], n_ops=2 * n_ops)
    return results


if __name__ == "__main__":
    run()
