"""Paper Figure 9: HashMap operation microbenchmarks.

Variants (paper naming):
  insert          fully-atomic insert (Table 3a: 2A + W)
  insert_buffer   HashMapBuffer staged insert + flush (the 10x mechanism)
  find_atomic     fully-atomic find (Table 3c: 2A + R)
  find            phase-local find (Table 3d: R)

Reported as microseconds per operation (amortized over the batch) plus
the collective/bytes observables, so the paper's relative claims
(buffer >> insert; find 2-3x over find_atomic) are directly checkable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from benchmarks.util import emit, time_fn
from repro.core import ConProm, costs, get_backend
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb

N_OPS = 1 << 14
TABLE = 1 << 17
WAVES = 8                      # fine-grained ops issue per-wave


def run():
    bk = get_backend(None)
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.permutation(1 << 22)[:N_OPS], jnp.uint32)
    vals = keys * 3 + 1
    results = {}

    def fresh():
        return hm.hashmap_create(bk, TABLE, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=64)

    # --- insert (fully atomic), issued in WAVES batches ---
    spec, st0 = fresh()
    wave = N_OPS // WAVES

    @jax.jit
    def insert_waves(st, keys, vals):
        for i in range(WAVES):
            st, _ = hm.insert(bk, spec, st, keys[i * wave:(i + 1) * wave],
                              vals[i * wave:(i + 1) * wave], capacity=wave,
                              promise=ConProm.HashMap.find_insert,
                              attempts=1)
        return st

    t = time_fn(insert_waves, st0, keys, vals)
    results["hashmap_insert"] = t / N_OPS * 1e6

    # --- insert through the HashMapBuffer ---
    spec, st0 = fresh()
    bspec, bst0 = hb.create(bk, spec, st0, queue_capacity=N_OPS,
                            buffer_cap=N_OPS)

    @jax.jit
    def insert_buffered(bst, keys, vals):
        for i in range(WAVES):
            bst, _ = hb.insert(bspec, bst, keys[i * wave:(i + 1) * wave],
                               vals[i * wave:(i + 1) * wave])
        bst, _ = hb.flush(bk, bspec, bst, capacity=N_OPS)
        return bst

    t = time_fn(insert_buffered, bst0, keys, vals)
    results["hashmap_insert_buffer"] = t / N_OPS * 1e6

    # --- finds against a populated table ---
    spec, st = fresh()
    st, _ = hm.insert(bk, spec, st, keys, vals, capacity=N_OPS)

    @jax.jit
    def find_atomic(st, keys):
        for i in range(WAVES):
            st, v, f = hm.find(bk, spec, st, keys[i * wave:(i + 1) * wave],
                               capacity=wave,
                               promise=ConProm.HashMap.find_insert,
                               attempts=1)
        return v, f

    @jax.jit
    def find_relaxed(st, keys):
        for i in range(WAVES):
            _, v, f = hm.find(bk, spec, st, keys[i * wave:(i + 1) * wave],
                              capacity=wave, promise=ConProm.HashMap.find,
                              attempts=1)
        return v, f

    results["hashmap_find_atomic"] = time_fn(find_atomic, st, keys) \
        / N_OPS * 1e6
    results["hashmap_find"] = time_fn(find_relaxed, st, keys) / N_OPS * 1e6

    emit("hashmap_insert", results["hashmap_insert"], "2A+W")
    emit("hashmap_insert_buffer", results["hashmap_insert_buffer"],
         f"speedup={results['hashmap_insert'] / results['hashmap_insert_buffer']:.2f}x")
    emit("hashmap_find_atomic", results["hashmap_find_atomic"], "2A+R")
    emit("hashmap_find", results["hashmap_find"],
         f"speedup={results['hashmap_find_atomic'] / results['hashmap_find']:.2f}x")
    return results


if __name__ == "__main__":
    run()
