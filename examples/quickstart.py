"""Quickstart: the BCL containers in ten minutes.

Run: PYTHONPATH=src python examples/quickstart.py

Shows the paper's core abstractions end to end on one device (the same
code runs unchanged inside jax.shard_map on a real mesh — see
tests/spmd_check.py for the 8-device version of each snippet).
"""

import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from repro.core import ConProm, costs, get_backend
from repro.containers import bloom as bl
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb
from repro.containers import queue as q

backend = get_backend(None)   # serial; get_backend("axis") inside shard_map

# ---------------------------------------------------------------- HashMap
print("== BCL::HashMap ==")
spec, table = hm.hashmap_create(backend, capacity=4096,
                                key_spec=SDS((), jnp.uint32),
                                val_spec=SDS((), jnp.uint32))
keys = jnp.arange(100, dtype=jnp.uint32)
vals = keys * keys
with costs.recording() as log:
    table, ok = hm.insert(backend, spec, table, keys, vals, capacity=128)
print(f"inserted {int(ok.sum())} pairs, cost per op: "
      f"{log.by_op('hashmap.insert').formula()}")

table, found_vals, found = hm.find(backend, spec, table, keys, capacity=128,
                                   promise=ConProm.HashMap.find)
print(f"found {int(found.sum())}, 7^2 = {int(found_vals[7])}")

# ------------------------------------------------------- HashMapBuffer
print("\n== BCL::HashMapBuffer (paper Fig. 4) ==")
bspec, buf = hb.create(backend, spec, table, queue_capacity=1024,
                       buffer_cap=512)
buf, _ = hb.insert(bspec, buf, keys + 1000, vals + 1)   # local staging only
buf, dropped = hb.flush(backend, bspec, buf, capacity=512)
_, v, f = hm.find(backend, spec, buf.map,
                  jnp.asarray([1007], jnp.uint32), capacity=4,
                  promise=ConProm.HashMap.find)
print(f"flushed with {int(dropped)} drops; buffered key 1007 -> {int(v[0])}")

# ---------------------------------------------------------------- Queues
print("\n== BCL::FastQueue ==")
qspec, ring = q.queue_create(backend, capacity=256,
                             value_spec=SDS((), jnp.uint32))
ring, pushed, _ = q.push(backend, qspec, ring,
                         jnp.arange(10, dtype=jnp.uint32),
                         jnp.zeros(10, jnp.int32), capacity=16)
ring, popped, got = q.local_nonatomic_pop(qspec, ring, 5)
print(f"pushed {int(pushed)}, popped {np.asarray(popped)[np.asarray(got)]}")

# ----------------------------------------------------------- BloomFilter
print("\n== BCL::BloomFilter (blocked, atomic insert) ==")
fspec, filt = bl.bloom_create(backend, nbits=1 << 16,
                              value_spec=SDS((), jnp.uint32), k=4)
items = jnp.asarray([3, 3, 3, 5, 7], jnp.uint32)
filt, already = bl.insert(backend, fspec, filt, items, capacity=8)
print(f"insert [3,3,3,5,7]: already_present={np.asarray(already)} "
      "(exactly one 3 was 'new' — the paper's atomicity invariant)")
present = bl.find(backend, fspec, filt, jnp.asarray([3, 4], jnp.uint32),
                  capacity=4)
print(f"find [3,4] -> {np.asarray(present)}")
print("\nquickstart OK")
