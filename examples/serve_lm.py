"""Serving example: batched prefill + decode with slot reuse.

Run: PYTHONPATH=src python examples/serve_lm.py

Thin wrapper over launch/serve.py with a reduced qwen3 config — shows
the public serving API (prefill -> iterated decode_step with a typed,
sharded KV cache).
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(["--arch", "qwen3-4b", "--reduced",
                   "--requests", "8", "--batch", "4",
                   "--prompt-len", "24", "--gen", "12"]))
