"""ISx bucket sort — the paper's Figure 3 program, JAX edition.

Run: PYTHONPATH=src python examples/isx_sort.py [n_keys]

The structure matches the paper's 72-line C++ exactly: one queue per
rank, local buffers per destination, aggregated pushes once a buffer
reaches message_size, barrier, local sort.
"""

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from repro.core import get_backend
from repro.containers import queue as q

N = int(sys.argv[1]) if len(sys.argv) > 1 else 1 << 16
MESSAGE_SIZE = 4096
KEY_SPACE = 1 << 28


def sort(keys: jnp.ndarray):
    backend = get_backend(None)      # or get_backend("ranks") in shard_map
    nprocs = backend.nprocs()
    spec, queue = q.queue_create(backend, 2 * N, SDS((), jnp.uint32))

    # distribution stage: push each key to its bucket's queue, aggregated
    # into MESSAGE_SIZE chunks (the pushes overlap with binning on TPU)
    bucket_width = KEY_SPACE // nprocs
    for i in range(0, N, MESSAGE_SIZE):
        chunk = keys[i:i + MESSAGE_SIZE]
        dest = (chunk // bucket_width).astype(jnp.int32).clip(0, nprocs - 1)
        queue, _, dropped = q.push(backend, spec, queue, chunk, dest,
                                   capacity=MESSAGE_SIZE)
    backend.barrier()

    # local sort stage (invalid slots sort to the end; sliced off outside)
    rows, got = q.local_drain(spec, queue)
    return jnp.sort(jnp.where(got, rows, jnp.uint32(0xFFFFFFFF))), got.sum()


def main():
    keys = jnp.asarray(
        np.random.default_rng(0).integers(0, KEY_SPACE, N), jnp.uint32)
    jitted = jax.jit(sort)
    out, count = jitted(keys)               # compile
    t0 = time.perf_counter()
    out, count = jax.block_until_ready(jitted(keys))
    dt = time.perf_counter() - t0
    out = np.asarray(out)[: int(count)]
    assert np.array_equal(out, np.sort(np.asarray(keys)))
    print(f"sorted {N} keys in {dt*1e3:.1f} ms "
          f"({N/dt/1e6:.2f} Mkeys/s) — verified")


if __name__ == "__main__":
    main()
