"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300] [--tiny]

Uses the full production stack — sharded init, jit train_step with
donated state, deterministic restartable data stream, async atomic
checkpointing, FT heartbeats — on a ~108M-param StableLM-family config
(d_model=768, 12 layers, vocab 32768).  ``--tiny`` shrinks it for quick
CI-style verification.
"""

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import init_state, make_train_step


def config_100m(tiny: bool):
    base = get_config("stablelm-1.6b")
    if tiny:
        return dataclasses.replace(
            base, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
            d_ff=512, vocab=2048, head_dim=32, dtype="float32",
            tie_embeddings=True)
    return dataclasses.replace(
        base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=2048, vocab=32768, head_dim=64, dtype="float32",
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = config_100m(args.tiny)
    mesh = make_test_mesh(1, 1)
    rng = jax.random.PRNGKey(0)
    params, opt, _, _ = init_state(cfg, mesh, rng)
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree_util.tree_leaves(params))
    print(f"model: {n_params/1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab})")

    step_fn = jax.jit(make_train_step(cfg, mesh), donate_argnums=(0, 1))
    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    ckpt = CheckpointManager(args.ckpt_dir, save_interval=100)

    losses, t0 = [], time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
        ckpt.maybe_save(step + 1, (params, opt, stream.state_dict()))
        if step % 20 == 0:
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d} loss {losses[-1]:7.4f} "
                  f"({tok_s/1e3:.1f} ktok/s)")
    ckpt.wait()
    print(f"loss: {np.mean(losses[:10]):.4f} -> {np.mean(losses[-10:]):.4f}")
    ok = np.mean(losses[-10:]) < np.mean(losses[:10])
    print("TRAINING", "IMPROVED" if ok else "DID NOT IMPROVE")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
