"""Meraculous-style genome assembly: k-mer counting + contig generation.

Run: PYTHONPATH=src python examples/genome_assembly.py

Pipeline (paper section 9.2):
  1. simulate a genome + error-prone reads
  2. count k-mers with the Bloom-filter pre-pass (singletons — mostly
     sequencing errors — never enter the hash table)
  3. keep solid k-mers (count >= 2), build the de Bruijn table
     k-mer -> next-base through a HashMapBuffer
  4. walk contigs with phase-local finds (ConProm find-only)
"""

import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from repro.core import ConProm, get_backend
from repro.containers import bloom as bl
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb
from repro.data.genomics import (GenomeSim, extract_kmers, kmer_neighbors,
                                 pack_kmers)
from repro.kernels.ops import MODE_ADD, MODE_KEEP

K = 17
BASES = "ACGT"


def main():
    backend = get_backend(None)
    sim = GenomeSim(genome_len=1 << 12, coverage=12, error_rate=0.005,
                    seed=7)
    reads = sim.reads()
    print(f"genome {sim.genome_len}bp, {reads.shape[0]} reads of "
          f"{sim.read_len}bp, {sim.error_rate:.1%} error rate")

    # ---- stage 1: k-mer counting with Bloom pre-pass ----
    kmers = pack_kmers(extract_kmers(reads, K))
    n = kmers.shape[0]
    kspec = {"hi": SDS((), jnp.uint32), "lo": SDS((), jnp.uint32)}
    items = {"hi": jnp.asarray(kmers[:, 0]), "lo": jnp.asarray(kmers[:, 1])}

    bspec, filt = bl.bloom_create(backend, 1 << 22, kspec, k=4)
    filt, seen_before = bl.insert(backend, bspec, filt, items, capacity=n)

    cspec, counts = hm.hashmap_create(backend, 1 << 17, kspec,
                                      SDS((), jnp.uint32), block_size=64)
    counts, _ = hm.insert(backend, cspec, counts, items,
                          jnp.ones(n, jnp.uint32), capacity=n,
                          valid=seen_before, mode=MODE_ADD, attempts=3)
    stored = int(hm.count_ready(backend, counts))
    print(f"{n} k-mers, {stored} entered the table "
          f"(Bloom filtered {1 - stored / n:.0%} as probable singletons)")

    # ---- stage 2: solid extensions -> de Bruijn table (buffered build) ----
    # like the paper's pipeline, only extensions observed >=2 times enter
    # the graph (single-occurrence (k+1)-mers are presumed read errors)
    uniq, cnt = np.unique(kmers, axis=0, return_counts=True)
    solid = cnt >= 3
    flat = extract_kmers(reads, K + 1)       # (k+1)-mers give extensions
    e_uniq, e_cnt = np.unique(flat, axis=0, return_counts=True)
    e_solid = e_uniq[e_cnt >= 2]
    ext = pack_kmers(e_solid[:, :K])
    nxt = e_solid[:, K].astype(np.uint32)

    dspec, table = hm.hashmap_create(backend, 1 << 17, kspec,
                                     SDS((), jnp.uint32), block_size=64)
    bufspec, buf = hb.create(backend, dspec, table,
                             queue_capacity=2 * len(ext),
                             buffer_cap=2 * len(ext))
    buf, _ = hb.insert(bufspec, buf,
                       {"hi": jnp.asarray(ext[:, 0]),
                        "lo": jnp.asarray(ext[:, 1])},
                       jnp.asarray(nxt))
    buf, dropped = hb.flush(backend, bufspec, buf,
                            capacity=2 * len(ext))
    table = buf.map
    print(f"de Bruijn table: {len(ext)} solid extensions via "
          f"HashMapBuffer ({int(dropped)} drops)")

    # ---- stage 3: contig walk (find-only phase) ----
    start = uniq[solid][0]
    contig = []
    cur = start
    for _ in range(2000):
        probe = {"hi": jnp.asarray([cur[0]]), "lo": jnp.asarray([cur[1]])}
        table, v, found = hm.find(backend, dspec, table, probe, capacity=4,
                                  promise=ConProm.HashMap.find, attempts=3)
        if not bool(found[0]):
            break
        b = int(v[0]) & 3
        contig.append(b)
        cur = np.asarray(kmer_neighbors(cur[None], K)[b][0])
    genome = sim.genome()
    contig_str = "".join(BASES[b] for b in contig[:60])
    print(f"walked a contig of {len(contig)} bases: {contig_str}...")

    # verify the contig appears in the true genome
    gs = "".join(BASES[b] for b in genome)
    ok = contig_str in gs
    print(f"contig matches reference genome: {ok}")


if __name__ == "__main__":
    main()
