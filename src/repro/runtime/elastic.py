"""Elastic scaling: recompute the mesh after node loss/gain.

Policy: keep the 'model' axis intact (TP/EP layouts are weight-resident
and expensive to reshape) and shrink/grow the data axes — drop whole
data rows so the remaining device grid stays rectangular.  The data
stream is a pure function of (seed, step, shard), so rebalancing shards
is just renumbering; the checkpoint restores onto the new mesh
(checkpoint/ckpt.py resharding path).
"""

from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    dropped_devices: int
    batch_per_shard_scale: float   # growth factor of per-shard batch


def plan_remesh(axis_names: tuple[str, ...], old_shape: tuple[int, ...],
                available_devices: int) -> ElasticPlan:
    """Largest rectangular mesh with the model axis preserved."""
    names = list(axis_names)
    shape = list(old_shape)
    model_idx = names.index("model") if "model" in names else len(names) - 1
    model = shape[model_idx]
    if available_devices < model:
        raise ValueError("cannot preserve the model axis: "
                         f"{available_devices} < model={model}")
    data_total = 1
    for i, s in enumerate(shape):
        if i != model_idx:
            data_total *= s
    new_data_total = available_devices // model
    # fold into the existing data axes, last axis absorbs the remainder
    new_shape = list(shape)
    remaining = new_data_total
    for i in range(len(shape)):
        if i == model_idx:
            continue
        new_shape[i] = min(shape[i], remaining)
        while new_shape[i] > 1 and remaining % new_shape[i]:
            new_shape[i] -= 1
        remaining //= max(new_shape[i], 1)
    # put any leftover factor on the first data axis
    used = 1
    for i, s in enumerate(new_shape):
        if i != model_idx:
            used *= s
    first_data = next(i for i in range(len(shape)) if i != model_idx)
    new_shape[first_data] *= max(new_data_total // used, 1)

    return ElasticPlan(tuple(old_shape), tuple(new_shape),
                       tuple(axis_names),
                       dropped_devices=available_devices -
                       model * new_data_total,
                       batch_per_shard_scale=data_total / new_data_total)


def make_elastic_mesh(plan: ElasticPlan):
    from repro.compat import make_mesh
    return make_mesh(plan.new_shape, plan.axis_names)
