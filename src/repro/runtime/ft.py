"""Fault-tolerance control plane: heartbeats, stragglers, failover.

At 1000+ nodes, MTBF drops below job length; the framework must treat
node failure as routine.  The control plane here is a set of pure state
machines (simulation-testable on one host, drivable by a real heartbeat
transport on a cluster):

  NodeState / FaultToleranceManager
      heartbeat bookkeeping, failure declaration after ``timeout``
      missed beats, restart-from-checkpoint decision, spare promotion.

  StragglerDetector
      per-node step-time EWMA; z-score against fleet median flags
      stragglers; mitigation hooks (data rebalance / hot spare swap).

Recovery contract with the rest of the stack:
  * checkpoint/ckpt.py restores on ANY surviving device set (elastic);
  * data/tokens.py streams are pure functions of (seed, step, shard) so
    a restarted or re-sharded job replays the exact global batches;
  * runtime/elastic.py computes the new mesh + shard mapping.

The train driver (launch/train.py) wires these together; tests inject
synthetic failures and assert the manager's decisions.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Callable


class NodeHealth(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    FAILED = "failed"
    SPARE = "spare"


@dataclasses.dataclass
class NodeState:
    node_id: int
    health: NodeHealth = NodeHealth.HEALTHY
    last_heartbeat: float = 0.0
    step_time_ewma: float = 0.0
    missed: int = 0


@dataclasses.dataclass
class FTDecision:
    action: str                    # "none" | "restart" | "rebalance"
    failed_nodes: list[int]
    promoted_spares: list[int]
    restart_step: int | None = None


class FaultToleranceManager:
    """Declares failures and plans recovery. Pure bookkeeping — the
    caller supplies time and the checkpoint step."""

    def __init__(self, n_nodes: int, n_spares: int = 0,
                 heartbeat_interval: float = 10.0, timeout_beats: int = 3):
        self.nodes = {i: NodeState(i) for i in range(n_nodes)}
        for i in range(n_nodes - n_spares, n_nodes):
            self.nodes[i].health = NodeHealth.SPARE
        self.interval = heartbeat_interval
        self.timeout_beats = timeout_beats

    def heartbeat(self, node_id: int, now: float) -> None:
        st = self.nodes[node_id]
        st.last_heartbeat = now
        st.missed = 0
        if st.health == NodeHealth.SUSPECT:
            st.health = NodeHealth.HEALTHY

    def tick(self, now: float, last_ckpt_step: int) -> FTDecision:
        """Advance the failure detector; returns the recovery decision."""
        newly_failed = []
        for st in self.nodes.values():
            if st.health in (NodeHealth.FAILED, NodeHealth.SPARE):
                continue
            gap = now - st.last_heartbeat
            st.missed = int(gap // self.interval)
            if st.missed >= self.timeout_beats:
                st.health = NodeHealth.FAILED
                newly_failed.append(st.node_id)
            elif st.missed >= 1:
                st.health = NodeHealth.SUSPECT

        if not newly_failed:
            return FTDecision("none", [], [])

        promoted = []
        for nid in newly_failed:
            spare = next((s for s in self.nodes.values()
                          if s.health == NodeHealth.SPARE), None)
            if spare is not None:
                spare.health = NodeHealth.HEALTHY
                # a spare has never heartbeated; without a fresh stamp the
                # very next tick would see gap = now - 0 and re-fail it
                spare.last_heartbeat = now
                spare.missed = 0
                promoted.append(spare.node_id)
        # any failure => deterministic restart from the last checkpoint;
        # with spares the world size is unchanged, otherwise elastic.
        return FTDecision("restart", newly_failed, promoted,
                          restart_step=last_ckpt_step)

    def healthy_nodes(self) -> list[int]:
        return [i for i, s in self.nodes.items()
                if s.health == NodeHealth.HEALTHY]


class StragglerDetector:
    """Flags nodes whose step time drifts above the fleet (EWMA + MAD)."""

    def __init__(self, n_nodes: int, alpha: float = 0.2,
                 threshold: float = 2.0):
        self.ewma = [0.0] * n_nodes
        self.alpha = alpha
        self.threshold = threshold

    def observe(self, node_id: int, step_time: float) -> None:
        prev = self.ewma[node_id]
        self.ewma[node_id] = (step_time if prev == 0.0 else
                              (1 - self.alpha) * prev +
                              self.alpha * step_time)

    def stragglers(self) -> list[int]:
        vals = sorted(v for v in self.ewma if v > 0)
        if len(vals) < 3:
            return []
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2]
        sigma = max(1.4826 * mad, 1e-2 * med, 1e-12)
        return [i for i, v in enumerate(self.ewma)
                if v > 0 and (v - med) / sigma > self.threshold]

    def mitigation(self, node_id: int) -> str:
        """Policy: first rebalance input shards away; persistently slow
        nodes get swapped with a spare at the next checkpoint."""
        return ("swap_at_checkpoint"
                if self.ewma[node_id] > 0 and self._persistent(node_id)
                else "rebalance_data")

    def _persistent(self, node_id: int) -> bool:
        vals = sorted(v for v in self.ewma if v > 0)
        if not vals:
            return False     # cold start: no observations, nothing is slow
        med = vals[len(vals) // 2]
        return self.ewma[node_id] > 1.5 * med
