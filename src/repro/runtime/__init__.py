from repro.runtime.ft import FaultToleranceManager, NodeState, StragglerDetector
from repro.runtime.elastic import ElasticPlan, plan_remesh

__all__ = ["FaultToleranceManager", "NodeState", "StragglerDetector",
           "ElasticPlan", "plan_remesh"]
