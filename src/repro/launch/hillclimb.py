import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver (EXPERIMENTS.md section Perf).

Runs a named (arch, shape) cell with a sequence of config overrides,
re-lowering + re-analyzing after each change, and emits the
hypothesis -> change -> before/after log as JSON.

  PYTHONPATH=src python -m repro.launch.hillclimb deepseek_train
"""

import dataclasses
import json
import sys

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh


# Each plan: (cell_name, arch, shape, [(change_name, hypothesis, overrides)])
# Overrides are CUMULATIVE: each step keeps the previous ones unless
# explicitly reverted (refuted hypotheses pass revert=True).
PLANS = {
    "deepseek_train": (
        "deepseek-v3-671b", "train_4k", [
            ("bf16_exchange",
             "dispatch/combine payloads are f32; bf16 packing halves both "
             "the all-to-all wire bytes and the route-buffer HBM traffic "
             "of the dominant memory term (expect ~2x on exchange bytes, "
             "memory term -15-30%)",
             {"moe_payload_dtype": "bfloat16"}),
            ("tight_capacity",
             "exchange slot slack 1.3 pads every (src,dst) bucket; 1.15 "
             "cuts route buffers + binned expert batch ~12% with the same "
             "drop risk profile at init-time routing entropy",
             {"moe_capacity_slack": 1.15}),
            ("grad_accum8",
             "237GiB/dev live is activation-dominated; 8 microbatches cut "
             "live activations ~8x toward the 16GiB budget; memory TERM "
             "(traffic) should stay ~flat (weights re-read 8x is only "
             "~40GB/chip)",
             {"grad_accum": 8}),
            ("remat_nothing",
             "default checkpoint policy saves block inputs; "
             "nothing_saveable recomputes everything, trading ~17% more "
             "compute for another big live-bytes cut",
             {"remat_policy": "nothing"}),
            ("bf16_attn_probs",
             "attention probability matrices (B,128H,qb,kb) are the "
             "largest f32 operands left in the memory term; casting the "
             "PV matmul to bf16 (f32 accumulate) halves those bytes "
             "(expect memory term -5-15%, no accuracy loss at f32 "
             "normalizer)",
             {"attn_probs_bf16": True}),
        ]),
    "deepseek_decode": (
        "deepseek-v3-671b", "decode_32k", [
            ("mla_absorb",
             "naive MLA decode re-expands K/V for all 32k cached "
             "positions each step: ~2*B*S*r*H*(nope+v) flops and the "
             "matching HBM traffic; latent-space absorption cuts compute "
             "~100x and memory term several-fold (useful ratio 0.00 -> "
             "O(0.01), both terms collapse toward the cache-read floor)",
             {"mla_absorb": True}),
            ("bf16_exchange",
             "after absorption the MoE dispatch buffers are a larger "
             "share of remaining traffic; bf16 halves them",
             {"moe_payload_dtype": "bfloat16"}),
        ]),
    "arctic_train": (
        "arctic-480b", "train_4k", [
            ("bf16_exchange",
             "the collective term is all-to-all dispatch payloads (f32 "
             "lanes x top-2 x 35 layers x fwd+bwd); bf16 packing halves "
             "wire bytes -> collective term ~ -45%",
             {"moe_payload_dtype": "bfloat16"}),
            ("tight_capacity",
             "slack 1.5 -> 1.15: route buffers and expert padding shrink "
             "~23%; collective AND memory terms drop proportionally",
             {"moe_capacity_slack": 1.15}),
            ("grad_accum4",
             "44.5GiB/dev live -> ~4x cut from microbatching; terms flat",
             {"grad_accum": 4}),
        ]),
}


def run_plan(name: str, out_path: str | None = None):
    arch, shape_name, steps = PLANS[name]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=False)
    log = []

    cfg = get_config(arch)
    print(f"[baseline] {arch} x {shape_name}")
    base = lower_cell(cfg, shape, mesh, verbose=True)
    base["change"] = "baseline (paper-faithful)"
    log.append(base)

    overrides = {}
    for change, hypothesis, delta in steps:
        overrides.update(delta)
        cfg_i = dataclasses.replace(get_config(arch), **overrides)
        print(f"\n[change] {change}: {delta}")
        print(f"  hypothesis: {hypothesis}")
        rec = lower_cell(cfg_i, shape, mesh, verbose=True)
        rec["change"] = change
        rec["hypothesis"] = hypothesis
        rec["overrides"] = dict(overrides)
        prev = log[-1]["roofline"]
        cur = rec["roofline"]
        for term in ("compute_s", "memory_s", "collective_s"):
            d = (cur[term] - prev[term]) / max(prev[term], 1e-12)
            print(f"  {term}: {prev[term]:.4f} -> {cur[term]:.4f} "
                  f"({d:+.1%})")
        print(f"  live: {log[-1]['per_device_live_bytes']/2**30:.1f} -> "
              f"{rec['per_device_live_bytes']/2**30:.1f} GiB")
        log.append(rec)

    if out_path:
        with open(out_path, "w") as f:
            json.dump(log, f, indent=1)
    return log


if __name__ == "__main__":
    name = sys.argv[1]
    out = sys.argv[2] if len(sys.argv) > 2 else f"hillclimb_{name}.json"
    run_plan(name, out)
