"""End-to-end training driver.

Wires the whole stack: config -> mesh -> sharded init -> jit train_step
-> deterministic data stream -> checkpoint manager (async, atomic,
retained) -> fault-tolerance hooks (heartbeats + straggler EWMA; on this
single-host container the heartbeat source is simulated, the decision
logic is the production state machine).

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck

``--kill-at N`` injects a failure at step N and demonstrates
restart-from-checkpoint continuing to the target step with identical
data order (the FT guarantee).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step
from repro.configs import get_config, reduced
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import (batch_shardings, init_state, make_train_step,
                                train_shardings)
from repro.runtime.elastic import plan_remesh
from repro.runtime.ft import FaultToleranceManager, StragglerDetector


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--async-dispatch", action="store_true",
                    help="split-phase MoE dispatch: issue the exchange "
                         "wire, overlap the always-on paths, then finish "
                         "(DESIGN.md section 1.9)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.async_dispatch:
        cfg = dataclasses.replace(cfg, moe_async_dispatch=True)

    n_dev = len(jax.devices())
    model_par = 1
    data_par = n_dev // model_par
    mesh = make_test_mesh(data=data_par, model=model_par)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    rng = jax.random.PRNGKey(args.seed)
    params, opt, psh, osh = init_state(cfg, mesh, rng)
    step_fn = jax.jit(make_train_step(cfg, mesh),
                      donate_argnums=(0, 1))

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir, save_interval=args.ckpt_every) \
        if args.ckpt_dir else None
    ft = FaultToleranceManager(n_nodes=max(n_dev, 1))
    strag = StragglerDetector(n_nodes=max(n_dev, 1))

    start_step = 0
    if ckpt and latest_step(args.ckpt_dir) is not None:
        (params, opt, stream_state), start_step = ckpt.restore_latest(
            (params, opt, stream.state_dict()))
        stream.load_state_dict(jax.tree_util.tree_map(int, stream_state))
        print(f"restored checkpoint at step {start_step}")

    stream.step = start_step
    losses = []
    for step in range(start_step, args.steps):
        if args.kill_at is not None and step == args.kill_at:
            if ckpt:
                ckpt.wait()   # drain in-flight async save, like a real
                #               preemption handler would before exiting
            # drive the production recovery state machine with the kill:
            # node 0 goes silent, every survivor keeps heartbeating, and
            # the detector's decision selects the restart step + remesh
            killed = 0
            now = time.time()
            for node in range(max(n_dev, 1)):
                if node != killed:
                    ft.heartbeat(node, now)
            ckpt_step = (latest_step(args.ckpt_dir) or 0) if ckpt else 0
            dec = ft.tick(now + ft.interval * ft.timeout_beats,
                          last_ckpt_step=ckpt_step)
            print(f"[ft] injected failure at step {step}: "
                  f"node {killed} silent -> decision {dec}")
            if dec.failed_nodes and not dec.promoted_spares:
                survivors = max(n_dev, 1) - len(dec.failed_nodes)
                try:
                    plan = plan_remesh(tuple(mesh.axis_names),
                                       tuple(mesh.devices.shape), survivors)
                    print(f"[ft] remesh plan: {plan.old_shape} -> "
                          f"{plan.new_shape} (dropped "
                          f"{plan.dropped_devices}, batch/shard x"
                          f"{plan.batch_per_shard_scale:.2f})")
                except ValueError as e:
                    print(f"[ft] remesh impossible: {e}")
            print(f"[ft] restart this command to resume from step "
                  f"{dec.restart_step}; the survivors re-inject the dead "
                  "rank's checkpointed container shards on restore")
            return 17
        hb = time.time()
        batch_np = stream.next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        losses.append(loss)
        for node in range(max(n_dev, 1)):
            ft.heartbeat(node, hb)
            strag.observe(node, dt)
        dec = ft.tick(time.time(), last_ckpt_step=step)
        if dec.action != "none":
            print(f"[ft] decision: {dec}")
        if ckpt:
            ckpt.maybe_save(step + 1, (params, opt, stream.state_dict()))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"{dt*1000:7.1f} ms "
                  f"stragglers={strag.stragglers()}")
        if not np.isfinite(loss):
            print("NON-FINITE LOSS — aborting")
            return 1
    if ckpt:
        ckpt.wait()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
