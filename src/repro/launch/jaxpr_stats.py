"""Jaxpr-level cost analysis with correct scan trip-count multiplication.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a while-loop
body exactly ONCE, so any scanned computation (scan-over-layers, the
blockwise-attention KV scan, SSM time scans, the chunked-vocab xent) is
undercounted by its trip count — for a 61-layer scanned model that is a
~60x error in the compute term.  The dry-run therefore derives:

  flops       dot_general/einsum FLOPs (+1 per output element for cheap
              elementwise ops), multiplied through scan lengths, and
              multiplied by participant count inside shard_map bodies
              (global totals).
  dot_bytes   a fusion-aware HBM-traffic estimate: operand/result bytes
              of matmuls, gathers, scatters and scan carries — the
              tensors that must actually round-trip HBM.  Elementwise
              chains are assumed fused (free), which is what XLA does.
  coll_bytes  explicit collective payloads (psum / all_gather /
              all_to_all / ppermute / psum_scatter) with ring-model wire
              factors and scan multipliers — this captures the BCL
              exchange traffic inside the layer scan that the HLO text
              parse sees only once.

The HLO-text parse (roofline.parse_collectives) still runs: it is the
only view of GSPMD-inserted collectives (gradient sync, resharding).
EXPERIMENTS.md reports both and explains the reconciliation.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import numpy as np
from jax.extend import core as jcore


@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll_wire: dict = dataclasses.field(default_factory=dict)
    coll_payload: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    count_trips: bool = True   # multiply scan bodies by trip count

    def add_coll(self, kind: str, payload: float, wire: float, n: float):
        self.coll_wire[kind] = self.coll_wire.get(kind, 0.0) + wire
        self.coll_payload[kind] = self.coll_payload.get(kind, 0.0) + payload
        self.coll_counts[kind] = self.coll_counts.get(kind, 0.0) + n

    def total_wire(self) -> float:
        return sum(self.coll_wire.values())


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64) *
                     np.dtype(aval.dtype).itemsize)
    except Exception:
        return 0.0


def _aval_size(aval) -> float:
    try:
        return float(np.prod(aval.shape, dtype=np.float64))
    except Exception:
        return 0.0


_CHEAP_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "neg", "sign", "floor", "ceil", "round",
    "erf", "pow", "integer_pow", "select_n", "and", "or", "xor", "not",
    "cos", "sin",
}

_REDUCES = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
            "reduce_and", "reduce_or", "argmax", "argmin",
            "cumsum", "cummax", "cumlogsumexp"}


def _axis_sizes(axis_names, axis_env: dict) -> int:
    if isinstance(axis_names, (str,)):
        axis_names = (axis_names,)
    size = 1
    for a in axis_names or ():
        size *= axis_env.get(a, 1)
    return size


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = 1.0
    for d in lb:
        batch *= a.shape[d]
    contract = 1.0
    for d in lc:
        contract *= a.shape[d]
    m = 1.0
    for d in range(len(a.shape)):
        if d not in lc and d not in lb:
            m *= a.shape[d]
    n = 1.0
    for d in range(len(b.shape)):
        if d not in rc and d not in rb:
            n *= b.shape[d]
    return 2.0 * batch * m * n * contract


def _walk(jaxpr, stats: Stats, mult: float, axis_env: dict):
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name

        if prim == "dot_general":
            stats.flops += mult * _dot_flops(eqn)
            io = sum(_aval_bytes(v.aval) for v in eqn.invars) + \
                sum(_aval_bytes(v.aval) for v in eqn.outvars)
            stats.dot_bytes += mult * io
            continue

        if prim in ("gather", "scatter", "scatter-add", "scatter_add",
                    "dynamic_slice", "dynamic_update_slice", "sort",
                    "argsort", "take", "rng_bit_generator", "iota_32x2"):
            io = sum(_aval_bytes(v.aval) for v in eqn.invars) + \
                sum(_aval_bytes(v.aval) for v in eqn.outvars)
            stats.dot_bytes += mult * io
            # sorts and scatters also do comparison work
            stats.flops += mult * sum(_aval_size(v.aval)
                                      for v in eqn.outvars)
            continue

        if prim in _CHEAP_ELEMENTWISE:
            stats.flops += mult * sum(_aval_size(v.aval)
                                      for v in eqn.outvars)
            continue

        if prim in _REDUCES:
            stats.flops += mult * sum(_aval_size(v.aval)
                                      for v in eqn.invars)
            continue

        # ---- collectives (explicit: BCL exchange, embed psum, ...) ----
        if prim in ("psum", "psum2", "all_gather", "all_to_all",
                    "ppermute", "psum_scatter", "pmax", "pmin",
                    "reduce_scatter"):
            names = eqn.params.get("axes") or eqn.params.get("axis_name") \
                or eqn.params.get("axis_index_groups") or ()
            if isinstance(names, dict):
                names = tuple(names)
            g = eqn.params.get("axis_size") or _axis_sizes(names, axis_env)
            g = max(int(g), 1)
            frac = (g - 1) / g
            size = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            in_size = sum(_aval_bytes(v.aval) for v in eqn.invars)
            if prim in ("psum", "psum2", "pmax", "pmin"):
                kind, wire = "all-reduce", 2 * size * frac
            elif prim == "all_gather":
                kind, wire = "all-gather", size * frac
            elif prim in ("psum_scatter", "reduce_scatter"):
                kind, wire = "reduce-scatter", in_size * frac
            elif prim == "all_to_all":
                kind, wire = "all-to-all", size * frac
            else:
                kind, wire = "collective-permute", size
            stats.add_coll(kind, mult * size, mult * wire, mult)
            continue

        # ---- structured control flow ----
        if prim == "scan":
            length = eqn.params.get("length", 1) if stats.count_trips else 1
            inner = eqn.params["jaxpr"]
            # carries + xs slices round-trip HBM each iteration
            carry_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)
            stats.dot_bytes += mult * carry_bytes
            _walk(inner.jaxpr, stats, mult * length, axis_env)
            continue
        if prim == "while":
            body = eqn.params["body_jaxpr"]
            _walk(body.jaxpr, stats, mult, axis_env)  # trip count unknown
            continue
        if prim == "cond":
            branches = eqn.params["branches"]
            subs = [Stats() for _ in branches]
            for s, br in zip(subs, branches):
                _walk(br.jaxpr, s, mult, axis_env)
            # worst case branch
            best = max(subs, key=lambda s: s.flops)
            stats.flops += best.flops
            stats.dot_bytes += best.dot_bytes
            for k in best.coll_wire:
                stats.add_coll(k, best.coll_payload[k], best.coll_wire[k],
                               best.coll_counts[k])
            continue

        if prim == "shard_map":
            mesh = eqn.params.get("mesh")
            env = dict(axis_env)
            participants = 1
            if mesh is not None:
                for name, size in zip(mesh.axis_names, mesh.devices.shape
                                      if hasattr(mesh, "devices")
                                      else mesh.shape.values()):
                    env[name] = int(size)
                participants = int(np.prod(
                    [env[n] for n in mesh.axis_names]))
            inner = eqn.params.get("jaxpr")
            if inner is not None:
                sub = Stats()
                _walk(inner if not hasattr(inner, "jaxpr") else inner.jaxpr,
                      sub, mult, env)
                # body runs on every participant: totals scale by count
                stats.flops += sub.flops * participants
                stats.dot_bytes += sub.dot_bytes * participants
                for k in sub.coll_wire:
                    stats.add_coll(k, sub.coll_payload[k] * participants,
                                   sub.coll_wire[k] * participants,
                                   sub.coll_counts[k])
            continue

        # ---- generic recursion: any param holding a (Closed)Jaxpr ----
        recursed = False
        for v in eqn.params.values():
            for sub in _iter_jaxprs(v):
                _walk(sub, stats, mult, axis_env)
                recursed = True
        if recursed:
            continue

        # everything else: count outputs as cheap ops
        stats.flops += mult * sum(_aval_size(v.aval) for v in eqn.outvars)


def _iter_jaxprs(v):
    if isinstance(v, jcore.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jcore.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _iter_jaxprs(x)


def analyze(fn, *args, axis_env: dict | None = None,
            count_trips: bool = True) -> Stats:
    """Trace ``fn(*args)`` to a jaxpr and accumulate Stats (global totals:
    shard_map bodies are multiplied by participant count).

    ``count_trips=False`` reproduces XLA's count-scan-once convention —
    the difference between the two runs is exactly the correction the
    HLO-text collective parse needs."""
    closed = jax.make_jaxpr(fn)(*args)
    return analyze_jaxpr(closed, axis_env=axis_env, count_trips=count_trips)


def analyze_jaxpr(closed, *, axis_env: dict | None = None,
                  count_trips: bool = True) -> Stats:
    stats = Stats(count_trips=count_trips)
    _walk(closed.jaxpr, stats, 1.0, dict(axis_env or {}))
    # program inputs must be read at least once (params etc.)
    stats.dot_bytes += sum(_aval_bytes(v.aval) for v in closed.jaxpr.invars)
    return stats


def analyze_pair(fn, *args, axis_env: dict | None = None):
    """(scan-multiplied, scan-once) stats from a single trace."""
    closed = jax.make_jaxpr(fn)(*args)
    return (analyze_jaxpr(closed, axis_env=axis_env, count_trips=True),
            analyze_jaxpr(closed, axis_env=axis_env, count_trips=False))


def _count_ops(jaxpr, counts: dict, opaque_kernels: bool):
    for eqn in jaxpr.eqns:
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
        if opaque_kernels and eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            for sub in _iter_jaxprs(v):
                _count_ops(sub, counts, opaque_kernels)


def op_counts(fn_or_closed, *args, opaque_kernels: bool = True) -> dict:
    """Static primitive census of a traced program: ``{prim_name: count}``
    over the whole jaxpr, recursing into every nested (Closed)Jaxpr —
    scan/while/cond bodies, shard_map, pjit calls, custom_jvp wrappers.

    Counts are STATIC occurrences (a scan body counts once, not per
    trip) — this is the structural-pinning view, not a cost model: the
    wire-fusion tests assert e.g. ``op_counts(commit)["scatter"] == 0``
    to prove the fused Pallas path replaced XLA's scatter lowering, and
    pin the exact count on the fallback path so a regression that quietly
    adds a wire pass fails loudly (DESIGN.md section 1.10).

    ``opaque_kernels=True`` (the default) counts a ``pallas_call`` as one
    opaque primitive without descending into its body: in-kernel
    functional updates trace as scatter eqns INSIDE the kernel jaxpr but
    lower to vector stores on the accelerator, so they are not XLA
    scatter passes over HBM.  Pass ``False`` for a raw census.

    Accepts a ClosedJaxpr, or a callable plus its example args (traced
    via ``jax.make_jaxpr``).
    """
    closed = (fn_or_closed if isinstance(fn_or_closed, jcore.ClosedJaxpr)
              else jax.make_jaxpr(fn_or_closed)(*args))
    counts: dict = {}
    _count_ops(closed.jaxpr, counts, opaque_kernels)
    return counts
