"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets the placeholder device count
before any jax initialization).
"""

from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; the multi-pod mesh adds a leading pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh for CPU tests (uses however many devices exist)."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


# Hardware model used by the roofline (TPU v5e-class chip)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
HBM_PER_CHIP = 16 * 1024 ** 3     # 16 GiB
