import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell this lowers + compiles the
real step function (train_step / prefill_step / serve_step) against
ShapeDtypeStruct inputs on the production mesh — 16x16 single-pod and
2x16x16 multi-pod — and extracts:

  * memory_analysis()      argument/output/temp bytes per device
  * cost_analysis()        HLO FLOPs + bytes accessed
  * collective wire bytes  parsed from the compiled HLO (roofline.py)

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out dryrun.json

The two os.environ lines above MUST stay the first statements: jax locks
the device count at first init.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import (ARCH_IDS, SHAPES, get_config, input_specs,
                           shape_applicable)
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh, HBM_PER_CHIP
from repro.launch.steps import (batch_shardings, cache_shardings,
                                make_prefill_step, make_serve_step,
                                make_train_step, train_shardings)


def lower_cell(cfg, shape, mesh, verbose: bool = True):
    """Lower + compile one (arch, shape) on ``mesh``; return the record."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models.sharding import Axes
    axes = Axes.from_mesh(mesh)
    specs = input_specs(cfg, shape)
    t0 = time.time()

    from repro.launch import jaxpr_stats
    axis_env = dict(zip(mesh.axis_names, mesh.devices.shape))

    if shape.kind == "train":
        pshape, oshape, psh, osh = train_shardings(cfg, mesh)
        bsh = batch_shardings(cfg, mesh, specs)
        step = make_train_step(cfg, mesh)
        lowered = jax.jit(step,
                          in_shardings=(psh, osh, bsh),
                          out_shardings=(psh, osh, None),
                          donate_argnums=(0, 1)).lower(
            pshape, oshape, specs)
        st_mult, st_once = jaxpr_stats.analyze_pair(
            step, pshape, oshape, specs, axis_env=axis_env)
    elif shape.kind == "prefill":
        pshape, _, psh, _ = train_shardings(cfg, mesh)
        bsh = batch_shardings(cfg, mesh, specs)
        step = make_prefill_step(cfg, mesh, cache_len=shape.seq_len)
        lowered = jax.jit(step, in_shardings=(psh, bsh)).lower(
            pshape, specs)
        st_mult, st_once = jaxpr_stats.analyze_pair(
            step, pshape, specs, axis_env=axis_env)
    else:  # decode
        pshape, _, psh, _ = train_shardings(cfg, mesh)
        cache_shape = specs["cache"]
        csh = cache_shardings(cfg, mesh, cache_shape)
        from repro.launch.steps import _n_data
        b_tok = specs["tokens"].shape[0]
        lead = axes.data if b_tok % _n_data(mesh, axes) == 0 else None
        tok_sh = NamedSharding(mesh, P(lead, None))
        step = make_serve_step(cfg, mesh)
        lowered = jax.jit(step,
                          in_shardings=(psh, csh, tok_sh),
                          donate_argnums=(1,)).lower(
            pshape, cache_shape, specs["tokens"])
        st_mult, st_once = jaxpr_stats.analyze_pair(
            step, pshape, cache_shape, specs["tokens"], axis_env=axis_env)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = rl.parse_collectives(hlo, mesh.size)
    model_flops = rl.model_flops_for(cfg, shape)

    # Reconcile collective bytes: the HLO parse sees GSPMD-inserted
    # collectives but counts scan bodies once; the jaxpr pass multiplies
    # our explicit (BCL exchange) collectives by trip count.  Correction
    # = the trips-minus-once delta of the explicit set (global bytes).
    scan_correction = st_mult.total_wire() - st_once.total_wire()
    wire_total = coll.total_wire() + max(scan_correction, 0.0)

    roof = rl.compute_roofline(
        flops=st_mult.flops / mesh.size,            # analytic, scan-exact
        hbm_bytes=st_mult.dot_bytes / mesh.size,    # fusion-aware estimate
        wire_bytes=wire_total / mesh.size,
        n_chips=mesh.size,
        model_flops=model_flops)

    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
        "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
    }
    live = (mem["argument_bytes"] or 0) + (mem["temp_bytes"] or 0) \
        + (mem["output_bytes"] or 0) - (mem["alias_bytes"] or 0)
    rec = {
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.size,
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "per_device_live_bytes": live,
        "fits_16g": bool(live <= HBM_PER_CHIP),
        "xla_flops_per_device_raw": float(ca.get("flops", 0.0)),
        "xla_bytes_per_device_raw": float(ca.get("bytes accessed", 0.0)),
        "analytic_flops_total": st_mult.flops,
        "analytic_hbm_bytes_total": st_mult.dot_bytes,
        "collectives_hlo": {
            "counts": coll.counts,
            "payload_bytes": coll.payload_bytes,
            "wire_bytes": coll.wire_bytes,
        },
        "collectives_jaxpr": {
            "counts": st_mult.coll_counts,
            "payload_bytes": st_mult.coll_payload,
            "wire_bytes": st_mult.coll_wire,
        },
        "wire_bytes_total": wire_total,
        "roofline": roof.to_dict(),
    }
    if verbose:
        print(f"    memory_analysis: {ma}")
        print(f"    cost_analysis(raw): flops={rec['xla_flops_per_device_raw']:.3e} "
              f"bytes={rec['xla_bytes_per_device_raw']:.3e}")
        print(f"    collectives: {coll.counts} wire={coll.total_wire():.3e}B")
        print(f"    roofline[s]: compute={roof.compute_s:.4f} "
              f"memory={roof.memory_s:.4f} "
              f"collective={roof.collective_s:.4f} -> {roof.dominant}")
    return rec


def run(arch_ids, shape_names, meshes, out_path, verbose=True):
    results = {}
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch in arch_ids:
            cfg = get_config(arch)
            for sname in shape_names:
                shape = SHAPES[sname]
                key = f"{arch}|{sname}|{mesh_name}"
                ok, reason = shape_applicable(cfg, shape)
                if not ok:
                    results[key] = {"status": "skipped", "reason": reason}
                    print(f"[skip] {key}: {reason}")
                    continue
                print(f"[cell] {key} ...", flush=True)
                try:
                    rec = lower_cell(cfg, shape, mesh, verbose=verbose)
                    rec["status"] = "ok"
                    results[key] = rec
                    print(f"  OK lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"live={rec['per_device_live_bytes']/2**30:.2f}GiB "
                          f"dominant={rec['roofline']['dominant']}")
                except Exception as e:  # a failure here is a bug in our system
                    results[key] = {"status": "error",
                                    "error": f"{type(e).__name__}: {e}"}
                    print(f"  FAIL {type(e).__name__}: {e}")
                    if verbose:
                        traceback.print_exc(limit=8)
                if out_path:
                    with open(out_path, "w") as f:
                        json.dump(results, f, indent=1)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = run(archs, shapes, meshes, args.out, verbose=not args.quiet)
    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"\ndry-run cells: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
