"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md):

  compute    = HLO_FLOPs / (chips * 197e12)
  memory     = HLO_bytes / (chips * 819e9)
  collective = wire_bytes / (chips * 50e9)

FLOPs/bytes come from ``compiled.cost_analysis()`` (already per-program
= whole-mesh totals on the host-platform backend... empirically XLA
reports per-device-program totals; we treat them as per-device and note
the convention).  Collective wire bytes are parsed from the compiled
HLO text: for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute we take operand/output sizes and apply
the standard ring-cost factor for the op's group size.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|all-to-all|reduce-scatter|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # [n_groups, group_size]<=[total]
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    payload_bytes: dict      # raw payload per op kind
    wire_bytes: dict         # ring-model bytes actually serialized per link-step

    def total_wire(self) -> float:
        return sum(self.wire_bytes.values())

    def dominant(self) -> str:
        if not self.wire_bytes:
            return "none"
        return max(self.wire_bytes, key=self.wire_bytes.get)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    counts: dict = {}
    payload: dict = {}
    wire: dict = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        out_shape, kind, _ = m.groups()
        size = _shape_bytes(out_shape)
        g = _group_size(line, n_devices)
        frac = (g - 1) / max(g, 1)
        if kind == "all-gather":
            w = size * frac                       # output-size based
        elif kind == "reduce-scatter":
            w = size * (g - 1)                    # out = in/g; wire ~ in*frac
        elif kind == "all-reduce":
            w = 2 * size * frac                   # RS + AG ring
        elif kind == "all-to-all":
            w = size * frac
        else:                                     # collective-permute
            w = size
        counts[kind] = counts.get(kind, 0) + 1
        payload[kind] = payload.get(kind, 0) + size
        wire[kind] = wire.get(kind, 0) + w
    return CollectiveStats(counts, payload, wire)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float

    def to_dict(self):
        return dataclasses.asdict(self)


def compute_roofline(*, flops: float, hbm_bytes: float, wire_bytes: float,
                     n_chips: int, model_flops: float,
                     per_device_costs: bool = True) -> Roofline:
    from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW
    # cost_analysis on SPMD programs reports the PER-DEVICE program;
    # model_flops is the global batch's ideal count.
    if per_device_costs:
        total_flops = flops * n_chips
        total_bytes = hbm_bytes * n_chips
        total_wire = wire_bytes * n_chips
    else:
        total_flops, total_bytes, total_wire = flops, hbm_bytes, wire_bytes
    compute_s = total_flops / (n_chips * PEAK_FLOPS_BF16)
    memory_s = total_bytes / (n_chips * HBM_BW)
    collective_s = total_wire / (n_chips * ICI_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dom = max(terms, key=terms.get)
    return Roofline(flops=total_flops, hbm_bytes=total_bytes,
                    wire_bytes=total_wire, n_chips=n_chips,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, dominant=dom,
                    model_flops=model_flops,
                    useful_ratio=(model_flops / total_flops
                                  if total_flops else 0.0))


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) per step/batch."""
    from repro.models.lm import active_param_count_exact
    n_active = active_param_count_exact(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
