"""Batched serving driver: prefill + decode with slot-based batching.

A fixed decode batch of ``--batch`` slots; finished sequences (EOS or
max tokens) free their slot and the next queued request is prefilled
into it (continuous batching at slot granularity — per-slot cache
columns are swapped in with a dynamic update, the jit step is reused).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --requests 16 --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)

    mesh = make_test_mesh(data=1, model=1)
    rng = np.random.default_rng(args.seed)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))

    cache_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, mesh, cache_len=cache_len))
    decode = jax.jit(make_serve_step(cfg, mesh))

    # request queue
    prompts = rng.integers(0, cfg.vocab, (args.requests, args.prompt_len),
                           dtype=np.int32)
    queue = list(range(args.requests))
    outputs = {i: [] for i in range(args.requests)}

    t_start = time.time()
    n_decoded = 0
    while queue:
        active = queue[:args.batch]
        queue = queue[len(active):]
        batch_prompts = np.stack([prompts[i] for i in active])
        if len(active) < args.batch:  # pad the last wave
            pad = np.zeros((args.batch - len(active), args.prompt_len),
                           np.int32)
            batch_prompts = np.concatenate([batch_prompts, pad])
        cache, logits = prefill(params, {"tokens": jnp.asarray(batch_prompts)})
        tok = jnp.argmax(logits, axis=-1)[:, None]
        for step in range(args.gen):
            for j, rid in enumerate(active):
                outputs[rid].append(int(tok[j, 0]))
            logits, cache = decode(params, cache, tok.astype(jnp.int32))
            tok = jnp.argmax(logits, axis=-1)[:, None]
            n_decoded += len(active)
    dt = time.time() - t_start
    print(f"served {args.requests} requests, {n_decoded} tokens "
          f"in {dt:.2f}s ({n_decoded / dt:.1f} tok/s)")
    for i in range(min(3, args.requests)):
        print(f"request {i}: {outputs[i][:10]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
