"""Jit-able step builders shared by the dry-run and the drivers.

All three entry points close over (cfg, mesh) and are pure:

  train_step(params, opt_state, batch) -> (params, opt_state, metrics)
  prefill_step(params, batch)          -> (cache, logits)
  serve_step(params, cache, tokens)    -> (logits, cache)

plus the sharding trees the jit wrapper needs.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.models.sharding import Axes, param_shardings
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import opt_shardings


def opt_config_for(cfg: ArchConfig) -> AdamWConfig:
    return AdamWConfig(moment_dtype=cfg.optimizer_dtype,
                       factored=cfg.factored_second_moment)


def make_train_step(cfg: ArchConfig, mesh: Mesh):
    axes = Axes.from_mesh(mesh)
    ocfg = opt_config_for(cfg)
    accum = max(1, cfg.grad_accum)

    def grads_of(params, batch):
        def lf(p):
            return lm.loss_fn(p, cfg, batch, mesh=mesh, axes=axes)
        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = grads_of(params, batch)
        else:
            # microbatching: scan over batch splits, accumulate f32 grads
            # (activation memory / accum — EXPERIMENTS.md section Perf)
            def split(x):
                b = x.shape[0]
                return x.reshape((accum, b // accum) + x.shape[1:])
            micro = jax.tree_util.tree_map(split, batch)

            def body(acc, mb):
                (l, m), g = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, gg: a + gg.astype(jnp.float32) / accum,
                    acc, g)
                return acc, (l, m)

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, (losses, metricses) = jax.lax.scan(body, zeros, micro)
            loss = losses.mean()
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), metricses)
        new_params, new_opt, om = adamw_update(ocfg, params, grads, opt_state)
        return new_params, new_opt, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ArchConfig, mesh: Mesh, cache_len: int):
    axes = Axes.from_mesh(mesh)

    def prefill_step(params, batch):
        return lm.prefill(params, cfg, batch, cache_len=cache_len,
                          mesh=mesh, axes=axes)

    return prefill_step


def make_serve_step(cfg: ArchConfig, mesh: Mesh):
    axes = Axes.from_mesh(mesh)

    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cfg, cache, tokens,
                              mesh=mesh, axes=axes)

    return serve_step


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def _n_data(mesh: Mesh, axes: Axes) -> int:
    n = 1
    for a in axes.data:
        n *= mesh.shape[a]
    return n


def batch_shardings(cfg: ArchConfig, mesh: Mesh, batch_shape: dict):
    axes = Axes.from_mesh(mesh)
    d = axes.data
    nd_ = _n_data(mesh, axes)

    def one(kp, leaf):
        nd = len(leaf.shape)
        lead = d if leaf.shape[0] % nd_ == 0 else None  # batch=1 replicates
        return NamedSharding(mesh, P(*((lead,) + (None,) * (nd - 1))))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, cache_shape):
    axes = Axes.from_mesh(mesh)
    d, m = axes.data, axes.model
    nm = mesh.shape[m]

    nd_ = _n_data(mesh, axes)

    def one(kp, leaf):
        path = jax.tree_util.keystr(kp)
        nd = len(leaf.shape)
        stacked = "stack" in path
        core = nd - (1 if stacked else 0)
        if "pos" in path or core == 0:
            return NamedSharding(mesh, P())
        bdim = leaf.shape[1 if stacked else 0]
        dims: list = [d if bdim % nd_ == 0 else None]
        if core == 3 and cfg.mla_cp_decode and \
                ("c_kv" in path or "k_rope" in path):
            sdim = leaf.shape[(1 if stacked else 0) + 1]
            dims += [m if sdim % nm == 0 else None]
        elif core >= 2:
            # shard the head-like dim over model when it divides evenly
            if any(k in path for k in ("'k'", "'v'", "xk", "xv")) and core == 4:
                hdim = leaf.shape[1 + (1 if stacked else 0)]
                dims += [m if hdim % nm == 0 else None]
            elif "ssd" in path and core == 4:
                hdim = leaf.shape[1 + (1 if stacked else 0)]
                dims += [m if hdim % nm == 0 else None]
            elif path.endswith("'s']") and core == 4:
                hdim = leaf.shape[1 + (1 if stacked else 0)]
                dims += [m if hdim % nm == 0 else None]
        while len(dims) < core:
            dims.append(None)
        if stacked:
            dims = [None] + dims
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def abstract_state(cfg: ArchConfig):
    """(params_shape, opt_shape) without allocation."""
    pshape = lm.abstract_params(cfg)
    ocfg = opt_config_for(cfg)
    oshape = jax.eval_shape(lambda: adamw_init(
        ocfg, jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), pshape)))
    return pshape, oshape


def train_shardings(cfg: ArchConfig, mesh: Mesh):
    pshape, oshape = abstract_state(cfg)
    psh = param_shardings(cfg, mesh, pshape)
    osh = opt_shardings(psh, oshape, mesh)
    return pshape, oshape, psh, osh


def init_state(cfg: ArchConfig, mesh: Mesh, rng):
    """Materialize params + opt state WITH shardings applied (real runs)."""
    pshape, oshape, psh, osh = train_shardings(cfg, mesh)
    params = jax.jit(lambda r: lm.init_params(cfg, r),
                     out_shardings=psh)(rng)
    ocfg = opt_config_for(cfg)
    opt = jax.jit(lambda p: adamw_init(ocfg, p),
                  out_shardings=osh)(params)
    return params, opt, psh, osh
