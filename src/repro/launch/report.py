"""Render dry-run JSON into the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import argparse
import json


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def render(results: dict, mesh_filter: str = "single") -> str:
    rows = []
    header = ("| arch | shape | status | live/dev | fits16G | compute | "
              "memory | collective | dominant | useful(6ND/flops) | "
              "collectives |")
    sep = "|" + "---|" * 11
    rows.append(header)
    rows.append(sep)
    for key, rec in sorted(results.items()):
        arch, shape, mesh = key.split("|")
        if mesh != mesh_filter:
            continue
        if rec.get("status") == "skipped":
            rows.append(f"| {arch} | {shape} | SKIP ({rec['reason'][:40]}...)"
                        f" | - | - | - | - | - | - | - | - |")
            continue
        if rec.get("status") == "error":
            rows.append(f"| {arch} | {shape} | ERROR {rec['error'][:60]} "
                        f"| - | - | - | - | - | - | - | - |")
            continue
        r = rec["roofline"]
        colls = rec["collectives_hlo"]["counts"]
        coll_str = " ".join(f"{k.split('-')[-1][:3]}:{v}"
                            for k, v in sorted(colls.items()))
        rows.append(
            f"| {arch} | {shape} | ok | "
            f"{fmt_bytes(rec['per_device_live_bytes'])} | "
            f"{'Y' if rec['fits_16g'] else 'N'} | "
            f"{fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
            f"{fmt_s(r['collective_s'])} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {coll_str} |")
    return "\n".join(rows)


def summarize(results: dict) -> str:
    lines = []
    for mesh in ("single", "multi"):
        ok = [k for k, r in results.items()
              if k.endswith(mesh) and r.get("status") == "ok"]
        sk = [k for k, r in results.items()
              if k.endswith(mesh) and r.get("status") == "skipped"]
        er = [k for k, r in results.items()
              if k.endswith(mesh) and r.get("status") == "error"]
        lines.append(f"{mesh}-pod: {len(ok)} ok / {len(sk)} skipped / "
                     f"{len(er)} errors")
        for k in er:
            lines.append(f"  ERROR {k}: {results[k]['error'][:100]}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("json", nargs="?", default="dryrun_results.json")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    with open(args.json) as f:
        results = json.load(f)
    print(summarize(results))
    print()
    print(render(results, args.mesh))


if __name__ == "__main__":
    main()
