"""Version compatibility shims over the installed JAX.

The repo targets the modern JAX surface (``jax.sharding.AxisType``,
``jax.make_mesh(..., axis_types=...)``, ``jax.shard_map``,
``jax.lax.axis_size``); older installs expose the same functionality
under different names or without the newer keywords.  Everything that
touches one of those entry points goes through this module so the rest
of the codebase is written once against the modern API.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # noqa: F401
    _HAS_AXIS_TYPES = True
except ImportError:  # pragma: no cover - depends on installed jax
    class AxisType:  # type: ignore[no-redef]
        """Placeholder enum: old JAX has implicit (auto) axes only."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _HAS_AXIS_TYPES = False


if hasattr(jax, "shard_map"):  # jax >= 0.6
    shard_map = jax.shard_map
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, mesh, in_specs, out_specs, **kw):
        # old shard_map spells check_vma as check_rep, and its replication
        # checker predates several collective rep rules used here;
        # correctness is covered by the out_specs.
        kw.pop("check_vma", None)
        kw.setdefault("check_rep", False)
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types=None):
    """``jax.make_mesh`` that tolerates old JAX without ``axis_types``."""
    if _HAS_AXIS_TYPES:
        if axis_types is None:
            axis_types = (AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, axis_types=axis_types)
    return jax.make_mesh(axis_shapes, axis_names)


def axis_size(axis) -> int:
    """Static size of a named mesh axis (product for a tuple of names)."""
    if hasattr(jax.lax, "axis_size"):
        return int(jax.lax.axis_size(axis))
    from jax.core import axis_frame  # old jax: returns the static size
    if isinstance(axis, tuple):
        return math.prod(int(axis_frame(a)) for a in axis)
    return int(axis_frame(axis))
