"""repro — BCL (Berkeley Container Library) reproduced as a TPU-native JAX framework.

The package is layered exactly like the paper's stack:

  core/        the "BCL Core" internal DSL: backends, global pointers,
               object containers (serialization), concurrency promises and
               the many-to-many exchange engine (the TPU analogue of
               one-sided RDMA + remote atomics).
  containers/  the distributed data structures: DHashMap, FastQueue,
               CircularQueue, BloomFilter, DArray, HashMapBuffer.
  kernels/     Pallas TPU kernels for the compute hot spots (blocked hash
               probing, blocked Bloom hashing, binning, flash attention).
  models/      the LM framework built on top of the containers (MoE dispatch
               uses the BCL exchange; embeddings are DArray rgets).
  optim/ data/ checkpoint/ runtime/   training substrate.
  configs/     assigned architecture configs + paper app configs.
  launch/      production mesh, multi-pod dry-run, train/serve drivers.
"""

__version__ = "1.0.0"

from repro.core.promises import ConProm  # noqa: F401
