"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *semantic ground truth*: deliberately simple (sequential
``fori_loop`` where ordering matters), obviously correct, and used by the
test suite to validate both the vectorized jnp implementations in
``ops.py`` and the Pallas kernels (run in interpret mode on CPU).

Hash-table layout (blocked open addressing, DESIGN.md section 2):
  tkeys  (nb, B, Lk) u32   stored key lanes
  tvals  (nb, B, Lv) u32   stored value lanes
  status (nb, B)     u32   0=FREE, 1=RESERVED, 2=READY (paper's 2-bit state)

A key hashes to a block; probing is vectorized across the block's B slots.
Cross-block overflow is handled by the container via bounded rehash
attempts (quadratic in the attempt number), not inside the kernel.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

_U32 = jnp.uint32
FREE, RESERVED, READY = _U32(0), _U32(1), _U32(2)
STATE_MASK = _U32(3)   # low 2 bits = bucket state; high 30 bits = read flags


def bucket_state(status):
    return status & STATE_MASK

MODE_SET, MODE_ADD, MODE_KEEP = 0, 1, 2


# --------------------------------------------------------------------------
# blocked hash probe
# --------------------------------------------------------------------------

def hash_probe_insert_ref(tkeys, tvals, status, qblock, qkeys, qvals, qvalid,
                          mode: int = MODE_SET):
    """Sequential-semantics blocked insert oracle.

    Items are inserted one at a time in batch order: matching READY slot
    updates the value (set / add / keep); otherwise the first FREE slot
    in the block is claimed; a full block fails the item.

    Returns (tkeys, tvals, status, success(M,) bool).
    """
    m = qblock.shape[0]

    def body(i, carry):
        tk, tv, st, ok = carry
        b = qblock[i]
        key = qkeys[i]
        blk_keys = tk[b]          # (B, Lk)
        blk_stat = st[b]          # (B,)
        match = (blk_keys == key[None, :]).all(axis=1) & (bucket_state(blk_stat) == READY)
        has_match = match.any()
        match_slot = jnp.argmax(match)
        free = bucket_state(blk_stat) == FREE
        has_free = free.any()
        free_slot = jnp.argmax(free)
        slot = jnp.where(has_match, match_slot, free_slot)
        can = qvalid[i] & (has_match | has_free)

        old_val = tv[b, slot]
        if mode == MODE_SET:
            new_val = qvals[i]
        elif mode == MODE_ADD:
            new_val = jnp.where(has_match, old_val + qvals[i], qvals[i])
        else:  # MODE_KEEP: first writer wins
            new_val = jnp.where(has_match, old_val, qvals[i])

        tk = tk.at[b, slot].set(jnp.where(can, key, tk[b, slot]))
        tv = tv.at[b, slot].set(jnp.where(can, new_val, old_val))
        old_st = st[b, slot]
        st = st.at[b, slot].set(jnp.where(can, (old_st & ~STATE_MASK) | READY, old_st))
        ok = ok.at[i].set(can)
        return tk, tv, st, ok

    ok0 = jnp.zeros((m,), bool)
    tkeys, tvals, status, ok = jax.lax.fori_loop(
        0, m, body, (tkeys, tvals, status, ok0))
    return tkeys, tvals, status, ok


def hash_probe_find_ref(tkeys, tvals, status, qblock, qkeys, qvalid):
    """Blocked find oracle: (found(M,), values(M, Lv))."""
    blk_keys = tkeys[qblock]                  # (M, B, Lk)
    blk_stat = status[qblock]                 # (M, B)
    match = (blk_keys == qkeys[:, None, :]).all(axis=2) & (bucket_state(blk_stat) == READY)
    found = match.any(axis=1) & qvalid
    slot = jnp.argmax(match, axis=1)
    vals = tvals[qblock, slot]
    return found, jnp.where(found[:, None], vals, jnp.zeros_like(vals))


# --------------------------------------------------------------------------
# blocked Bloom filter
# --------------------------------------------------------------------------

def bloom_words_ref(hashes: jax.Array, k: int) -> jax.Array:
    """Expand (M, k) u32 hashes (each in [0,64)) into 64-bit block words
    represented as (M, 2) u32 [lo, hi]."""
    bits = hashes.astype(_U32)
    lo = jnp.where(bits < 32, _U32(1) << (bits % 32), _U32(0))
    hi = jnp.where(bits >= 32, _U32(1) << (bits % 32), _U32(0))
    word_lo = jnp.bitwise_or.reduce(lo, axis=1)
    word_hi = jnp.bitwise_or.reduce(hi, axis=1)
    return jnp.stack([word_lo, word_hi], axis=1)


def bloom_insert_ref(filter_words, qblock, qwords, qvalid):
    """Sequential-semantics blocked Bloom insert oracle.

    filter_words: (nblocks, 2) u32.  Returns (filter_words,
    already_present(M,)): item i is "already present" iff all of its bits
    were set before *its own* insertion (earlier batch items count —
    first-inserter-wins atomicity, paper section 5.4.2).
    """
    m = qblock.shape[0]

    def body(i, carry):
        fw, present = carry
        b = qblock[i]
        w = qwords[i]
        cur = fw[b]
        already = ((cur & w) == w).all() & qvalid[i]
        fw = fw.at[b].set(jnp.where(qvalid[i], cur | w, cur))
        present = present.at[i].set(already)
        return fw, present

    present0 = jnp.zeros((m,), bool)
    return jax.lax.fori_loop(0, m, body, (filter_words, present0))


def bloom_find_ref(filter_words, qblock, qwords, qvalid):
    cur = filter_words[qblock]                        # (M, 2)
    return ((cur & qwords) == qwords).all(axis=1) & qvalid


# --------------------------------------------------------------------------
# binning histogram (ISx)
# --------------------------------------------------------------------------

def bin_histogram_ref(bins: jax.Array, nbins: int, valid=None) -> jax.Array:
    """Per-bin counts; the oracle for the one-hot-matmul Pallas kernel."""
    w = jnp.ones_like(bins, dtype=jnp.int32) if valid is None else valid.astype(jnp.int32)
    return jnp.zeros((nbins,), jnp.int32).at[bins].add(w)


def bin_offsets_ref(bins: jax.Array, nbins: int, valid=None):
    """Sequential oracle for exchange send-buffer construction.

    Returns ``(counts (nbins,), offsets (N,))`` where ``offsets[i]`` is
    the number of *valid* items ``j < i`` with ``bins[j] == bins[i]`` —
    the stable position-within-destination each item claims in the
    per-destination send bucket.  Offsets of invalid items are
    unspecified (callers mask them).
    """
    n = bins.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    bins = bins.astype(jnp.int32)

    def body(i, carry):
        counts, offs = carry
        b = jnp.clip(bins[i], 0, nbins - 1)
        offs = offs.at[i].set(counts[b])
        counts = jnp.where(valid[i], counts.at[b].add(1), counts)
        return counts, offs

    counts0 = jnp.zeros((nbins,), jnp.int32)
    offs0 = jnp.zeros((n,), jnp.int32)
    return jax.lax.fori_loop(0, n, body, (counts0, offs0))


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """Plain softmax attention oracle.

    q: (B, Hq, Tq, D), k/v: (B, Hkv, Tk, D); GQA by head repetition.
    ``window`` > 0 limits attention to the last ``window`` keys (sliding).
    """
    bq, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qi = jnp.arange(tq)[:, None] + (tk - tq)   # align to suffix (decode)
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)
