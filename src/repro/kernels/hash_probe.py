"""Pallas TPU kernel: blocked open-addressing hash probe (insert + find).

TPU adaptation of the paper's hash bucket probing (DESIGN.md section 2).
The table is an array of blocks of B buckets; a query compares against
all B slots of its block in one vector op.  Queries are pre-binned per
block on the host side (the same machinery as the exchange engine), so
the kernel's addressing is entirely tile-local:

  grid         (nb / TB,)                    one step per tile of blocks
  tkeys tile   (TB, B, Lk)  VMEM             the table tile
  query tile   (TB, Q, Lk)  VMEM             binned queries

Insert iterates the Q binned queries of each block sequentially (the
deterministic arrival order — the ownership-serialized analogue of the
paper's CAS loop) while staying fully vectorized across the TB blocks
of the tile and the B slots of each block.  All slot updates use
one-hot selects rather than scatters — the VPU-friendly formulation.

Find has no ordering constraint and is a single (TB, Q, B) compare +
one-hot value contraction (MXU matmul shape).

VMEM budget at defaults (TB=8, B=128, Q=64, Lk+Lv=4 lanes, u32):
8*128*4*4 B (table) + 8*64*4*4 B (queries) ~= 24 KiB — comfortably
inside the ~16 MiB/core VMEM with room for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import interpret_mode as _interpret
from repro.kernels.ref import MODE_SET, MODE_ADD, MODE_KEEP

# kernel-local constants (plain ints: Pallas kernels cannot capture arrays)
_FREE, _READY, _MASK = 0, 2, 3

_U32 = jnp.uint32
_I32 = jnp.int32


# --------------------------------------------------------------------------
# binning: group queries per local block (host side, shared by both ops)
# --------------------------------------------------------------------------

def bin_queries(qblock, qvalid, nb: int, q_cap: int):
    """Compute per-block slots for each query.

    Returns (bin_slot(M,) flat index into (nb, q_cap), overflow(M,) bool).
    Stable order within a block == original batch order.
    """
    m = qblock.shape[0]
    b = jnp.where(qvalid, qblock.astype(_I32), nb)
    counts_full = jnp.zeros((nb + 1,), _I32).at[b].add(1)
    start = jnp.concatenate([jnp.zeros((1,), _I32),
                             jnp.cumsum(counts_full)[:-1].astype(_I32)])
    order = jnp.argsort(b, stable=True)
    sortb = b[order]
    pos = jnp.arange(m, dtype=_I32) - start[sortb]
    pos_orig = jnp.zeros((m,), _I32).at[order].set(pos)
    overflow = qvalid & (pos_orig >= q_cap)
    ok = qvalid & ~overflow
    slot = jnp.where(ok, qblock.astype(_I32) * q_cap + pos_orig, nb * q_cap)
    return slot, overflow


def _scatter_to_bins(x, slot, nb, q_cap, lanes):
    out = jnp.zeros((nb * q_cap, lanes), _U32)
    if x.ndim == 1:
        x = x[:, None]
    return out.at[slot].set(x.astype(_U32), mode="drop").reshape(nb, q_cap, lanes)


def default_q_cap(m: int, nb: int) -> int:
    """Static per-block query capacity; generous for skewed batches."""
    avg = -(-m // max(nb, 1))
    return int(min(m, max(16, 8 * avg)))


# --------------------------------------------------------------------------
# insert kernel
# --------------------------------------------------------------------------

def _insert_kernel(tk_ref, tv_ref, st_ref, qk_ref, qv_ref, qval_ref,
                   otk_ref, otv_ref, ost_ref, ok_ref, *, mode: int,
                   q_cap: int, block_size: int):
    tk = tk_ref[...]          # (TB, B, Lk)
    tv = tv_ref[...]          # (TB, B, Lv)
    st = st_ref[...]          # (TB, B)
    tb = tk.shape[0]

    def body(j, carry):
        tk, tv, st, ok = carry
        key = jax.lax.dynamic_slice_in_dim(qk_ref[...], j, 1, axis=1)[:, 0]
        val = jax.lax.dynamic_slice_in_dim(qv_ref[...], j, 1, axis=1)[:, 0]
        vld = jax.lax.dynamic_slice_in_dim(qval_ref[...], j, 1, axis=1)[:, 0]
        state = st & _MASK
        match = (tk == key[:, None, :]).all(axis=2) & (state == _READY)
        has_match = match.any(axis=1)
        free = state == _FREE
        has_free = free.any(axis=1)
        # first-match / first-free via argmax on bool
        mslot = jnp.argmax(match, axis=1)
        fslot = jnp.argmax(free, axis=1)
        slot = jnp.where(has_match, mslot, fslot)
        can = (vld == 1) & (has_match | has_free)

        onehot = (jax.lax.broadcasted_iota(_I32, (tb, block_size), 1)
                  == slot[:, None]) & can[:, None]
        old_val = jnp.take_along_axis(tv, slot[:, None, None], axis=1)[:, 0]
        if mode == MODE_ADD:
            new_val = jnp.where(has_match[:, None], old_val + val, val)
        elif mode == MODE_KEEP:
            new_val = jnp.where(has_match[:, None], old_val, val)
        else:
            new_val = val
        tk = jnp.where(onehot[:, :, None], key[:, None, :], tk)
        tv = jnp.where(onehot[:, :, None], new_val[:, None, :], tv)
        st = jnp.where(onehot, (st & ~_U32(_MASK)) | _U32(_READY), st)
        ok = ok.at[:, j].set(can)
        return tk, tv, st, ok

    ok0 = jnp.zeros((tb, q_cap), bool)
    tk, tv, st, ok = jax.lax.fori_loop(0, q_cap, body, (tk, tv, st, ok0))
    otk_ref[...] = tk
    otv_ref[...] = tv
    ost_ref[...] = st
    ok_ref[...] = ok.astype(_U32)


def insert(tkeys, tvals, status, qblock, qkeys, qvals, qvalid,
           mode: int = MODE_SET, q_cap: int | None = None,
           tile_blocks: int | None = None):
    """Pallas bulk insert; semantics == ref.hash_probe_insert_ref.

    Items that overflow a block's static query capacity fail (success
    False) exactly like a full block — callers already retry those.
    """
    nb, bsz, lk = tkeys.shape
    lv = tvals.shape[2]
    m = qblock.shape[0]
    q_cap = q_cap or default_q_cap(m, nb)
    tb = tile_blocks or (8 if nb % 8 == 0 else 1)

    slot, overflow = bin_queries(qblock, qvalid, nb, q_cap)
    qk = _scatter_to_bins(qkeys, slot, nb, q_cap, lk)
    qv = _scatter_to_bins(qvals, slot, nb, q_cap, lv)
    qval = _scatter_to_bins(qvalid.astype(_U32), slot, nb, q_cap, 1)[..., 0]

    grid = (nb // tb,)
    kern = functools.partial(_insert_kernel, mode=mode, q_cap=q_cap,
                             block_size=bsz)
    otk, otv, ost, okbins = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, bsz, lk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz, lv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, q_cap, lk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, q_cap, lv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, q_cap), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, bsz, lk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz, lv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, q_cap), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bsz, lk), _U32),
            jax.ShapeDtypeStruct((nb, bsz, lv), _U32),
            jax.ShapeDtypeStruct((nb, bsz), _U32),
            jax.ShapeDtypeStruct((nb, q_cap), _U32),
        ],
        interpret=_interpret(),
    )(tkeys, tvals, status, qk, qv, qval)

    flat_ok = okbins.reshape(-1)
    success = jnp.zeros((m,), bool)
    take = jnp.minimum(slot, nb * q_cap - 1)
    success = jnp.where(slot < nb * q_cap, flat_ok[take] == 1, False)
    success = success & ~overflow & qvalid
    return otk, otv, ost, success


def _insert_arrivals_kernel(tk_ref, tv_ref, st_ref, comb_ref,
                            otk_ref, otv_ref, ost_ref, ok_ref, *, mode: int,
                            q_cap: int, block_size: int, lk: int, lv: int):
    """Insert straight off the combined arrival tile (DESIGN.md §1.10).

    ``comb_ref`` holds one (TB, Q, lk+lv+1) tile of the wire's arrival
    rows — key lanes, value lanes, validity — binned by ONE scatter on
    the host side instead of one per component; the kernel slices the
    columns (static slices on the VMEM block, free) and then runs the
    exact :func:`_insert_kernel` ownership-serialized loop.
    """
    tk = tk_ref[...]          # (TB, B, Lk)
    tv = tv_ref[...]          # (TB, B, Lv)
    st = st_ref[...]          # (TB, B)
    tb = tk.shape[0]

    def body(j, carry):
        tk, tv, st, ok = carry
        row = jax.lax.dynamic_slice_in_dim(comb_ref[...], j, 1,
                                           axis=1)[:, 0]  # (TB, L)
        key = row[:, :lk]
        val = row[:, lk:lk + lv]
        vld = row[:, lk + lv]
        state = st & _MASK
        match = (tk == key[:, None, :]).all(axis=2) & (state == _READY)
        has_match = match.any(axis=1)
        free = state == _FREE
        has_free = free.any(axis=1)
        mslot = jnp.argmax(match, axis=1)
        fslot = jnp.argmax(free, axis=1)
        slot = jnp.where(has_match, mslot, fslot)
        can = (vld == 1) & (has_match | has_free)

        onehot = (jax.lax.broadcasted_iota(_I32, (tb, block_size), 1)
                  == slot[:, None]) & can[:, None]
        old_val = jnp.take_along_axis(tv, slot[:, None, None], axis=1)[:, 0]
        if mode == MODE_ADD:
            new_val = jnp.where(has_match[:, None], old_val + val, val)
        elif mode == MODE_KEEP:
            new_val = jnp.where(has_match[:, None], old_val, val)
        else:
            new_val = val
        tk = jnp.where(onehot[:, :, None], key[:, None, :], tk)
        tv = jnp.where(onehot[:, :, None], new_val[:, None, :], tv)
        st = jnp.where(onehot, (st & ~_U32(_MASK)) | _U32(_READY), st)
        ok = ok.at[:, j].set(can)
        return tk, tv, st, ok

    ok0 = jnp.zeros((tb, q_cap), bool)
    tk, tv, st, ok = jax.lax.fori_loop(0, q_cap, body, (tk, tv, st, ok0))
    otk_ref[...] = tk
    otv_ref[...] = tv
    ost_ref[...] = st
    ok_ref[...] = ok.astype(_U32)


def insert_arrivals(tkeys, tvals, status, seg, valid,
                    mode: int = MODE_SET, q_cap: int | None = None,
                    tile_blocks: int | None = None):
    """Bulk insert consuming the contiguous arrival segment directly.

    ``seg`` is the exchange wire's (M, 1+Lk+Lv) owner view — local
    block, key lanes, value lanes — exactly as sliced off the arrival
    buffer.  Semantics == :func:`insert` on the sliced columns, but the
    host side bins with ONE combined scatter instead of three, so the
    arrivals cross HBM once before the probe.
    """
    nb, bsz, lk = tkeys.shape
    lv = tvals.shape[2]
    m = seg.shape[0]
    q_cap = q_cap or default_q_cap(m, nb)
    tb = tile_blocks or (8 if nb % 8 == 0 else 1)

    qblock = jnp.where(valid, seg[:, 0].astype(_I32), 0)
    slot, overflow = bin_queries(qblock, valid, nb, q_cap)
    comb = jnp.concatenate([seg[:, 1:1 + lk + lv].astype(_U32),
                            valid.astype(_U32)[:, None]], axis=1)
    cb = _scatter_to_bins(comb, slot, nb, q_cap, lk + lv + 1)

    grid = (nb // tb,)
    kern = functools.partial(_insert_arrivals_kernel, mode=mode, q_cap=q_cap,
                            block_size=bsz, lk=lk, lv=lv)
    otk, otv, ost, okbins = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, bsz, lk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz, lv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, q_cap, lk + lv + 1), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, bsz, lk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz, lv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, q_cap), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, bsz, lk), _U32),
            jax.ShapeDtypeStruct((nb, bsz, lv), _U32),
            jax.ShapeDtypeStruct((nb, bsz), _U32),
            jax.ShapeDtypeStruct((nb, q_cap), _U32),
        ],
        interpret=_interpret(),
    )(tkeys, tvals, status, cb)

    flat_ok = okbins.reshape(-1)
    take = jnp.minimum(slot, nb * q_cap - 1)
    success = jnp.where(slot < nb * q_cap, flat_ok[take] == 1, False)
    success = success & ~overflow & valid
    return otk, otv, ost, success


# --------------------------------------------------------------------------
# find kernel
# --------------------------------------------------------------------------

def _find_kernel(tk_ref, tv_ref, st_ref, qk_ref, qval_ref,
                 found_ref, val_ref, *, block_size: int):
    tk = tk_ref[...]                      # (TB, B, Lk)
    tv = tv_ref[...]                      # (TB, B, Lv)
    st = st_ref[...]                      # (TB, B)
    qk = qk_ref[...]                      # (TB, Q, Lk)
    vld = qval_ref[...] == 1              # (TB, Q)

    ready = (st & _MASK) == _READY        # (TB, B)
    match = (qk[:, :, None, :] == tk[:, None, :, :]).all(axis=3)
    match = match & ready[:, None, :]     # (TB, Q, B)
    found = match.any(axis=2) & vld
    # first matching slot, recovered via an integer gather (u32 values
    # would not survive an f32 MXU contraction above 2^24)
    first = match & (jnp.cumsum(match.astype(_I32), axis=2) == 1)
    slot = jnp.argmax(first, axis=2)      # (TB, Q)
    vals_exact = jnp.take_along_axis(tv, slot[:, :, None], axis=1)
    found_ref[...] = found.astype(_U32)
    val_ref[...] = jnp.where(found[:, :, None], vals_exact, 0)


def find(tkeys, tvals, status, qblock, qkeys, qvalid,
         q_cap: int | None = None, tile_blocks: int | None = None):
    """Pallas bulk find; semantics == ref.hash_probe_find_ref."""
    nb, bsz, lk = tkeys.shape
    lv = tvals.shape[2]
    m = qblock.shape[0]
    q_cap = q_cap or default_q_cap(m, nb)
    tb = tile_blocks or (8 if nb % 8 == 0 else 1)

    slot, overflow = bin_queries(qblock, qvalid, nb, q_cap)
    qk = _scatter_to_bins(qkeys, slot, nb, q_cap, lk)
    qval = _scatter_to_bins((qvalid & ~overflow).astype(_U32), slot,
                            nb, q_cap, 1)[..., 0]

    grid = (nb // tb,)
    kern = functools.partial(_find_kernel, block_size=bsz)
    foundb, valb = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, bsz, lk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz, lv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, q_cap, lk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, q_cap), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, q_cap), lambda i: (i, 0)),
            pl.BlockSpec((tb, q_cap, lv), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, q_cap), _U32),
            jax.ShapeDtypeStruct((nb, q_cap, lv), _U32),
        ],
        interpret=_interpret(),
    )(tkeys, tvals, status, qk, qval)

    flat_f = foundb.reshape(-1)
    flat_v = valb.reshape(-1, lv)
    take = jnp.minimum(slot, nb * q_cap - 1)
    in_range = slot < nb * q_cap
    found = jnp.where(in_range, flat_f[take] == 1, False) & qvalid & ~overflow
    vals = jnp.where(found[:, None], flat_v[take], 0)

    # overflow queries fall back to the direct jnp probe (rare, bounded)
    if True:
        from repro.kernels.ref import hash_probe_find_ref
        f2, v2 = hash_probe_find_ref(tkeys, tvals, status,
                                     jnp.clip(qblock, 0, nb - 1), qkeys,
                                     overflow)
        found = found | f2
        vals = jnp.where(f2[:, None], v2, vals)
    return found, vals


def _find_arrivals_kernel(tk_ref, tv_ref, st_ref, comb_ref,
                          found_ref, val_ref, *, block_size: int, lk: int):
    """:func:`_find_kernel` off the combined (TB, Q, lk+1) arrival tile:
    key lanes + validity binned by one host-side scatter, columns split
    in-kernel (static VMEM slices)."""
    tk = tk_ref[...]                      # (TB, B, Lk)
    tv = tv_ref[...]                      # (TB, B, Lv)
    st = st_ref[...]                      # (TB, B)
    comb = comb_ref[...]                  # (TB, Q, Lk+1)
    qk = comb[:, :, :lk]
    vld = comb[:, :, lk] == 1             # (TB, Q)

    ready = (st & _MASK) == _READY        # (TB, B)
    match = (qk[:, :, None, :] == tk[:, None, :, :]).all(axis=3)
    match = match & ready[:, None, :]     # (TB, Q, B)
    found = match.any(axis=2) & vld
    first = match & (jnp.cumsum(match.astype(_I32), axis=2) == 1)
    slot = jnp.argmax(first, axis=2)      # (TB, Q)
    vals_exact = jnp.take_along_axis(tv, slot[:, :, None], axis=1)
    found_ref[...] = found.astype(_U32)
    val_ref[...] = jnp.where(found[:, :, None], vals_exact, 0)


def find_arrivals(tkeys, tvals, status, seg, valid,
                  q_cap: int | None = None, tile_blocks: int | None = None):
    """Bulk find consuming the contiguous arrival segment directly.

    ``seg`` is the wire's (M, 1+Lk) owner view (local block + key
    lanes); results are bit-identical to :func:`find` on the sliced
    columns, with the arrivals binned by ONE combined scatter.
    """
    nb, bsz, lk = tkeys.shape
    lv = tvals.shape[2]
    m = seg.shape[0]
    q_cap = q_cap or default_q_cap(m, nb)
    tb = tile_blocks or (8 if nb % 8 == 0 else 1)

    qblock = jnp.where(valid, seg[:, 0].astype(_I32), 0)
    qkeys = seg[:, 1:1 + lk]
    slot, overflow = bin_queries(qblock, valid, nb, q_cap)
    comb = jnp.concatenate([qkeys.astype(_U32),
                            (valid & ~overflow).astype(_U32)[:, None]],
                           axis=1)
    cb = _scatter_to_bins(comb, slot, nb, q_cap, lk + 1)

    grid = (nb // tb,)
    kern = functools.partial(_find_arrivals_kernel, block_size=bsz, lk=lk)
    foundb, valb = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, bsz, lk), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz, lv), lambda i: (i, 0, 0)),
            pl.BlockSpec((tb, bsz), lambda i: (i, 0)),
            pl.BlockSpec((tb, q_cap, lk + 1), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tb, q_cap), lambda i: (i, 0)),
            pl.BlockSpec((tb, q_cap, lv), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, q_cap), _U32),
            jax.ShapeDtypeStruct((nb, q_cap, lv), _U32),
        ],
        interpret=_interpret(),
    )(tkeys, tvals, status, cb)

    flat_f = foundb.reshape(-1)
    flat_v = valb.reshape(-1, lv)
    take = jnp.minimum(slot, nb * q_cap - 1)
    in_range = slot < nb * q_cap
    found = jnp.where(in_range, flat_f[take] == 1, False) & valid & ~overflow
    vals = jnp.where(found[:, None], flat_v[take], 0)

    from repro.kernels.ref import hash_probe_find_ref
    f2, v2 = hash_probe_find_ref(tkeys, tvals, status,
                                 jnp.clip(qblock, 0, nb - 1), qkeys, overflow)
    found = found | f2
    vals = jnp.where(f2[:, None], v2, vals)
    return found, vals
