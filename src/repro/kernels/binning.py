"""Pallas TPU kernel: destination histogram for the exchange engine / ISx.

The distribution stage of ISx (paper section 9.1) bins every key to a
destination bucket.  On TPU the per-tile histogram is a one-hot
contraction — an (1, TM) x (TM, NB) matmul that runs on the MXU — with
partial histograms accumulated across grid steps in the output block
(all grid steps map to the same output tile; step 0 initializes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_I32 = jnp.int32
_F32 = jnp.float32


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _hist_kernel(bins_ref, valid_ref, out_ref, *, nbins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...].astype(_I32)            # (TM,)
    valid = valid_ref[...].astype(_F32)          # (TM,)
    onehot = (bins[:, None] ==
              jax.lax.broadcasted_iota(_I32, (bins.shape[0], nbins), 1))
    # (1, TM) @ (TM, NB) on the MXU
    part = jnp.dot(valid[None, :], onehot.astype(_F32),
                   preferred_element_type=_F32)[0]
    out_ref[...] = out_ref[...] + part.astype(_I32)


def histogram(bins: jax.Array, nbins: int, valid: jax.Array | None = None,
              tile: int = 2048) -> jax.Array:
    """Count items per destination bin; oracle: ref.bin_histogram_ref."""
    m = bins.shape[0]
    if valid is None:
        valid = jnp.ones((m,), bool)
    pad = (-m) % tile
    if pad:
        bins = jnp.pad(bins, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    mp = bins.shape[0]
    kern = functools.partial(_hist_kernel, nbins=nbins)
    return pl.pallas_call(
        kern,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), _I32),
        interpret=_interpret(),
    )(bins.astype(_I32), valid)
