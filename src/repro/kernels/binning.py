"""Pallas TPU kernel: destination histogram for the exchange engine / ISx.

The distribution stage of ISx (paper section 9.1) bins every key to a
destination bucket.  On TPU the per-tile histogram is a one-hot
contraction — an (1, TM) x (TM, NB) matmul that runs on the MXU — with
partial histograms accumulated across grid steps in the output block
(all grid steps map to the same output tile; step 0 initializes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import interpret_mode as _interpret

_I32 = jnp.int32
_F32 = jnp.float32
_U32 = jnp.uint32


def _hist_kernel(bins_ref, valid_ref, out_ref, *, nbins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...].astype(_I32)            # (TM,)
    valid = valid_ref[...].astype(_F32)          # (TM,)
    onehot = (bins[:, None] ==
              jax.lax.broadcasted_iota(_I32, (bins.shape[0], nbins), 1))
    # (1, TM) @ (TM, NB) on the MXU
    part = jnp.dot(valid[None, :], onehot.astype(_F32),
                   preferred_element_type=_F32)[0]
    out_ref[...] = out_ref[...] + part.astype(_I32)


def _offsets_kernel(bins_ref, valid_ref, counts_ref, off_ref, *, nbins: int):
    """Histogram -> per-tile prefix -> per-item slot offset.

    Grid steps run in order on TPU, so ``counts_ref`` (all steps map to
    the same output tile) doubles as the running cross-tile prefix: at
    step t it holds the per-bin counts of tiles [0, t), which is exactly
    the base offset every item of tile t adds to its within-tile rank.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    bins = bins_ref[...].astype(_I32)                      # (TM,)
    valid = valid_ref[...]                                 # (TM,)
    tm = bins.shape[0]
    onehot = ((bins[:, None] ==
               jax.lax.broadcasted_iota(_I32, (tm, nbins), 1))
              & valid[:, None]).astype(_I32)               # (TM, NB)
    # stable within-tile rank: exclusive cumsum down each bin column
    within = jnp.cumsum(onehot, axis=0) - onehot
    base = counts_ref[...]                                 # tiles [0, i)
    off_ref[...] = ((within + base[None, :]) * onehot).sum(axis=1)
    # fold this tile's histogram into the running counts on the MXU
    part = jnp.dot(jnp.ones((1, tm), _F32), onehot.astype(_F32),
                   preferred_element_type=_F32)[0]
    counts_ref[...] = base + part.astype(_I32)


def bin_offsets(bins: jax.Array, nbins: int, valid: jax.Array | None = None,
                tile: int = 2048):
    """Exchange send-buffer construction; oracle: ref.bin_offsets_ref.

    Returns ``(counts (nbins,), offsets (N,))`` — per-destination valid
    counts and each item's stable position within its destination bucket.
    Replaces the argsort+gather hot path: the caller scatters payload
    rows straight to ``dest * capacity + offsets``.  The ExchangePlan
    scheduler's segmented multi-flow slot assignment
    (``kernels/ops.py::multi_bin_offsets``) feeds this same kernel
    composite ``dest * nflows + flow`` bins, so one launch bins every
    flow of a fused round.
    """
    m = bins.shape[0]
    if valid is None:
        valid = jnp.ones((m,), bool)
    pad = (-m) % tile
    if pad:
        bins = jnp.pad(bins, (0, pad), constant_values=nbins)
        valid = jnp.pad(valid, (0, pad))
    mp = bins.shape[0]
    kern = functools.partial(_offsets_kernel, nbins=nbins)
    counts, offs = pl.pallas_call(
        kern,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((nbins,), lambda i: (0,)),
                   pl.BlockSpec((tile,), lambda i: (i,))],
        out_shape=(jax.ShapeDtypeStruct((nbins,), _I32),
                   jax.ShapeDtypeStruct((mp,), _I32)),
        interpret=_interpret(),
    )(bins.astype(_I32), valid)
    return counts, offs[:m]


def _ragged_slots_kernel(bins_ref, flow_ref, off_ref, valid_ref,
                         woff_ref, roww_ref, caps_ref, rounds_ref,
                         slot_ref, *, nflows: int, rnd: int, wtot: int,
                         sentinel: int):
    """Per-item ragged word slot off the ONE binning pass.

    Flow tables (word offset, row words, capacity, rounds) are gathered
    by flow id via a one-hot contraction (nflows is tiny), then the
    retry-round window ``[rnd*C_f, (rnd+1)*C_f)`` masks which items ride
    this launch — the §1.6 mask and the §1.5 ragged layout fused into
    one elementwise pass, with no second binning.
    """
    bins = bins_ref[...].astype(_I32)
    flow = flow_ref[...].astype(_I32)
    off = off_ref[...].astype(_I32)
    valid = valid_ref[...]
    tm = bins.shape[0]
    oh = (flow[:, None] ==
          jax.lax.broadcasted_iota(_I32, (tm, nflows), 1)).astype(_I32)

    def sel(tbl_ref):
        return (oh * tbl_ref[...][None, :]).sum(axis=1)

    woff_i, roww_i = sel(woff_ref), sel(roww_ref)
    cap_i, rnds_i = sel(caps_ref), sel(rounds_ref)
    off_r = off - rnd * cap_i
    in_r = valid & (rnds_i > rnd) & (off_r >= 0) & (off_r < cap_i)
    slot_ref[...] = jnp.where(in_r, bins * wtot + woff_i + off_r * roww_i,
                              sentinel)


def ragged_slots(bins: jax.Array, flow: jax.Array, offsets: jax.Array,
                 valid: jax.Array, rnd: int, word_off: jax.Array,
                 row_words: jax.Array, caps: jax.Array, rounds: jax.Array,
                 wtot: int, sentinel: int, tile: int = 2048) -> jax.Array:
    """Ragged send-buffer word slots for retry round ``rnd``.

    Item ``i`` of flow ``f = flow[i]`` with within-(dest, flow)-bucket
    rank ``offsets[i]`` (from :func:`bin_offsets`) starts at word
    ``bins[i]*wtot + word_off[f] + (offsets[i] - rnd*caps[f]) *
    row_words[f]`` of the flat fused wire iff its rank falls in round
    ``rnd``'s capacity window and the flow is still retrying; every
    other item gets ``sentinel`` (a drop index past the buffer).
    Oracle: the pure-jnp gather in ``kernels/ops.py::ragged_slots``.
    """
    m = bins.shape[0]
    nflows = word_off.shape[0]
    pad = (-m) % tile
    if pad:
        bins = jnp.pad(bins, (0, pad))
        flow = jnp.pad(flow, (0, pad))
        offsets = jnp.pad(offsets, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    mp = bins.shape[0]
    kern = functools.partial(_ragged_slots_kernel, nflows=nflows,
                             rnd=rnd, wtot=wtot, sentinel=sentinel)
    full = lambda i: (0,)
    slots = pl.pallas_call(
        kern,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((nflows,), full),
                  pl.BlockSpec((nflows,), full),
                  pl.BlockSpec((nflows,), full),
                  pl.BlockSpec((nflows,), full)],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), _I32),
        interpret=_interpret(),
    )(bins.astype(_I32), flow.astype(_I32), offsets.astype(_I32), valid,
      word_off.astype(_I32), row_words.astype(_I32), caps.astype(_I32),
      rounds.astype(_I32))
    return slots[:m]


def _pack_rows_kernel(rows_ref, bins_ref, flow_ref, off_ref, valid_ref,
                      woff_ref, roww_ref, caps_ref, rounds_ref,
                      out_ref, *, nflows: int, rnd: int, wtot: int,
                      total: int, wmax: int):
    """Slot computation + row scatter fused: one pass writes the wire.

    Same slot math as :func:`_ragged_slots_kernel`, but instead of
    emitting the slot vector for an XLA ``.at[].set`` to consume (one
    extra HBM round trip over the rows), each tile scatters its rows
    straight into the flat send buffer held in the output block.  All
    grid steps map the same (total,) block; step 0 zero-fills.  Lanes at
    or past a row's flow width, rows outside the round window, and
    sentinel rows all index past ``total`` and drop.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bins = bins_ref[...].astype(_I32)
    flow = flow_ref[...].astype(_I32)
    off = off_ref[...].astype(_I32)
    valid = valid_ref[...]
    tm = bins.shape[0]
    oh = (flow[:, None] ==
          jax.lax.broadcasted_iota(_I32, (tm, nflows), 1)).astype(_I32)

    def sel(tbl_ref):
        return (oh * tbl_ref[...][None, :]).sum(axis=1)

    woff_i, roww_i = sel(woff_ref), sel(roww_ref)
    cap_i, rnds_i = sel(caps_ref), sel(rounds_ref)
    off_r = off - rnd * cap_i
    in_r = valid & (rnds_i > rnd) & (off_r >= 0) & (off_r < cap_i)
    slot = jnp.where(in_r, bins * wtot + woff_i + off_r * roww_i, total)
    lane = jax.lax.broadcasted_iota(_I32, (tm, wmax), 1)
    idx = jnp.where((lane < roww_i[:, None]) & in_r[:, None],
                    slot[:, None] + lane, total)
    buf = out_ref[...]
    out_ref[...] = buf.at[idx.reshape(-1)].set(
        rows_ref[...].astype(_U32).reshape(-1), mode="drop")


def pack_rows(rows: jax.Array, bins: jax.Array, flow: jax.Array,
              offsets: jax.Array, valid: jax.Array, rnd: int,
              word_off: jax.Array, row_words: jax.Array, caps: jax.Array,
              rounds: jax.Array, wtot: int, total: int,
              tile: int = 2048) -> jax.Array:
    """Fused ragged wire pack: one kernel, one HBM write of the buffer.

    ``rows`` is the (N, wmax) right-padded u32 row matrix (flow ``f``
    uses its first ``row_words[f]`` lanes); the result is the flat
    ``(total,)`` u32 send buffer that :func:`ragged_slots` +
    ``object_container.scatter_rows`` would produce in two passes.
    Oracle: the jnp path of ``kernels/ops.py::pack_rows``.
    """
    m, wmax = rows.shape
    nflows = word_off.shape[0]
    pad = (-m) % tile
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
        bins = jnp.pad(bins, (0, pad))
        flow = jnp.pad(flow, (0, pad))
        offsets = jnp.pad(offsets, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    mp = bins.shape[0]
    kern = functools.partial(_pack_rows_kernel, nflows=nflows, rnd=rnd,
                             wtot=wtot, total=total, wmax=wmax)
    full = lambda i: (0,)
    return pl.pallas_call(
        kern,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((tile, wmax), lambda i: (i, 0)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((nflows,), full),
                  pl.BlockSpec((nflows,), full),
                  pl.BlockSpec((nflows,), full),
                  pl.BlockSpec((nflows,), full)],
        out_specs=pl.BlockSpec((total,), full),
        out_shape=jax.ShapeDtypeStruct((total,), _U32),
        interpret=_interpret(),
    )(rows.astype(_U32), bins.astype(_I32), flow.astype(_I32),
      offsets.astype(_I32), valid, word_off.astype(_I32),
      row_words.astype(_I32), caps.astype(_I32), rounds.astype(_I32))


def _place_rows_kernel(dst_ref, slot_ref, rows_ref, out_ref, *,
                       total: int, w: int):
    """Scatter fixed-width rows at precomputed word slots, in-kernel.

    The output block starts as a copy of ``dst`` (step 0) and each tile
    folds its rows in; a slot at or past ``total`` drops its row.  Used
    where the wire slots are analytic (dense replies, owner-side
    assembly by hop/slot lane) so even those writes stay off XLA's
    scatter path.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = dst_ref[...]

    slot = slot_ref[...].astype(_I32)
    tm = slot.shape[0]
    lane = jax.lax.broadcasted_iota(_I32, (tm, w), 1)
    idx = jnp.where(slot[:, None] < total, slot[:, None] + lane, total)
    buf = out_ref[...]
    out_ref[...] = buf.at[idx.reshape(-1)].set(
        rows_ref[...].astype(_U32).reshape(-1), mode="drop")


def place_rows(dst: jax.Array, slots: jax.Array, rows: jax.Array,
               tile: int = 2048) -> jax.Array:
    """In-kernel ``scatter_rows``: pack (N, W) rows into ``dst`` words.

    Bit-identical to ``object_container.scatter_rows(dst, slots, rows)``
    (the jnp oracle) including the drop-on-sentinel contract; rows whose
    slot is ``>= dst.size`` are dropped whole.
    """
    m, w = rows.shape
    total = dst.shape[0]
    pad = (-m) % tile
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
        slots = jnp.pad(slots, (0, pad), constant_values=total)
    mp = slots.shape[0]
    kern = functools.partial(_place_rows_kernel, total=total, w=w)
    full = lambda i: (0,)
    return pl.pallas_call(
        kern,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((total,), full),
                  pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((total,), full),
        out_shape=jax.ShapeDtypeStruct((total,), _U32),
        interpret=_interpret(),
    )(dst.astype(_U32), slots.astype(_I32), rows.astype(_U32))


def _row_mix_kernel(rows_ref, out_ref, *, lanes: int):
    """Per-row wire-checksum hash: weighted lane sum + fmix32 avalanche.

    All arithmetic is wrapping u32 so the kernel is bit-identical to the
    jnp lowering in ``kernels/ops.py::mix_rows`` (the sender and owner
    sides of an integrity-checked exchange must agree exactly).
    """
    rows = rows_ref[...].astype(_U32)                    # (TM, L)
    mult = (_U32(0x9E3779B1)
            * (jax.lax.broadcasted_iota(_U32, (1, lanes), 1) * _U32(2)
               + _U32(1)))
    h = jnp.sum(rows * mult, axis=1, dtype=_U32)
    h = h ^ (h >> 16)
    h = h * _U32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * _U32(0xC2B2AE35)
    h = h ^ (h >> 16)
    out_ref[...] = h


def row_mix(rows: jax.Array, tile: int = 2048) -> jax.Array:
    """Per-row u32 hash of a lane matrix; oracle: ops.mix_rows jnp path."""
    m, lanes = rows.shape
    pad = (-m) % tile
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
    mp = rows.shape[0]
    kern = functools.partial(_row_mix_kernel, lanes=lanes)
    out = pl.pallas_call(
        kern,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((tile, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), _U32),
        interpret=_interpret(),
    )(rows.astype(_U32))
    return out[:m]


def histogram(bins: jax.Array, nbins: int, valid: jax.Array | None = None,
              tile: int = 2048) -> jax.Array:
    """Count items per destination bin; oracle: ref.bin_histogram_ref."""
    m = bins.shape[0]
    if valid is None:
        valid = jnp.ones((m,), bool)
    pad = (-m) % tile
    if pad:
        bins = jnp.pad(bins, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    mp = bins.shape[0]
    kern = functools.partial(_hist_kernel, nbins=nbins)
    return pl.pallas_call(
        kern,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), _I32),
        interpret=_interpret(),
    )(bins.astype(_I32), valid)
