"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three ways (see ops.py): a sequential-semantics oracle
(ref.py), a vectorized jnp implementation, and the Pallas kernel proper
(pl.pallas_call + BlockSpec VMEM tiling, interpret=True on CPU).

  hash_probe       blocked open-addressing insert/find (DHashMap)
  bloom_kernel     blocked Bloom hashing + membership
  binning          destination histogram (exchange engine / ISx)
  flash_attention  fused online-softmax attention (LM hot spot)
"""

from repro.kernels import ops, ref  # noqa: F401
