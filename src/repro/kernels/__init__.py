"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three ways (see ops.py): a sequential-semantics oracle
(ref.py), a vectorized jnp implementation, and the Pallas kernel proper
(pl.pallas_call + BlockSpec VMEM tiling, interpret=True on CPU).

  hash_probe       blocked open-addressing insert/find (DHashMap)
  bloom_kernel     blocked Bloom hashing + membership
  binning          destination histogram (exchange engine / ISx)
  flash_attention  fused online-softmax attention (LM hot spot)
"""

import jax


def interpret_mode() -> bool:
    """Whether pallas_call should run in interpret mode (non-TPU hosts).

    Shared by every kernel module so the backend check lives in exactly
    one place; kernels pass ``interpret=interpret_mode()`` to
    ``pl.pallas_call``.
    """
    return jax.default_backend() != "tpu"


from repro.kernels import ops, ref  # noqa: E402,F401
