"""Pallas TPU kernel: fused (flash) attention forward.

The LM framework's compute hot spot.  Online-softmax attention with
causal and sliding-window masking and GQA (q-head groups share a kv
head via the BlockSpec index map — no KV replication in memory).

Grid: (batch, q_heads, Tq/BQ, Tk/BK); the last dim is a reduction —
running max / normalizer / accumulator live in VMEM scratch and the
output tile is written on the final reduction step.

VMEM per step at defaults (BQ=BK=128, D=128, f32):
q,k,v tiles 3*128*128*4 = 192 KiB + acc 64 KiB — fine.

On CPU this runs in interpret mode for correctness only; the model
stack uses the XLA path by default (see models/attention.py) so that
dry-run cost analysis sees the attention FLOPs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interpret_mode as _interpret

_F32 = jnp.float32
_NEG_INF = -1e30



def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, tq: int, tk: int):
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(_F32)          # (BQ, D)
    k = k_ref[0, 0].astype(_F32)          # (BK, D)
    v = v_ref[0, 0].astype(_F32)          # (BK, D)

    s = jnp.dot(q, k.T, preferred_element_type=_F32) * scale   # (BQ, BK)

    # global positions: queries are suffix-aligned to keys (decode support)
    iq = pl.program_id(2)
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (tk - tq)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_scr[...]                   # (BQ, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                # (BQ, BK)
    alpha = jnp.exp(m_prev - m_new)       # (BQ, 1)
    l_new = alpha * l_scr[...] + p.sum(axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jnp.dot(p, v, preferred_element_type=_F32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(j == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q (B,Hq,Tq,D), k/v (B,Hkv,Tk,D) -> (B,Hq,Tq,D).

    Oracle: ref.flash_attention_ref (suffix-aligned causal + window).
    """
    b, hq, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    rep = hq // hkv
    bq = min(block_q, tq)
    bk = min(block_k, tk)
    pad_q = (-tq) % bq
    pad_k = (-tk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    tqp, tkp = tq + pad_q, tk + pad_k

    # padded key positions must never win the mask: suffix alignment uses
    # the ORIGINAL tq/tk so padded keys (kpos >= tk) are masked by causal;
    # for non-causal pure-window we extend the window mask below.
    grid = (b, hq, tqp // bq, tkp // bk)
    kern = functools.partial(
        _flash_kernel, scale=1.0 / (d ** 0.5),
        causal=causal, window=(window if window > 0 else (tk if not causal else 0)),
        bq=bq, bk=bk, tq=tq, tk=tk)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, rep=rep: (b_, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, rep=rep: (b_, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, tqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), _F32),    # running max
            pltpu.VMEM((bq, 1), _F32),    # running normalizer
            pltpu.VMEM((bq, d), _F32),    # output accumulator
        ],
        interpret=_interpret(),
    )(q, k, v)
    return out[:, :, :tq]
