"""Public kernel API with implementation dispatch.

Every op comes in up to three implementations:

  impl="oracle"  sequential-semantics pure-jnp oracle (ref.py)
  impl="jnp"     vectorized pure-jnp (sort + segment ops) — the CPU
                 production path and the second correctness witness
  impl="pallas"  the Pallas TPU kernel (interpret=True on CPU)

``impl="auto"`` picks "pallas" on TPU and "jnp" elsewhere.  Containers
call through this module only.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.ref import (FREE, READY, STATE_MASK, bucket_state,  # noqa: F401
                               MODE_SET, MODE_ADD, MODE_KEEP)

_U32 = jnp.uint32
_I32 = jnp.int32


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def _resolve(impl: str) -> str:
    return default_impl() if impl == "auto" else impl


# --------------------------------------------------------------------------
# segmented scan helpers
# --------------------------------------------------------------------------

def seg_exclusive_or_scan(words: jax.Array, seg_start: jax.Array) -> jax.Array:
    """Exclusive segmented bitwise-OR scan over rows (segments contiguous).

    words: (M, L) u32; seg_start: (M,) bool marking segment heads.
    Row i receives the OR of earlier rows in its segment (0 at heads).
    """
    def combine(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(fb[:, None], vb, va | vb)

    flags = seg_start
    incl_f, incl_v = jax.lax.associative_scan(combine, (flags, words))
    del incl_f
    # exclusive = inclusive shifted down by one, zeroed at segment heads
    shifted = jnp.concatenate([jnp.zeros_like(words[:1]), incl_v[:-1]], axis=0)
    return jnp.where(seg_start[:, None], jnp.zeros_like(words), shifted)


def _lexsort_items(qblock, qkeys, qvalid, nb):
    """Stable order grouping items by (block, key lanes); invalid last."""
    b = jnp.where(qvalid, qblock.astype(_I32), nb)
    keys = [qkeys[:, i] for i in range(qkeys.shape[1] - 1, -1, -1)] + [b]
    order = jnp.lexsort(keys)
    return order, b[order]


# --------------------------------------------------------------------------
# blocked hash table: bulk insert
# --------------------------------------------------------------------------

def bulk_insert(tkeys, tvals, status, qblock, qkeys, qvals, qvalid,
                mode: int = MODE_SET, impl: str = "auto"):
    """Insert a batch into the blocked table; see ref.hash_probe_insert_ref.

    Vectorized semantics match the sequential oracle for any batch,
    including duplicate keys (SET keeps the last duplicate's value, ADD
    accumulates, KEEP keeps the first).
    Returns (tkeys, tvals, status, success(M,)).
    """
    impl = _resolve(impl)
    if impl == "oracle":
        return _ref.hash_probe_insert_ref(tkeys, tvals, status, qblock,
                                          qkeys, qvals, qvalid, mode)
    if impl == "pallas":
        from repro.kernels import hash_probe
        return hash_probe.insert(tkeys, tvals, status, qblock, qkeys,
                                 qvals, qvalid, mode)

    nb, bsz, lk = tkeys.shape
    m = qblock.shape[0]
    lv = qvals.shape[1]

    order, sb = _lexsort_items(qblock, qkeys, qvalid, nb)
    sk = qkeys[order]
    sv = qvals[order]
    svalid = qvalid[order]
    idx = jnp.arange(m, dtype=_I32)

    prev_same = jnp.concatenate([
        jnp.zeros((1,), bool),
        (sb[1:] == sb[:-1]) & (sk[1:] == sk[:-1]).all(axis=1)])
    is_leader = svalid & ~prev_same
    group_id = jnp.cumsum(is_leader.astype(_I32)) - 1          # (M,)
    group_id = jnp.maximum(group_id, 0)

    # combine duplicate values per group, honoring batch order
    if mode == MODE_ADD:
        gval = jnp.zeros((m, lv), _U32).at[group_id].add(
            jnp.where(svalid[:, None], sv, 0))
    elif mode == MODE_SET:   # last duplicate wins
        last_pos = jnp.full((m,), -1, _I32).at[group_id].max(
            jnp.where(svalid, idx, -1))
        gval = sv[jnp.maximum(last_pos, 0)]
    else:                     # MODE_KEEP: first duplicate (== leader row)
        gval = jnp.zeros((m, lv), _U32).at[group_id].add(
            jnp.where((is_leader & svalid)[:, None], sv, 0))
    leader_val = gval[group_id]   # value each leader should write

    # probe each leader's block
    blk_keys = tkeys[sb % nb]                                   # (M, B, Lk)
    blk_stat = status[sb % nb]                                  # (M, B)
    match = (blk_keys == sk[:, None, :]).all(axis=2) & (bucket_state(blk_stat) == READY)
    found = match.any(axis=1) & is_leader
    mslot = jnp.argmax(match, axis=1).astype(_I32)

    # free-slot ranking per block
    free_mask = bucket_state(status) == FREE                                  # (nb, B)
    free_order = jnp.argsort(~free_mask, axis=1).astype(_I32)   # free first
    nfree = free_mask.sum(axis=1).astype(_I32)                  # (nb,)

    new_leader = is_leader & ~found
    # Rank each new leader within its block by ORIGINAL batch position, so
    # free slots are claimed in the same order the sequential oracle claims
    # them (this fixes which items fail when a block overflows).
    orig_idx = order.astype(_I32)
    ord2 = jnp.lexsort((jnp.where(new_leader, orig_idx, m),
                        jnp.where(new_leader, sb, nb)))
    nl2 = new_leader[ord2]
    sb2 = jnp.where(nl2, sb[ord2], nb)
    blk_change2 = jnp.concatenate([jnp.ones((1,), bool), sb2[1:] != sb2[:-1]])
    seg2 = jnp.cumsum(blk_change2.astype(_I32)) - 1
    incl2 = jnp.cumsum(nl2.astype(_I32))
    ex2 = incl2 - nl2.astype(_I32)
    base2 = jnp.zeros((m,), _I32).at[seg2].add(jnp.where(blk_change2, ex2, 0))
    r2 = ex2 - base2[seg2]
    r = jnp.zeros((m,), _I32).at[ord2].set(r2)                  # (M,)

    sb_c = jnp.clip(sb, 0, nb - 1)
    has_room = r < nfree[sb_c]
    slot_new = free_order[sb_c, jnp.clip(r, 0, bsz - 1)]
    slot = jnp.where(found, mslot, slot_new)
    ok_leader = is_leader & (found | (new_leader & has_room))

    # value to store
    old_val = tvals[sb_c, slot]
    if mode == MODE_ADD:
        store_val = jnp.where(found[:, None], old_val + leader_val, leader_val)
    elif mode == MODE_KEEP:
        store_val = jnp.where(found[:, None], old_val, leader_val)
    else:
        store_val = leader_val

    wb = jnp.where(ok_leader, sb_c, nb)    # drop sentinel
    tkeys = tkeys.at[wb, slot].set(sk, mode="drop")
    tvals = tvals.at[wb, slot].set(store_val, mode="drop")
    old_st = status[sb_c, slot]
    status = status.at[wb, slot].set((old_st & ~STATE_MASK) | READY,
                                     mode="drop")

    # per-item success = its group leader's success
    succ_g = jnp.zeros((m,), _I32).at[group_id].add(
        (ok_leader & is_leader).astype(_I32))
    succ_sorted = (succ_g[group_id] > 0) & svalid
    success = jnp.zeros((m,), bool).at[order].set(succ_sorted)
    return tkeys, tvals, status, success


def bulk_find(tkeys, tvals, status, qblock, qkeys, qvalid, impl: str = "auto"):
    """Batch find; returns (found(M,), values(M,Lv))."""
    impl = _resolve(impl)
    if impl == "pallas":
        from repro.kernels import hash_probe
        return hash_probe.find(tkeys, tvals, status, qblock, qkeys, qvalid)
    return _ref.hash_probe_find_ref(tkeys, tvals, status, qblock, qkeys, qvalid)


def bulk_find_arrivals(tkeys, tvals, status, seg, valid, impl: str = "auto"):
    """Batch find off the contiguous (M, 1+Lk) arrival segment.

    ``seg`` is an exchange owner view — local block in lane 0, key lanes
    after — consumed as-is (DESIGN.md section 1.10): the Pallas path
    bins the combined segment with ONE scatter and splits columns
    in-kernel, so no intermediate lane matrices cross HBM.  The jnp and
    oracle paths slice the columns and run :func:`bulk_find` — the
    fallback/oracle, bit-identical by construction.
    """
    impl = _resolve(impl)
    if impl == "pallas":
        from repro.kernels import hash_probe
        return hash_probe.find_arrivals(tkeys, tvals, status, seg, valid)
    lk = tkeys.shape[2]
    qblock = jnp.where(valid, seg[:, 0].astype(_I32), 0)
    return bulk_find(tkeys, tvals, status, qblock, seg[:, 1:1 + lk], valid,
                     impl=impl)


def bulk_insert_arrivals(tkeys, tvals, status, seg, valid,
                         mode: int = MODE_SET, impl: str = "auto"):
    """Batch insert off the contiguous (M, 1+Lk+Lv) arrival segment.

    Arrival-buffer twin of :func:`bulk_insert` (see
    :func:`bulk_find_arrivals` for the layout and the HBM argument).
    Returns (tkeys, tvals, status, success(M,)).
    """
    impl = _resolve(impl)
    if impl == "pallas":
        from repro.kernels import hash_probe
        return hash_probe.insert_arrivals(tkeys, tvals, status, seg, valid,
                                          mode)
    lk = tkeys.shape[2]
    qblock = jnp.where(valid, seg[:, 0].astype(_I32), 0)
    return bulk_insert(tkeys, tvals, status, qblock, seg[:, 1:1 + lk],
                       seg[:, 1 + lk:], valid, mode, impl=impl)


# --------------------------------------------------------------------------
# blocked Bloom filter
# --------------------------------------------------------------------------

def bloom_insert(filter_words, qblock, qwords, qvalid, impl: str = "auto"):
    """Batch blocked-Bloom insert with first-inserter-wins atomicity.

    Returns (filter_words, already_present(M,)).
    """
    impl = _resolve(impl)
    if impl == "oracle":
        return _ref.bloom_insert_ref(filter_words, qblock, qwords, qvalid)

    nb = filter_words.shape[0]
    m = qblock.shape[0]
    b = jnp.where(qvalid, qblock.astype(_I32), nb)
    order = jnp.argsort(b, stable=True)
    sb = b[order]
    sw = qwords[order]
    svalid = qvalid[order]

    seg_start = jnp.concatenate([jnp.ones((1,), bool), sb[1:] != sb[:-1]])
    ex_or = seg_exclusive_or_scan(jnp.where(svalid[:, None], sw, 0), seg_start)

    sb_c = jnp.clip(sb, 0, nb - 1)
    prior = filter_words[sb_c] | ex_or
    already = ((prior & sw) == sw).all(axis=1) & svalid

    # inclusive OR per segment lands on the segment's last row
    incl_or = ex_or | jnp.where(svalid[:, None], sw, 0)
    is_last = jnp.concatenate([sb[1:] != sb[:-1], jnp.ones((1,), bool)])
    wb = jnp.where(is_last & (sb < nb), sb_c, nb)
    new_words = filter_words[sb_c] | incl_or
    if impl == "pallas":
        from repro.kernels import bloom_kernel
        already = bloom_kernel.membership(prior, sw, svalid)
    filter_words = filter_words.at[wb].set(new_words, mode="drop")

    out = jnp.zeros((m,), bool).at[order].set(already)
    return filter_words, out


def bloom_find(filter_words, qblock, qwords, qvalid, impl: str = "auto"):
    return _ref.bloom_find_ref(filter_words, qblock, qwords, qvalid)


# --------------------------------------------------------------------------
# binning histogram + exchange send-buffer construction
# --------------------------------------------------------------------------

def bin_histogram(bins, nbins: int, valid=None, impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "pallas":
        from repro.kernels import binning
        return binning.histogram(bins, nbins, valid)
    return _ref.bin_histogram_ref(bins, nbins, valid)


def bin_offsets(bins, nbins: int, valid=None, impl: str = "auto"):
    """Per-destination counts + stable within-destination offsets.

    The exchange engine's send-buffer construction: item i's slot is
    ``bins[i] * capacity + offsets[i]``.  Returns ``(counts (nbins,),
    offsets (N,))``; offsets of invalid items are unspecified.
    """
    impl = _resolve(impl)
    if impl == "oracle":
        return _ref.bin_offsets_ref(bins, nbins, valid)
    if impl == "pallas":
        from repro.kernels import binning
        return binning.bin_offsets(bins, nbins, valid)

    # vectorized jnp path: one stable argsort, offsets scattered back
    n = bins.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    b = jnp.where(valid, bins.astype(_I32), nbins)   # invalid -> bucket NB
    counts_full = jnp.zeros((nbins + 1,), _I32).at[b].add(1)
    start = jnp.concatenate([jnp.zeros((1,), _I32),
                             jnp.cumsum(counts_full)[:-1].astype(_I32)])
    order = jnp.argsort(b, stable=True)
    pos_sorted = jnp.arange(n, dtype=_I32) - start[b[order]]
    offsets = jnp.zeros((n,), _I32).at[order].set(pos_sorted)
    return counts_full[:nbins], offsets


def multi_bin_offsets(bins, flow, nbins: int, nflows: int, valid=None,
                      impl: str = "auto"):
    """Segmented multi-flow slot assignment (the ExchangePlan hot path).

    One binning pass over the concatenation of all flows of a plan:
    items are ranked within their composite ``(dest, flow)`` bucket
    (destination-major) so the fused send buffer can place flow ``f``'s
    items for destination ``d`` at
    ``d * sum(caps) + flow_offset[f] + offsets``.  Returns
    ``(counts (nbins, nflows), offsets (N,))``; per-flow capacity
    masking is the caller's (drops are ``offsets >= cap[flow]``).

    Lowers to ONE :func:`bin_offsets` pass over the composite key
    ``dest * nflows + flow`` (destination-major), so every impl —
    oracle, jnp, and the Pallas kernel — serves multi-flow plans
    through its existing single-key path.
    """
    comp = bins.astype(_I32) * nflows + flow.astype(_I32)
    counts, offs = bin_offsets(comp, nbins * nflows, valid, impl=impl)
    return counts.reshape(nbins, nflows), offs


def ragged_slots(bins, flow, offsets, valid, rnd: int, word_off, row_words,
                 caps, rounds, wtot: int, sentinel: int, impl: str = "auto"):
    """Ragged fused-wire word slots for retry round ``rnd``.

    The ExchangePlan send buffer is a flat u32 word vector per
    destination (DESIGN.md section 1.5): flow ``f``'s segment starts at
    ``word_off[f]`` and its rows are exactly ``row_words[f] = L_f + 1``
    words wide — no cross-flow padding.  This op turns the ONE
    :func:`multi_bin_offsets` pass's within-bucket ranks into per-item
    word slots for one launch: item i starts at ``bins[i]*wtot +
    word_off[flow[i]] + (offsets[i] - rnd*caps[flow[i]]) *
    row_words[flow[i]]`` iff its rank falls in round ``rnd``'s capacity
    window ``[rnd*C_f, (rnd+1)*C_f)`` and ``rounds[flow[i]] > rnd``;
    all other items get ``sentinel`` (an index past the buffer, dropped
    by the scatter).  Retry rounds therefore reuse the same offsets
    with a different ``rnd`` — never a second binning pass.
    """
    impl = _resolve(impl)
    if impl == "pallas":
        from repro.kernels import binning
        return binning.ragged_slots(bins, flow, offsets, valid, rnd,
                                    word_off, row_words, caps, rounds,
                                    wtot, sentinel)
    f = flow.astype(_I32)
    off_r = offsets.astype(_I32) - rnd * caps[f]
    in_r = valid & (rounds[f] > rnd) & (off_r >= 0) & (off_r < caps[f])
    return jnp.where(in_r,
                     bins.astype(_I32) * wtot + word_off[f]
                     + off_r * row_words[f],
                     sentinel).astype(_I32)


def stage_slots(bins, flow, offsets, valid, word_off, row_words, caps,
                live, wtot: int, sentinel: int, impl: str = "auto"):
    """Per-stage ragged word slots for a transport hop (DESIGN.md §1.7).

    The hierarchical transport re-bins items per hop — by destination
    *column* at the source, by destination *row* at the relay — and
    packs each hop's wire with the same ragged offset-table math as the
    fused plan wire.  This is :func:`ragged_slots` with no retry-round
    window: item i of flow ``f`` gets word ``bins[i]*wtot + word_off[f]
    + offsets[i]*row_words[f]`` iff it is valid, its stage rank is
    below the stage capacity ``caps[f]``, and ``live[f]`` marks the
    flow as riding this hop; everything else gets ``sentinel``.  Both
    the jnp path and the Pallas kernel are the existing ``ragged_slots``
    lowerings (round 0, per-flow "rounds" = the live mask), so the hop
    adds zero new kernel surface and still no argsort.
    """
    return ragged_slots(bins, flow, offsets, valid, 0, word_off, row_words,
                        caps, live, wtot, sentinel, impl=impl)


def pack_rows(rows, bins, flow, offsets, valid, rnd: int, word_off,
              row_words, caps, rounds, wtot: int, total: int,
              impl: str = "auto"):
    """Fused ragged wire pack: slots + row scatter in one pass.

    ``rows`` is the (N, wmax) right-padded u32 row matrix over all flows
    in item order (flow ``f`` uses lanes ``[0, row_words[f])``); returns
    the flat ``(total,)`` u32 send buffer for retry round ``rnd``.  The
    jnp path is the declared fallback/oracle — :func:`ragged_slots`
    followed by ``object_container.scatter_rows`` (the two-pass XLA
    lowering, DESIGN.md section 1.10); the Pallas path writes the wire
    exactly once (``kernels/binning.pack_rows``).
    """
    impl = _resolve(impl)
    if impl == "pallas":
        from repro.kernels import binning
        return binning.pack_rows(rows, bins, flow, offsets, valid, rnd,
                                 word_off, row_words, caps, rounds,
                                 wtot, total)
    from repro.core.object_container import scatter_rows
    slots = ragged_slots(bins, flow, offsets, valid, rnd, word_off,
                         row_words, caps, rounds, wtot, total, impl=impl)
    return scatter_rows(jnp.zeros((total,), _U32), slots, rows,
                        widths=row_words[flow.astype(_I32)])


def place_rows(dst, slots, rows, impl: str = "auto"):
    """Scatter fixed-width (N, W) rows into ``dst`` at word ``slots``.

    Rows with ``slots[i] >= dst.size`` drop.  jnp path is
    ``object_container.scatter_rows`` (the fallback/oracle); the Pallas
    path folds the scatter into one kernel pass so analytic-slot writes
    (dense replies, owner-side assembly) stay off XLA's scatter path.
    """
    impl = _resolve(impl)
    if impl == "pallas":
        from repro.kernels import binning
        return binning.place_rows(dst, slots, rows)
    from repro.core.object_container import scatter_rows
    return scatter_rows(dst, slots, rows)


# --------------------------------------------------------------------------
# wire integrity: per-row mixing hash
# --------------------------------------------------------------------------

def mix_rows(rows: jax.Array, impl: str = "auto") -> jax.Array:
    """Per-row u32 mixing hash of a lane matrix (wire checksums).

    ``rows`` is (N, L) u32 (the exchange wire's payload + meta lanes);
    returns (N,) u32.  Lane ``l`` is weighted by the odd multiplier
    ``0x9E3779B1 * (2l + 1)`` (mod 2^32), the weighted sum is finished
    with the murmur3 fmix32 avalanche — all in wrapping u32 arithmetic,
    bit-identical across impls and platforms so sender and owner sides
    of an integrity-checked exchange (DESIGN.md section 1.8) agree.
    An all-zero row hashes to 0 (fmix32(0) == 0), so summing hashes
    over a wire window skips empty slots for free.
    """
    impl = _resolve(impl)
    if rows.ndim == 1:
        rows = rows[:, None]
    if impl == "pallas":
        from repro.kernels import binning
        return binning.row_mix(rows)
    rows = rows.astype(_U32)
    lanes = rows.shape[1]
    mult = (_U32(0x9E3779B1)
            * (jnp.arange(lanes, dtype=_U32) * _U32(2) + _U32(1)))
    h = jnp.sum(rows * mult[None, :], axis=1, dtype=_U32)
    h = h ^ (h >> 16)
    h = h * _U32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * _U32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    impl: str = "auto"):
    impl = _resolve(impl)
    if impl == "pallas":
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window)
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window)
