"""Pallas TPU kernel: blocked Bloom filter hashing + membership.

Fuses the per-item pipeline of paper section 5.4.2 into one VPU pass:
murmur-finalizer double hashing (k bit positions), expansion to a 64-bit
block word, and the membership test against the (pre-gathered) filter
word.  Everything is shift/xor/mul/or lanes — ideal VPU code; the grid
tiles the item batch.

The owner-side OR-scatter (and the segmented OR-scan that makes batch
insertion atomic) stays outside the kernel: it is a data-dependent
scatter that the exchange engine already organizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import interpret_mode as _interpret

_U32 = jnp.uint32
# plain ints: Pallas kernels cannot capture module-level array constants
_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_PHI = 0x9E3779B9



def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * _U32(_C1)
    h = h ^ (h >> 13)
    h = h * _U32(_C2)
    h = h ^ (h >> 16)
    return h


def _hash_lanes(lanes, seed, num_lanes):
    init = (seed * _PHI + num_lanes) & 0xFFFFFFFF
    h = jnp.full(lanes.shape[:1], _U32(init), _U32)
    for i in range(num_lanes):
        h = (h ^ _fmix32(lanes[:, i])) * _U32(_C1) + _U32(i + 1)
    return _fmix32(h)


def _words_kernel(lanes_ref, words_ref, *, k: int, num_lanes: int):
    lanes = lanes_ref[...]                       # (TM, L)
    h1 = _hash_lanes(lanes, 1, num_lanes)
    h2 = _hash_lanes(lanes, 2, num_lanes) | _U32(1)
    lo = jnp.zeros(lanes.shape[:1], _U32)
    hi = jnp.zeros(lanes.shape[:1], _U32)
    for i in range(k):
        bit = (h1 + _U32(i) * h2) % _U32(64)
        lo = lo | jnp.where(bit < 32, _U32(1) << (bit % 32), _U32(0))
        hi = hi | jnp.where(bit >= 32, _U32(1) << (bit % 32), _U32(0))
    words_ref[...] = jnp.stack([lo, hi], axis=1)


def hash_words(lanes: jax.Array, k: int, tile: int = 1024) -> jax.Array:
    """(M, L) u32 item lanes -> (M, 2) u32 64-bit block words (k bits)."""
    m, num_lanes = lanes.shape
    pad = (-m) % tile
    if pad:
        lanes = jnp.pad(lanes, ((0, pad), (0, 0)))
    mp = lanes.shape[0]
    kern = functools.partial(_words_kernel, k=k, num_lanes=num_lanes)
    words = pl.pallas_call(
        kern,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((tile, num_lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 2), _U32),
        interpret=_interpret(),
    )(lanes)
    return words[:m]


def _member_kernel(prior_ref, words_ref, valid_ref, out_ref):
    prior = prior_ref[...]
    words = words_ref[...]
    ok = ((prior & words) == words).all(axis=1)
    out_ref[...] = (ok & (valid_ref[...] == 1)).astype(_U32)


def membership(prior: jax.Array, words: jax.Array, valid: jax.Array,
               tile: int = 1024) -> jax.Array:
    """already_present = all k bits of ``words`` set in ``prior``."""
    m = prior.shape[0]
    pad = (-m) % tile
    if pad:
        prior = jnp.pad(prior, ((0, pad), (0, 0)))
        words = jnp.pad(words, ((0, pad), (0, 0)), constant_values=1)
        valid = jnp.pad(valid.astype(_U32), (0, pad))
    mp = prior.shape[0]
    out = pl.pallas_call(
        _member_kernel,
        grid=(mp // tile,),
        in_specs=[pl.BlockSpec((tile, 2), lambda i: (i, 0)),
                  pl.BlockSpec((tile, 2), lambda i: (i, 0)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), _U32),
        interpret=_interpret(),
    )(prior, words, valid.astype(_U32))
    return out[:m] == 1
