"""State-space / linear-recurrence mixers: Mamba2 (SSD) and RWKV-6.

Both are expressed as ``lax.scan`` over time with an explicit recurrent
state, which (a) keeps the HLO O(1) in sequence length, (b) gives decode
a natural single-step form (the state is the "cache"), and (c) makes the
500k-token long-context shape lowerable: state size is independent of
context.  Chunked/parallel-scan formulations are a recorded perf
candidate (EXPERIMENTS.md section Perf), not the baseline.

Shapes follow the configs: Mamba2 state (B, H, d_state, head) per layer;
RWKV6 state (B, H, hd, hd) with data-dependent per-channel decay (the
"Finch" form).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_F32 = jnp.float32


# ---------------------------------------------------------------------------
# Mamba2 (SSD recurrence, ngroups=1)
# ---------------------------------------------------------------------------

def mamba_dims(cfg):
    inner = cfg.ssm.expand * cfg.d_model
    nheads = cfg.ssm.n_heads or max(1, inner // 64)
    head = inner // nheads
    return inner, nheads, head


def mamba_init(rng, cfg, dtype):
    d = cfg.d_model
    ds = cfg.ssm.d_state
    dc = cfg.ssm.d_conv
    inner, nh, _ = mamba_dims(cfg)
    ks = jax.random.split(rng, 5)
    s = d ** -0.5
    proj_out = 2 * inner + 2 * ds + nh
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (dc, inner + 2 * ds)) * 0.1
                   ).astype(dtype),
        "a_log": jnp.zeros((nh,), _F32),
        "dt_bias": jnp.zeros((nh,), _F32),
        "d_skip": jnp.ones((nh,), _F32),
        "norm": jnp.ones((inner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (inner, d)) * inner ** -0.5
                     ).astype(dtype),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x (B,T,C), w (K,C). state (B,K-1,C) for decode.

    Returns (y, new_state)."""
    kw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(kw))
    new_state = xp[:, -(kw - 1):] if kw > 1 else pad
    return y, new_state


def mamba_apply(params, x, cfg, state=None):
    """x (B,T,D) -> (y, new_state).

    state: dict(conv (B,K-1,C), ssd (B,H,ds,hd)); None => zeros (training).
    """
    from repro.models.layers import rms_norm
    b, t, d = x.shape
    ds = cfg.ssm.d_state
    inner, nh, head = mamba_dims(cfg)

    proj = x @ params["in_proj"]
    z, xin, bc, dt = jnp.split(
        proj, [inner, 2 * inner, 2 * inner + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, params["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :inner]
    b_in = conv_out[..., inner:inner + ds]
    c_in = conv_out[..., inner + ds:]

    a = -jnp.exp(params["a_log"])                        # (H,)
    dt = jax.nn.softplus(dt.astype(_F32) + params["dt_bias"])   # (B,T,H)
    xh = xin.reshape(b, t, nh, head)

    h0 = state["ssd"] if state is not None else \
        jnp.zeros((b, nh, ds, head), _F32)

    def step(h, inputs):
        xt, bt, ct, dtt = inputs      # (B,H,hd) (B,ds) (B,ds) (B,H)
        decay = jnp.exp(a[None] * dtt)                    # (B,H)
        upd = jnp.einsum("bs,bhp->bhsp", bt.astype(_F32),
                         (xt.astype(_F32) * dtt[..., None]))
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bs,bhsp->bhp", ct.astype(_F32), h)
        return h, y

    xs = (xh.swapaxes(0, 1), b_in.swapaxes(0, 1), c_in.swapaxes(0, 1),
          dt.swapaxes(0, 1))
    h_fin, ys = jax.lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1)                                 # (B,T,H,hd)
    y = y + params["d_skip"][None, None, :, None] * xh.astype(_F32)
    y = y.reshape(b, t, inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"]
    new_state = {"conv": new_conv, "ssd": h_fin}
    return out, new_state


def mamba_state_init(cfg, batch, dtype=_F32):
    ds = cfg.ssm.d_state
    dc = cfg.ssm.d_conv
    inner, nh, head = mamba_dims(cfg)
    return {"conv": jnp.zeros((batch, dc - 1, inner + 2 * ds), dtype),
            "ssd": jnp.zeros((batch, nh, ds, head), _F32)}


# ---------------------------------------------------------------------------
# RWKV-6 ("Finch": data-dependent decay)
# ---------------------------------------------------------------------------

def rwkv_dims(cfg):
    hd = cfg.ssm.d_state if cfg.ssm else 64
    nh = cfg.d_model // hd
    return nh, hd


def rwkv_init(rng, cfg, dtype):
    d = cfg.d_model
    nh, hd = rwkv_dims(cfg)
    ks = jax.random.split(rng, 8)
    s = d ** -0.5
    return {
        "mu": (jax.random.uniform(ks[0], (5, d)) * 0.1 + 0.45).astype(dtype),
        "wr": (jax.random.normal(ks[1], (d, d)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[2], (d, d)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[3], (d, d)) * s).astype(dtype),
        "wg": (jax.random.normal(ks[4], (d, d)) * s).astype(dtype),
        "ww": (jax.random.normal(ks[5], (d, d)) * s * 0.1).astype(dtype),
        "w0": jnp.full((d,), -5.0, _F32),
        "u": (jax.random.normal(ks[6], (nh, hd)) * 0.1).astype(_F32),
        "wo": (jax.random.normal(ks[7], (d, d)) * s).astype(dtype),
        "ln_x": jnp.ones((d,), dtype),
    }


def rwkv_apply(params, x, cfg, state=None):
    """RWKV-6 time mixing. x (B,T,D) -> (y, new_state).

    state: dict(s (B,H,hd,hd) f32, prev (B,D)); None => zeros.
    """
    from repro.models.layers import rms_norm
    b, t, d = x.shape
    nh, hd = rwkv_dims(cfg)

    prev = state["prev"][:, None] if state is not None else \
        jnp.zeros((b, 1, d), x.dtype)
    xshift = jnp.concatenate([prev, x[:, :-1]], axis=1)

    def mix(i):
        return x + (xshift - x) * params["mu"][i]

    r = (mix(0) @ params["wr"]).reshape(b, t, nh, hd)
    kk = (mix(1) @ params["wk"]).reshape(b, t, nh, hd)
    v = (mix(2) @ params["wv"]).reshape(b, t, nh, hd)
    g = jax.nn.silu(mix(3) @ params["wg"])
    w = jnp.exp(-jnp.exp(
        params["w0"] + (mix(4) @ params["ww"]).astype(_F32)))  # (B,T,D)
    w = w.reshape(b, t, nh, hd)

    s0 = state["s"] if state is not None else jnp.zeros((b, nh, hd, hd), _F32)
    u = params["u"]

    def step(s, inp):
        rt, kt, vt, wt = inp    # each (B,H,hd)
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(_F32), vt.astype(_F32))
        out = jnp.einsum("bhk,bhkv->bhv", rt.astype(_F32),
                         s + u[None, :, :, None] * kv)
        s = wt.astype(_F32)[..., None] * s + kv
        return s, out

    xs = (r.swapaxes(0, 1), kk.swapaxes(0, 1), v.swapaxes(0, 1),
          w.swapaxes(0, 1))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    y = ys.swapaxes(0, 1).reshape(b, t, d).astype(x.dtype)
    y = rms_norm(y, params["ln_x"], cfg.norm_eps) * g
    out = y @ params["wo"]
    return out, {"s": s_fin, "prev": x[:, -1]}


def rwkv_state_init(cfg, batch, dtype=_F32):
    nh, hd = rwkv_dims(cfg)
    return {"s": jnp.zeros((batch, nh, hd, hd), _F32),
            "prev": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype))}


def rwkv_channel_mix_init(rng, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "mu": (jax.random.uniform(ks[0], (2, d)) * 0.1 + 0.45).astype(dtype),
        "w_in": (jax.random.normal(ks[1], (d, f)) * d ** -0.5).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (f, d)) * f ** -0.5).astype(dtype),
    }


def rwkv_channel_mix(params, x, prev=None):
    """RWKV channel mixing (token-shifted squared-ReLU MLP)."""
    b, t, d = x.shape
    pv = prev[:, None] if prev is not None else jnp.zeros((b, 1, d), x.dtype)
    xshift = jnp.concatenate([pv, x[:, :-1]], axis=1)
    xk = x + (xshift - x) * params["mu"][0]
    h = jnp.square(jax.nn.relu(xk @ params["w_in"]))
    return h @ params["w_out"], x[:, -1]
