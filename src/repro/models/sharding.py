"""Sharding rules: logical parameter/activation layouts -> PartitionSpecs.

One place defines the whole parallelism scheme:

  data axes   ('pod','data') on the multi-pod mesh, ('data',) single-pod.
              Batch dim of activations; FSDP (ZeRO-3) dim of params when
              cfg.fsdp.
  model axis  'model'. Tensor parallelism (heads / ffn hidden / vocab) and
              expert parallelism for MoE dispatch.

Param rules are path-based: the pytree path of each parameter determines
its PartitionSpec.  Scanned layer stacks have a leading (n_units,) dim
mapped to None.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Axes:
    data: tuple[str, ...]      # ('pod','data') or ('data',)
    model: str                 # 'model'

    @staticmethod
    def from_mesh(mesh: Mesh) -> "Axes":
        names = tuple(mesh.axis_names)
        model = "model" if "model" in names else names[-1]
        data = tuple(n for n in names if n != model)
        return Axes(data=data, model=model)

    @property
    def dp(self):
        return self.data if len(self.data) > 1 else self.data[0] if self.data else None


def _fsdp_axis(cfg) -> Any:
    return None if not cfg.fsdp else None  # placeholder; resolved in rules


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def param_spec(cfg, axes: Axes, path: str, ndim: int,
               scanned: bool) -> P:
    """PartitionSpec for a parameter identified by its flattened path."""
    m = axes.model
    f = axes.data if cfg.fsdp else None   # FSDP shard dim (tuple of axes)

    core = ndim - (1 if scanned else 0)

    def pad(spec_dims):
        dims = list(spec_dims)[:core]          # never exceed the rank
        while len(dims) < core:
            dims.append(None)
        if scanned:
            dims = [None] + dims
        return P(*dims)

    # match on the leaf parameter NAME (last path key); substrings of
    # container keys like 'rwkv' must not trigger projection rules
    parts = [s for s in path.replace("]", "").replace("'", "").split("[")
             if s]
    name = parts[-1] if parts else path
    in_experts = "experts" in parts

    if name in ("embed", "lm_head"):
        return pad((m, f))
    if name in ("router", "moe_bias"):
        return pad((None,))
    if in_experts and name in ("w_in", "w_gate"):
        return pad((m, f, None))
    if in_experts and name == "w_out":
        return pad((m, None, f))
    # attention / ssm in-projections: columns over model
    if name in ("wq", "wk", "wv", "w_uq", "w_ukv", "in_proj",
                "wr", "wg"):
        return pad((f, m))
    if name in ("wo", "out_proj"):
        return pad((m, f))
    # MLA down-projections + rwkv decay proj: small, FSDP only
    if name in ("w_dq", "w_dkv", "w_kr", "ww"):
        return pad((f, None))
    # MLP: hidden over model
    if name in ("w_in", "w_gate"):
        return pad((f, m))
    if name == "w_out":
        return pad((m, f))
    if name == "mtp_proj":
        return pad((f, None))
    # conv / norms / scalars / rwkv mixing vectors: replicate (tiny)
    return pad((None,))


def param_shardings(cfg, mesh: Mesh, params_shape) -> Any:
    """Tree of NamedShardings matching a params shape-tree."""
    axes = Axes.from_mesh(mesh)

    def one(kp, leaf):
        path = jax.tree_util.keystr(kp)
        scanned = "stack" in path
        spec = param_spec(cfg, axes, path, len(leaf.shape), scanned)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# activation rules
# ---------------------------------------------------------------------------

def act_spec(axes: Axes, kind: str) -> P:
    d = axes.data
    m = axes.model
    table = {
        "tokens": P(d, None),                  # (B, T)
        "btd": P(d, None, None),               # (B, T, D)
        "btd_seq": P(d, m, None),              # sequence-parallel segments
        "logits": P(d, None, m),               # (B, T, V)
        "kv_cache": P(d, m, None, None),       # (B, H_kv, S, hd)
        "kv_cache_rep": P(d, None, None, None),  # kv heads < model size
        "mla_cache": P(d, None, None),         # (B, S, r)
        "ssm_state": P(d, m, None, None),      # (B, H, hd, d_state)
        "scalar": P(),
    }
    return table[kind]


def shard(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
