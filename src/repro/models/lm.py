"""Model assembly: decoder-only / encoder-decoder LMs over the block zoo.

Layer pattern strings drive assembly (configs/base.py):
  g  global attention block        l  sliding-window attention block
  m  Mamba2 block                  r  RWKV-6 block (+ channel mix)
  a  shared attention block (Zamba: one parameter set, used repeatedly)

Structure = [first_k_dense prefix (unrolled)] + [scan over pattern units]
+ [remainder (unrolled)].  Scan-over-layers keeps HLO size O(1) in depth
(61-layer DeepSeek compiles as one unit body), which is what makes the
512-device dry-run compile in seconds.

Entry points (all pure functions of (params, batch)):
  init_params / abstract_params        parameter pytrees (real / eval_shape)
  forward                              hidden states (+aux, +cache)
  loss_fn                              LM loss (chunked vocab xent)
  prefill / decode_step                serving path with typed caches
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.sharding import Axes, shard

_F32 = jnp.float32


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _remat_policy(cfg):
    if cfg.remat_policy == "nothing":
        return jax.checkpoint_policies.nothing_saveable
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return None


def _kind_at(cfg, layer_idx: int) -> str:
    pat = cfg.layer_pattern
    return pat[layer_idx % len(pat)]


def _layer_is_moe(cfg, layer_idx: int) -> bool:
    return cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def _block_init(rng, cfg, kind: str, moe_layer: bool, cross: bool, dtype):
    ks = jax.random.split(rng, 6)
    d = cfg.d_model
    p: dict[str, Any] = {"ln1": jnp.ones((d,), dtype)}
    if kind in ("g", "l"):
        if cfg.mla:
            p["attn"] = attn_mod.mla_init(ks[0], cfg, dtype)
        else:
            p["attn"] = attn_mod.attn_init(ks[0], cfg, dtype)
        p["ln2"] = jnp.ones((d,), dtype)
        if moe_layer:
            p["moe"] = moe_mod.moe_init(ks[1], cfg, dtype)
        else:
            width = cfg.d_ff
            p["mlp"] = L.mlp_init(ks[1], d, width, cfg.activation, dtype)
        if cross:
            p["ln_x"] = jnp.ones((d,), dtype)
            p["xattn"] = attn_mod.attn_init(ks[2], cfg, dtype)
    elif kind == "m":
        p["mamba"] = ssm_mod.mamba_init(ks[0], cfg, dtype)
    elif kind == "r":
        p["rwkv"] = ssm_mod.rwkv_init(ks[0], cfg, dtype)
        p["ln2"] = jnp.ones((d,), dtype)
        p["cmix"] = ssm_mod.rwkv_channel_mix_init(ks[1], cfg, dtype)
    elif kind == "a":
        p["use_shared"] = jnp.zeros((), jnp.float32)  # marker leaf
    return p


def _unit_init(rng, cfg, cross: bool, dtype, start_layer: int):
    pat = cfg.layer_pattern
    ks = jax.random.split(rng, len(pat))
    return {f"p{i}": _block_init(ks[i], cfg, pat[i],
                                 _layer_is_moe(cfg, start_layer + i),
                                 cross, dtype)
            for i in range(len(pat))}


def _layer_layout(cfg):
    """(n_prefix, n_units, n_rem) given first_k_dense and the pattern."""
    prefix = cfg.moe.first_k_dense if cfg.moe else 0
    u = len(cfg.layer_pattern)
    rest = cfg.n_layers - prefix
    return prefix, rest // u, rest % u


def init_params(cfg: ArchConfig, rng) -> dict:
    dtype = _dtype(cfg)
    d, v = cfg.d_model, cfg.padded_vocab
    prefix, n_units, n_rem = _layer_layout(cfg)
    cross = cfg.encoder_layers > 0
    keys = iter(jax.random.split(rng, 16 + prefix + n_rem))

    params: dict[str, Any] = {
        "embed": (jax.random.normal(next(keys), (v, d)) * d ** -0.5
                  ).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(next(keys), (v, d))
                             * d ** -0.5).astype(dtype)

    for i in range(prefix):
        params[f"prefix_{i}"] = _block_init(
            next(keys), cfg, _kind_at(cfg, i), False, cross, dtype)

    if n_units:
        unit_rngs = jax.random.split(next(keys), n_units)
        params["stack"] = jax.vmap(
            lambda r: _unit_init(r, cfg, cross, dtype, prefix))(unit_rngs)

    for i in range(n_rem):
        li = prefix + n_units * len(cfg.layer_pattern) + i
        params[f"rem_{i}"] = _block_init(
            next(keys), cfg, _kind_at(cfg, li - prefix),
            _layer_is_moe(cfg, li), cross, dtype)

    if "a" in cfg.layer_pattern:
        shared = {"ln1": jnp.ones((d,), dtype),
                  "attn": attn_mod.attn_init(next(keys), cfg, dtype),
                  "ln2": jnp.ones((d,), dtype),
                  "mlp": L.mlp_init(next(keys), d, cfg.d_ff,
                                    cfg.activation, dtype)}
        params["shared_attn"] = shared

    if cfg.encoder_layers:
        enc_rngs = jax.random.split(next(keys), cfg.encoder_layers)
        params["enc_stack"] = jax.vmap(
            lambda r: _block_init(r, cfg, "g", False, False, dtype)
        )(enc_rngs)
        params["enc_norm"] = jnp.ones((d,), dtype)

    if cfg.mtp:
        params["mtp_block"] = _block_init(next(keys), cfg, "g", False,
                                          False, dtype)
        params["mtp_norm"] = jnp.ones((d,), dtype)
        params["mtp_proj"] = (jax.random.normal(next(keys), (2 * d, d))
                              * (2 * d) ** -0.5).astype(dtype)
    return params


def abstract_params(cfg: ArchConfig):
    """Parameter shapes without allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))


def param_count_exact(cfg: ArchConfig) -> int:
    shapes = abstract_params(cfg)
    n = 0
    for leaf in jax.tree_util.tree_leaves(shapes):
        n += int(functools.reduce(lambda a, b: a * b, leaf.shape, 1))
    return n


def active_param_count_exact(cfg: ArchConfig) -> int:
    """Active per-token params: non-expert params + top_k+shared experts."""
    total = param_count_exact(cfg)
    if not cfg.moe:
        return total
    shapes = abstract_params(cfg)
    expert_total = 0
    for kp, leaf in jax.tree_util.tree_leaves_with_path(shapes):
        path = jax.tree_util.keystr(kp)
        if "experts" in path:
            expert_total += int(functools.reduce(
                lambda a, b: a * b, leaf.shape, 1))
    mo = cfg.moe
    active_frac = mo.top_k / mo.n_experts
    return int(total - expert_total * (1 - active_frac))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _block_cache_init(cfg, kind: str, batch: int, cache_len: int,
                      cross_len: int, dtype):
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    c: dict[str, Any] = {}
    if kind in ("g", "l", "a"):
        if cfg.mla and kind != "a":
            m = cfg.mla
            c["c_kv"] = jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype)
            c["k_rope"] = jnp.zeros((batch, cache_len, m.qk_rope_head_dim),
                                    dtype)
        else:
            # cfg.window_cache caps 'l'-layer caches at the window size
            # (ring append) — the decode-memory optimization measured in
            # EXPERIMENTS.md section Perf; baseline keeps full length.
            s_len = cache_len
            if (cfg.window_cache and kind == "l" and cfg.sliding_window
                    and cfg.sliding_window < cache_len):
                s_len = cfg.sliding_window
            c["k"] = jnp.zeros((batch, nkv, s_len, hd), dtype)
            c["v"] = jnp.zeros((batch, nkv, s_len, hd), dtype)
        if cfg.encoder_layers and kind != "a":
            c["xk"] = jnp.zeros((batch, nkv, cross_len, hd), dtype)
            c["xv"] = jnp.zeros((batch, nkv, cross_len, hd), dtype)
    elif kind == "m":
        c = ssm_mod.mamba_state_init(cfg, batch)
    elif kind == "r":
        c = ssm_mod.rwkv_state_init(cfg, batch)
        c["cm_prev"] = jnp.zeros((batch, cfg.d_model), dtype)
    return c


def cache_init(cfg: ArchConfig, batch: int, cache_len: int,
               cross_len: int = 0):
    dtype = _dtype(cfg)
    prefix, n_units, n_rem = _layer_layout(cfg)
    pat = cfg.layer_pattern
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    for i in range(prefix):
        cache[f"prefix_{i}"] = _block_cache_init(
            cfg, _kind_at(cfg, i), batch, cache_len, cross_len, dtype)
    if n_units:
        unit = {f"p{i}": _block_cache_init(cfg, pat[i], batch, cache_len,
                                           cross_len, dtype)
                for i in range(len(pat))}
        cache["stack"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape), unit)
    for i in range(n_rem):
        cache[f"rem_{i}"] = _block_cache_init(
            cfg, pat[i % len(pat)], batch, cache_len, cross_len, dtype)
    return cache


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_block(bp, x, cfg, kind: str, *, positions, mesh, axes,
                 shared_params=None, enc_out=None, cache=None,
                 cache_len=None):
    """Pre-norm block. Returns (x, new_cache, aux)."""
    aux = jnp.float32(0.0)
    new_cache: dict[str, Any] = {}
    if kind == "a":
        bp = shared_params
    if kind in ("g", "l", "a"):
        window = cfg.sliding_window if kind == "l" else 0
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        sub_cache = None
        if cache is not None and ("k" in cache or "c_kv" in cache):
            sub_cache = {k: v for k, v in cache.items()
                         if k in ("k", "v", "c_kv", "k_rope")}
        if cfg.mla and kind != "a":
            o, nc = attn_mod.mla_attention(bp["attn"], h, cfg,
                                           positions=positions,
                                           cache=sub_cache,
                                           cache_len=cache_len,
                                           mesh=mesh, axes=axes)
        else:
            o, nc = attn_mod.attention(bp["attn"], h, cfg,
                                       positions=positions, causal=True,
                                       window=window, cache=sub_cache,
                                       cache_len=cache_len)
        if nc:
            new_cache.update(nc)
        x = x + o
        # cross attention (encoder-decoder)
        if "xattn" in bp and enc_out is not None:
            h = L.rms_norm(x, bp["ln_x"], cfg.norm_eps)
            xo, _ = attn_mod.attention(bp["xattn"], h, cfg,
                                       positions=positions, causal=False,
                                       kv_source=enc_out)
            x = x + xo
        elif "xattn" in bp and cache is not None and "xk" in cache:
            # decode: attend cached cross K/V
            h = L.rms_norm(x, bp["ln_x"], cfg.norm_eps)
            b = h.shape[0]
            q = (h @ bp["xattn"]["wq"]).reshape(
                b, 1, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
            xo = attn_mod.decode_attention(q, cache["xk"], cache["xv"],
                                           cache["xk"].shape[2])
            xo = xo.transpose(0, 2, 1, 3).reshape(b, 1, -1) @ bp["xattn"]["wo"]
            x = x + xo
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
        h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if "moe" in bp:
            # expert_load stats ride the dispatch collectives for free;
            # the bias-update consumer hooks in at the optimizer level
            y, aux, _stats = moe_mod.moe_apply(bp["moe"], h, cfg, mesh, axes)
        else:
            y = L.mlp(bp["mlp"], h, cfg.activation)
        x = x + y
    elif kind == "m":
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        st = cache if cache else None
        o, ns = ssm_mod.mamba_apply(bp["mamba"], h, cfg, st)
        new_cache = ns
        x = x + o
    elif kind == "r":
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        st = {k: cache[k] for k in ("s", "prev")} if cache else None
        o, ns = ssm_mod.rwkv_apply(bp["rwkv"], h, cfg, st)
        new_cache.update(ns)
        x = x + o
        h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        o, cm_prev = ssm_mod.rwkv_channel_mix(
            bp["cmix"], h, cache["cm_prev"] if cache else None)
        new_cache["cm_prev"] = cm_prev
        x = x + o
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def encode(params, cfg, src_embeds, mesh, axes):
    """Bidirectional encoder over precomputed frontend embeddings."""
    x = src_embeds.astype(_dtype(cfg))

    def enc_block(x, bp):
        h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
        o, _ = attn_mod.attention(
            bp["attn"], h, cfg,
            positions=jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                       x.shape[:2]),
            causal=False)
        x = x + o
        h = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        return x + L.mlp(bp["mlp"], h, cfg.activation), None

    fn = enc_block
    if cfg.remat == "block":
        fn = jax.checkpoint(enc_block)
    x, _ = jax.lax.scan(fn, x, params["enc_stack"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ArchConfig, tokens, *, mesh: Mesh, axes: Axes,
            patch_embeds=None, src_embeds=None, cache=None,
            decode: bool = False):
    """Returns (hidden (B,T,D), aux_loss, new_cache, n_skip).

    n_skip: leading positions (image patches) to exclude from loss.
    """
    b, t = tokens.shape
    dtype = _dtype(cfg)

    if mesh is not None and mesh.size > 1:
        x = L.embed_lookup(params["embed"], tokens, mesh, axes)
    else:
        x = L.embed_lookup_dense(params["embed"], tokens)
    x = (x * jnp.asarray(cfg.d_model ** 0.5, dtype)).astype(dtype)

    n_skip = 0
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(dtype), x], axis=1)
        n_skip = patch_embeds.shape[1]
        t = x.shape[1]

    enc_out = None
    if cfg.encoder_layers and src_embeds is not None:
        enc_out = encode(params, cfg, src_embeds, mesh, axes)

    if decode:
        pos0 = cache["pos"]
        positions = jnp.broadcast_to(pos0[None, None], (b, 1))
    else:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    cache_len = cache["pos"] if cache is not None else None

    if mesh is not None:
        x = shard(x, mesh, P(axes.data, None, None))

    prefix, n_units, n_rem = _layer_layout(cfg)
    pat = cfg.layer_pattern
    aux_total = jnp.float32(0.0)
    new_cache = {"pos": (cache["pos"] + (1 if decode else t))
                 if cache is not None else None}
    shared_params = params.get("shared_attn")

    def run_block(bp, x, kind, bc):
        return _apply_block(bp, x, cfg, kind, positions=positions,
                            mesh=mesh, axes=axes,
                            shared_params=shared_params, enc_out=enc_out,
                            cache=bc, cache_len=cache_len)

    for i in range(prefix):
        bc = cache.get(f"prefix_{i}") if cache is not None else None
        x, nc, aux = run_block(params[f"prefix_{i}"], x, _kind_at(cfg, i), bc)
        aux_total += aux
        if cache is not None:
            new_cache[f"prefix_{i}"] = nc

    if n_units:
        def unit_fn(carry, xs):
            x, aux_acc = carry
            if cache is not None:
                uparams, ucache = xs
            else:
                uparams, ucache = xs, None
            ncache = {}
            for i, kind in enumerate(pat):
                bc = ucache[f"p{i}"] if ucache is not None else None
                x, nc, aux = run_block(uparams[f"p{i}"], x, kind, bc)
                aux_acc = aux_acc + aux
                ncache[f"p{i}"] = nc if nc else {
                    "_": jnp.zeros((), jnp.int32)}
            return (x, aux_acc), (ncache if cache is not None else None)

        fn = unit_fn
        if cfg.remat == "block":
            fn = jax.checkpoint(unit_fn, policy=_remat_policy(cfg))
        xs = (params["stack"], cache["stack"]) if cache is not None \
            else params["stack"]
        (x, aux_total), stack_cache = jax.lax.scan(fn, (x, aux_total), xs)
        if cache is not None:
            new_cache["stack"] = stack_cache

    for i in range(n_rem):
        li = prefix + n_units * len(pat) + i
        bc = cache.get(f"rem_{i}") if cache is not None else None
        x, nc, aux = run_block(params[f"rem_{i}"], x,
                               _kind_at(cfg, li - prefix), bc)
        aux_total += aux
        if cache is not None:
            new_cache[f"rem_{i}"] = nc

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total, (new_cache if cache is not None else None), n_skip


# ---------------------------------------------------------------------------
# loss / serving
# ---------------------------------------------------------------------------

def head_table(params, cfg):
    return params["embed"] if cfg.tie_embeddings else params["lm_head"]


def _mask_pad_vocab(logits, cfg):
    return jnp.where(jnp.arange(logits.shape[-1]) < cfg.vocab, logits, -1e30)


def loss_fn(params, cfg: ArchConfig, batch, *, mesh, axes):
    """batch: tokens (B, T+1) [+ patch_embeds/src_embeds (+ loss_mask)]."""
    tokens = batch["tokens"]
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    h, aux, _, n_skip = forward(
        params, cfg, inputs, mesh=mesh, axes=axes,
        patch_embeds=batch.get("patch_embeds"),
        src_embeds=batch.get("src_embeds"))
    if n_skip:
        h = h[:, n_skip:]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(targets, _F32)
    table = head_table(params, cfg)
    nll = L.chunked_softmax_xent(h, table, targets, mask, mesh, axes,
                                 chunk=cfg.xent_chunk,
                                 vocab_real=cfg.vocab)
    loss = nll + aux

    if cfg.mtp and h.shape[1] > 2:
        # multi-token prediction: predict t+2 from [h_t ; emb(x_{t+1})]
        if mesh is not None and mesh.size > 1:
            emb_next = L.embed_lookup(params["embed"], targets, mesh, axes)
        else:
            emb_next = L.embed_lookup_dense(params["embed"], targets)
        cat = jnp.concatenate([h, emb_next.astype(h.dtype)], axis=-1)
        h2 = cat @ params["mtp_proj"]
        h2, _, _ = _apply_block(
            params["mtp_block"], h2, cfg, "g",
            positions=jnp.broadcast_to(
                jnp.arange(h2.shape[1])[None], h2.shape[:2]),
            mesh=mesh, axes=axes)[0:3]
        h2 = L.rms_norm(h2, params["mtp_norm"], cfg.norm_eps)
        t2 = jnp.concatenate([targets[:, 1:], targets[:, -1:]], axis=1)
        m2 = jnp.concatenate([mask[:, 1:], jnp.zeros_like(mask[:, -1:])],
                             axis=1)
        nll2 = L.chunked_softmax_xent(h2, table, t2, m2, mesh, axes,
                                      vocab_real=cfg.vocab)
        loss = loss + 0.3 * nll2
    return loss, {"nll": nll, "aux": aux}


def prefill(params, cfg: ArchConfig, batch, cache_len: int, *, mesh, axes):
    """Run the prompt, build the cache, return (cache, last_logits)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    cross_len = batch["src_embeds"].shape[1] if "src_embeds" in batch else 0
    cache = cache_init(cfg, b, cache_len, cross_len)
    h, _, new_cache, _ = forward(
        params, cfg, tokens, mesh=mesh, axes=axes,
        patch_embeds=batch.get("patch_embeds"),
        src_embeds=batch.get("src_embeds"),
        cache=cache, decode=False)
    logits = jnp.einsum("bd,vd->bv", h[:, -1], head_table(params, cfg))
    logits = _mask_pad_vocab(logits, cfg)
    return new_cache, logits


def decode_step(params, cfg: ArchConfig, cache, tokens, *, mesh, axes):
    """One token in, one logits row out; cache advances by one."""
    h, _, new_cache, _ = forward(params, cfg, tokens, mesh=mesh, axes=axes,
                                 cache=cache, decode=True)
    logits = jnp.einsum("bd,vd->bv", h[:, -1], head_table(params, cfg))
    return _mask_pad_vocab(logits, cfg), new_cache
