"""Common layers: norms, rotary embeddings, MLP variants, embeddings.

The vocab-sharded embedding lookup is the BCL DArray-rget specialization:
the table is sharded over the model axis ("hosted" shards), each owner
gathers its hits, and one psum delivers the rows — owner-computes remote
get with a single collective (DESIGN.md section 3).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.sharding import Axes, shard
from repro.compat import shard_map


def rms_norm(x, gamma, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * gamma


def rotary(x, positions, theta: float = 1e4):
    """x (..., T, hd) with positions (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) *
                    jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., T, half)
    cos, sin = jnp.cos(ang).astype(x.dtype), jnp.sin(ang).astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def activation_fn(name: str):
    return {
        "gelu": jax.nn.gelu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
        "silu": jax.nn.silu,
    }.get(name, jax.nn.silu)


def mlp(params, x, activation: str = "swiglu"):
    """Gated or plain MLP. params: w_in (D,F), w_out (F,D) [, w_gate (D,F)]."""
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        h = act(x @ params["w_gate"]) * (x @ params["w_in"])
    else:
        h = activation_fn(activation)(x @ params["w_in"])
    return h @ params["w_out"]


def mlp_init(rng, d: int, f: int, activation: str, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    scale_in = d ** -0.5
    scale_out = f ** -0.5
    p = {
        "w_in": (jax.random.normal(k1, (d, f)) * scale_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (f, d)) * scale_out).astype(dtype),
    }
    if activation in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k3, (d, f)) * scale_in).astype(dtype)
    return p


# ---------------------------------------------------------------------------
# vocab-sharded embedding: owner-computes rget (BCL DArray specialization)
# ---------------------------------------------------------------------------

def embed_lookup(table, tokens, mesh: Mesh, axes: Axes):
    """table (V, D) sharded P(model, ...); tokens (B, T) sharded over data.

    Each model-rank hosts a vocab shard; it gathers rows for the token ids
    that fall in its range and one psum combines — a batched one-sided
    remote get served by the owner, cost R per token (paper Table 2).
    """
    vsize = table.shape[0]
    nm = mesh.shape[axes.model]
    vloc = vsize // nm
    n_data = 1
    for a in axes.data:
        n_data *= mesh.shape[a]
    lead = axes.data if tokens.shape[0] % n_data == 0 else None

    def f(tbl, tok):
        r = jax.lax.axis_index(axes.model)
        loc = tok.astype(jnp.int32) - r * vloc
        hit = (loc >= 0) & (loc < vloc)
        rows = jnp.where(hit[..., None],
                         tbl[jnp.clip(loc, 0, vloc - 1)], 0)
        return jax.lax.psum(rows, axes.model)

    in_specs = (P(axes.model, None), P(lead, None))
    out_specs = P(lead, None, None)
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)(table, tokens)


def embed_lookup_dense(table, tokens):
    """Single-device / serial fallback."""
    return table[tokens]


def output_logits(x, table, mesh: Mesh | None, axes: Axes | None):
    """logits = x @ table.T with vocab sharded over model."""
    logits = jnp.einsum("btd,vd->btv", x, table)
    if mesh is not None:
        logits = shard(logits, mesh, P(axes.data, None, axes.model))
    return logits


def chunked_softmax_xent(x, table, targets, mask, mesh, axes,
                         chunk: int = 512, vocab_real: int | None = None):
    """Cross-entropy over a large sharded vocab without materializing the
    full (B, T, V) logits in one piece: scan over T chunks.

    ``vocab_real`` masks padding rows of the (padded) embedding table out
    of the normalizer."""
    b, t, d = x.shape
    n = t // chunk if t % chunk == 0 else 1
    c = t // n
    vpad = table.shape[0]
    col_ok = (jnp.arange(vpad) < (vocab_real or vpad))[None, None, :]

    def body(carry, xs):
        xc, yc, mc = xs                       # (B, c, D), (B, c), (B, c)
        logits = jnp.einsum("bcd,vd->bcv", xc, table).astype(jnp.float32)
        if mesh is not None:
            logits = shard(logits, mesh, P(axes.data, None, axes.model))
        logits = jnp.where(col_ok, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * mc
        return carry + nll.sum(), None

    xs = (x.reshape(b, n, c, d).swapaxes(0, 1),
          targets.reshape(b, n, c).swapaxes(0, 1),
          mask.reshape(b, n, c).swapaxes(0, 1))
    total, _ = jax.lax.scan(body, jnp.float32(0.0), xs)
    return total / jnp.maximum(mask.sum(), 1)
