"""Mixture-of-Experts with BCL-exchange token dispatch.

This is the paper's technique as a first-class framework feature
(DESIGN.md section 3): expert dispatch IS the many-to-many
redistribution pattern of BCL queues / ISx.  The layer:

  1. registers token routing AND a per-expert stats flow on one
     ``repro.core.exchange.ExchangePlan`` — bucket-by-owner, prefix-sum
     slot reservation, one tiled all-to-all for both flows (the
     FastQueue.push_many program).  The stats flow asks each expert's
     owner for its post-capacity served-token count, so every rank
     learns the true global expert load (the DeepSeek aux-loss-free
     bias-update signal) with ZERO extra collectives;
  2. bins arrivals per local expert (the same binning the hash kernel
     uses) and runs a batched expert FFN;
  3. the combine and the stats replies share one inverse all-to-all
     (``plan.finish``) and results merge with router weights.

Parallelism: experts sharded over 'model' (EP); per-expert weights
FSDP-sharded over the data axes and all-gathered just-in-time (EP x
ZeRO-3 — how 671B of expert weights fit 256 chips, DESIGN.md section 5).
Tokens are sequence-split over 'model' before dispatch so no rank
duplicates work.

Everything is differentiable: route/reply are built from sort/scatter/
all_to_all, all of which have transpose rules, so expert gradients flow
through the exchange exactly like activations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.backend import SpmdBackend
from repro.core.exchange import ExchangePlan
from repro.core.transport import make_transport
from repro.models.sharding import Axes
from repro.compat import shard_map

_F32 = jnp.float32
_U32 = jnp.uint32
_I32 = jnp.int32


def moe_init(rng, cfg, dtype):
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.expert_d_ff, mo.n_experts
    ks = jax.random.split(rng, 6)
    s_in, s_out = d ** -0.5, f ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(_F32),
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
            "w_in": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
            "w_out": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
        },
    }
    if mo.shared_experts:
        from repro.models.layers import mlp_init
        p["shared"] = mlp_init(ks[4], d, mo.expert_d_ff * mo.shared_experts,
                               cfg.activation, dtype)
    if mo.dense_residual:
        from repro.models.layers import mlp_init
        p["dense"] = mlp_init(ks[5], d, cfg.d_ff, cfg.activation, dtype)
    if mo.bias_update_rate > 0:
        p["moe_bias"] = jnp.zeros((e,), _F32)
    return p


def _pack_act(x, bf16: bool):
    """(N, D) activations -> u32 lanes; bf16 packs 2 values per lane
    (halves exchange wire bytes — EXPERIMENTS.md section Perf)."""
    if not bf16:
        return jax.lax.bitcast_convert_type(x.astype(_F32), _U32)
    n, d = x.shape
    h = jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16)
    return jax.lax.bitcast_convert_type(h.reshape(n, d // 2, 2), _U32)


def _unpack_act(lanes, bf16: bool):
    if not bf16:
        return jax.lax.bitcast_convert_type(lanes, _F32)
    n, dh = lanes.shape
    h = jax.lax.bitcast_convert_type(lanes, jnp.uint16).reshape(n, dh * 2)
    return jax.lax.bitcast_convert_type(h, jnp.bfloat16).astype(_F32)


def _bin_by_expert(rows, expert, valid, n_groups: int, cap: int):
    """Group rows (M, D) into (n_groups, cap, D) by expert id."""
    binned_idx, slot, ok = _bin_indices(expert, valid, n_groups, cap,
                                        rows.shape[0])
    binned = jnp.where((binned_idx >= 0)[:, None],
                       rows[jnp.maximum(binned_idx, 0)], 0)
    return binned.reshape(n_groups, cap, -1), slot, ok


def _bin_indices(expert, valid, n_groups: int, cap: int, m: int):
    """Slot assignment only: (flat_row_index (n_groups*cap,), slot (M,),
    ok (M,)); -1 marks empty bin slots."""
    g = jnp.where(valid, expert.astype(_I32), n_groups)
    counts_full = jnp.zeros((n_groups + 1,), _I32).at[g].add(1)
    start = jnp.concatenate([jnp.zeros((1,), _I32),
                             jnp.cumsum(counts_full)[:-1].astype(_I32)])
    order = jnp.argsort(g, stable=True)
    pos = jnp.arange(m, dtype=_I32) - start[g[order]]
    pos_orig = jnp.zeros((m,), _I32).at[order].set(pos)
    ok = valid & (pos_orig < cap)
    slot = jnp.where(ok, g * cap + pos_orig, n_groups * cap)
    binned_idx = jnp.full((n_groups * cap,), -1, _I32)
    binned_idx = binned_idx.at[slot].set(jnp.arange(m, dtype=_I32),
                                         mode="drop")
    return binned_idx, slot, ok


def _stats_flow(plan: ExchangePlan, e: int, e_loc: int) -> int:
    """Register the per-expert stats flow: one row per global expert,
    asking that expert's owner for its served-token count.  Capacity is
    exact (every rank sends exactly ``e_loc`` rows per owner), so the
    flow can never drop.

    The ragged fused wire (DESIGN.md section 1.5) makes this flow's
    cost independent of the token payload: its segment is exactly 2 u32
    request words (expert id + meta) and 1 reply word per row — byte-
    pinned in tests/test_wire_format.py — so global expert-load
    observability is genuinely free of d_model-width wire.
    ``max_rounds=1``: the capacity is exact, so the flow opts out of
    any retry rounds the token flow requests."""
    eid = jnp.arange(e, dtype=_I32)
    return plan.add((eid % e_loc).astype(_U32)[:, None], eid // e_loc,
                    e_loc, reply_lanes=1, op_name="moe.stats",
                    max_rounds=1)


def _stats_reply(committed, handle: int, served: jax.Array):
    """Owner side: answer each stats request with its expert's count."""
    sv = committed.view(handle)
    lid = jnp.where(sv.valid, sv.payload[:, 0].astype(_I32), 0)
    committed.set_reply(handle, jnp.where(sv.valid, served[lid], 0)
                        .astype(_U32))


def _make_expert_ffn(cfg):
    def _expert_ffn(binned, wg, wi, wo_):
        if cfg.activation in ("swiglu", "geglu"):
            act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
            h = act(jnp.einsum("ecd,edf->ecf", binned, wg)) * \
                jnp.einsum("ecd,edf->ecf", binned, wi)
        else:
            from repro.models.layers import activation_fn
            h = activation_fn(cfg.activation)(
                jnp.einsum("ecd,edf->ecf", binned, wi))
        return jnp.einsum("ecf,efd->ecd", h, wo_)
    return _expert_ffn


def moe_apply(params, x, cfg, mesh: Mesh, axes: Axes):
    """x (B, T, D) sharded over data -> same.

    Returns ``(y, aux, stats)``: the aux load-balance loss plus a stats
    dict with ``expert_load`` — the true global post-capacity
    served-token count per expert (E,), delivered by the stats flow that
    rides the dispatch plan's collectives — and ``dispatch_dropped``,
    the global count of token copies the exchange wire could not admit
    (the trajectory ``exchange.suggest_rounds`` reads to pick
    ``cfg.moe_dispatch_rounds``).  This is the observability signal
    DeepSeek-style bias routing (``moe_bias``) updates from; it costs
    zero extra collectives.
    """
    mo = cfg.moe
    b, t, d = x.shape
    e = mo.n_experts
    k = mo.top_k

    # ---- router (global) ----
    gate_logits = jnp.einsum("btd,de->bte", x.astype(_F32),
                             params["router"])
    if "moe_bias" in params:
        scores = jax.nn.sigmoid(gate_logits) + params["moe_bias"]
        _, top_idx = jax.lax.top_k(scores, k)
        top_p = jnp.take_along_axis(jax.nn.sigmoid(gate_logits), top_idx,
                                    axis=-1)
        top_w = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(gate_logits, axis=-1)
        top_w, top_idx = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (GShard)
    probs_mean = jax.nn.softmax(gate_logits, -1).mean(axis=(0, 1))
    hard = jnp.zeros((e,), _F32).at[top_idx.reshape(-1)].add(1.0)
    hard = hard / jnp.maximum(hard.sum(), 1.0)
    aux = mo.aux_loss_coef * e * jnp.sum(probs_mean * hard)

    # ---- dispatch over the model axis (the BCL exchange) ----
    nm = mesh.shape[axes.model]
    e_loc = -(-e // nm)
    seq_split = t % nm == 0 and nm > 1
    _expert_ffn = _make_expert_ffn(cfg)
    # physical collective layer for the dispatch plan (DESIGN.md §1.7)
    transport = make_transport(cfg.exchange_transport)
    # split-phase dispatch (DESIGN.md §1.9): commit_async issues the
    # wire, the always-on row-wise paths (shared/dense MLP) run in the
    # overlap window on the local shard, then finish() completes the
    # exchange before the owner-side expert compute
    async_ = bool(cfg.moe_async_dispatch)
    extra_keys = tuple(kk for kk in ("shared", "dense") if kk in params)

    def _overlap_window(xl, extras):
        from repro.models.layers import mlp
        out = None
        for p in extras:
            o = mlp(p, xl, cfg.activation)
            out = o if out is None else out + o
        return out

    def dispatch_dedup(xl, idxl, wl, wg, wi, wo_, *extras):
        """One exchange row per (token, distinct owner rank): the owner
        runs ALL of its local experts for the token and replies the
        weighted partial sum — for top-8 over 16 ranks the expected
        distinct-owner count is ~6.5, a ~19% cut of exchange rows in
        each direction (EXPERIMENTS.md section Perf iteration 6)."""
        bk = SpmdBackend(axes.model)
        bl, tl = xl.shape[0], xl.shape[1]
        n_tok = bl * tl
        n = n_tok * k
        exp_owners = nm * (1.0 - (1.0 - 1.0 / nm) ** k)
        cap = max(1, int(n_tok * min(k, exp_owners) / nm
                         * cfg.moe_capacity_slack) + 1)
        # retry rounds admit up to rounds x cap arrivals per (src,dst),
        # so the owner-side expert bins must scale with them too or the
        # rescued tokens would be silently zeroed at the bin stage
        e_cap = max(1, int(n_tok * k * nm / e * cfg.moe_capacity_slack)
                    + 1) * max(1, cfg.moe_dispatch_rounds)
        bf16 = cfg.moe_payload_dtype == "bfloat16"
        act_lanes = d // 2 if bf16 else d

        xx = xl.reshape(n_tok, d)
        ee = idxl.reshape(n_tok, k).astype(_I32)
        ww = wl.reshape(n_tok, k).astype(_F32)
        owners = ee // e_loc                                  # (n_tok, k)
        same = owners[:, :, None] == owners[:, None, :]       # (n_tok,j,i)
        first = ~jnp.triu(same, 1).any(axis=2)                # j is first
        # per (token, j) row: local expert ids + weights for MY owner
        ids = jnp.where(same, (ee % e_loc)[:, None, :], e_loc)  # (n_tok,j,i)
        wts = jnp.where(same, ww[:, None, :], 0.0)
        payload = jnp.concatenate(
            [_pack_act(jnp.repeat(xx, k, axis=0), bf16),
             ids.reshape(n, k).astype(_U32),
             jax.lax.bitcast_convert_type(wts.reshape(n, k), _U32)], axis=1)
        plan = ExchangePlan(name="moe.dispatch")
        h_tok = plan.add(payload, owners.reshape(-1), cap,
                         reply_lanes=act_lanes, valid=first.reshape(-1),
                         op_name="moe.dispatch")
        h_st = _stats_flow(plan, e, e_loc)
        if async_:
            pend = plan.commit_async(bk, max_rounds=cfg.moe_dispatch_rounds,
                                     transport=transport)
            win = _overlap_window(xl, extras)
            c = pend.finish(bk)
        else:
            win = None
            c = plan.commit(bk, max_rounds=cfg.moe_dispatch_rounds,
                            transport=transport)
        res = c.view(h_tok)

        m = res.payload.shape[0]
        rows = _unpack_act(res.payload[:, :act_lanes], bf16)   # (M, D)
        ids_m = res.payload[:, act_lanes:act_lanes + k].astype(_I32)
        wts_m = jax.lax.bitcast_convert_type(
            res.payload[:, act_lanes + k:act_lanes + 2 * k], _F32)
        flat_ids = ids_m.reshape(-1)
        flat_valid = jnp.repeat(res.valid, k) & (flat_ids < e_loc)
        flat_row = jnp.repeat(jnp.arange(m, dtype=_I32), k)
        flat_w = wts_m.reshape(-1)

        bin_idx, slot, okb = _bin_indices(flat_ids, flat_valid, e_loc,
                                          e_cap, m * k)
        src_row = jnp.where(bin_idx >= 0, flat_row[jnp.maximum(bin_idx, 0)],
                            0)
        binned = jnp.where((bin_idx >= 0)[:, None], rows[src_row], 0)
        binned = binned.reshape(e_loc, e_cap, d).astype(wg.dtype)
        y = _expert_ffn(binned, wg, wi, wo_)                   # (e_loc,cap,D)

        flat_y = y.reshape(e_loc * e_cap, d).astype(_F32)
        take = jnp.minimum(slot, e_loc * e_cap - 1)
        out_rows = jnp.zeros((m, d), _F32).at[
            jnp.where(okb, flat_row, m)].add(
            flat_y[take] * flat_w[:, None] * okb[:, None], mode="drop")

        served = jnp.zeros((e_loc,), _I32).at[
            jnp.where(okb, flat_ids, e_loc)].add(1, mode="drop")
        _stats_reply(c, h_st, served)
        c.set_reply(h_tok, _pack_act(out_rows, bf16))
        outs = c.finish(bk)
        out_lanes, _ = outs[h_tok]
        load = outs[h_st][0][:, 0].astype(_F32)[None]          # (1, e)
        yk = _unpack_act(out_lanes, bf16).reshape(n_tok, k, d)
        # weights applied at owner
        ybt = yk.sum(axis=1).reshape(bl, tl, d)
        if win is not None:
            ybt = ybt.astype(xl.dtype) + win
        return ybt, load, res.dropped[None]

    def dispatch(xl, idxl, wl, wg, wi, wo_, *extras):
        # xl (b_loc, t_loc, D); idxl/wl (b_loc, t_loc, K) — PER-DEVICE
        # shapes, so the static exchange capacities are sized from the
        # tokens this rank actually holds (uniform expectation x slack).
        if cfg.moe_dedup_dispatch:
            return dispatch_dedup(xl, idxl, wl, wg, wi, wo_, *extras)
        bk = SpmdBackend(axes.model)
        bl, tl = xl.shape[0], xl.shape[1]
        cap = max(1, int(bl * tl * k / nm * cfg.moe_capacity_slack) + 1)
        # expert bins scale with retry rounds (see dispatch_dedup)
        e_cap = max(1, int(bl * tl * k * nm / e * cfg.moe_capacity_slack)
                    + 1) * max(1, cfg.moe_dispatch_rounds)
        xx = jnp.repeat(xl.reshape(bl * tl, d), k, axis=0)     # (n, D)
        ee = idxl.reshape(-1).astype(_I32)                      # (n,)
        dest = ee // e_loc                                      # owner rank
        bf16 = cfg.moe_payload_dtype == "bfloat16"
        act_lanes = d // 2 if bf16 else d
        payload = jnp.concatenate(
            [_pack_act(xx, bf16),
             (ee % e_loc).astype(_U32)[:, None]], axis=1)
        plan = ExchangePlan(name="moe.dispatch")
        h_tok = plan.add(payload, dest, cap, reply_lanes=act_lanes,
                         op_name="moe.dispatch")
        h_st = _stats_flow(plan, e, e_loc)
        if async_:
            pend = plan.commit_async(bk, max_rounds=cfg.moe_dispatch_rounds,
                                     transport=transport)
            win = _overlap_window(xl, extras)
            c = pend.finish(bk)
        else:
            win = None
            c = plan.commit(bk, max_rounds=cfg.moe_dispatch_rounds,
                            transport=transport)
        res = c.view(h_tok)

        rows = _unpack_act(res.payload[:, :act_lanes], bf16)
        le = jnp.where(res.valid, res.payload[:, act_lanes].astype(_I32),
                       e_loc)
        binned, slot, okb = _bin_by_expert(rows, le, res.valid, e_loc, e_cap)
        binned = binned.astype(wg.dtype)

        # batched expert FFN (weights already all-gathered over fsdp axes
        # by the sharding constraint on entry — XLA inserts the gather)
        y = _expert_ffn(binned, wg, wi, wo_)                    # (e_loc,cap,D)

        flat = y.reshape(e_loc * e_cap, d)
        take = jnp.minimum(slot, e_loc * e_cap - 1)
        back_rows = jnp.where((slot < e_loc * e_cap)[:, None],
                              flat[take], 0).astype(_F32)
        served = jnp.zeros((e_loc,), _I32).at[
            jnp.where(okb, le, e_loc)].add(1, mode="drop")
        _stats_reply(c, h_st, served)
        c.set_reply(h_tok, _pack_act(back_rows, bf16))
        outs = c.finish(bk)
        out_lanes, _ = outs[h_tok]
        load = outs[h_st][0][:, 0].astype(_F32)[None]           # (1, e)
        yk = _unpack_act(out_lanes, bf16)                       # (n, D)
        yk = yk.reshape(bl, tl, k, d)
        ybt = jnp.einsum("btkd,btk->btd", yk, wl.astype(_F32))
        if win is not None:
            ybt = ybt.astype(xl.dtype) + win
        return ybt, load, res.dropped[None]

    din = axes.data
    if seq_split:
        in_x = P(din, axes.model, None)
        in_i = P(din, axes.model, None)
    else:
        in_x = P(din, None, None)
        in_i = P(din, None, None)
    espec = lambda *rest: P(axes.model, *rest)
    # under split-phase dispatch the shared/dense trees ride into the
    # shard_map (replicated) so the window can compute them on xl rows
    extra_args = tuple(params[kk] for kk in extra_keys) if async_ else ()
    y, load, drops = shard_map(
        dispatch, mesh=mesh,
        in_specs=(in_x, in_i, in_i,
                  espec(None, None), espec(None, None), espec(None, None))
                 + tuple(P() for _ in extra_args),
        out_specs=(in_x, P(din, None), P(din)),
        check_vma=False,   # replication over 'model' holds by construction
    )(x, top_idx.astype(_I32), top_w,
      params["experts"]["w_gate"], params["experts"]["w_in"],
      params["experts"]["w_out"], *extra_args)
    y = y.astype(x.dtype)
    expert_load = load.sum(axis=0)        # (E,) summed over data shards
    # wire drops of the token flow (already global over the model axis);
    # summed over data shards — the skew observability signal the
    # suggest_rounds heuristic and the --skew benchmarks read
    dispatch_dropped = drops.sum()

    # ---- always-on paths ----
    # (under async dispatch these were already folded in per shard,
    # inside the overlap window between commit_async and finish)
    if not async_:
        from repro.models.layers import mlp
        if "shared" in params:
            y = y + mlp(params["shared"], x, cfg.activation)
        if "dense" in params:
            y = y + mlp(params["dense"], x, cfg.activation)
    return y, aux, {"expert_load": expert_load,
                    "dispatch_dropped": dispatch_dropped}
