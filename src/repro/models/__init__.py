"""The LM framework built on the BCL container substrate.

Integration points with the paper's technique (DESIGN.md section 3):
  * MoE token dispatch  = core.exchange.route over the model axis
  * vocab-sharded embedding lookup = owner-computes DArray rget
  * decode KV cache     = hosted ring semantics (append = queue push)
"""
