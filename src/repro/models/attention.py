"""Attention: GQA/MQA with qk-norm, sliding windows, MLA, and KV caches.

Training/prefill uses *blockwise* attention: an unrolled loop over query
blocks, each scanning only the key blocks its mask can reach (causal
block-skipping is static, so HLO FLOPs match the causal ideal), with an
online-softmax accumulator.  This is flash attention expressed in XLA —
memory-bounded, differentiable, and visible to ``cost_analysis`` for the
roofline (the Pallas kernel in kernels/flash_attention.py is the TPU
fast path and is numerically validated against the same oracle).

Decode attends one query against the cache with a plain einsum (that
step is gather/bandwidth-bound, not compute-bound).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from repro.compat import shard_map

_F32 = jnp.float32
_NEG = -1e30


# ---------------------------------------------------------------------------
# blockwise attention (train / prefill)
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_block: int = 2048, k_block: int = 1024,
                        q_offset: int = 0, probs_bf16: bool = False):
    """q (B,Hq,Tq,hd), k/v (B,Hkv,Tk,hd) -> (B,Hq,Tq,hd).

    ``q_offset``: global position of q[0] relative to k[0] (suffix
    alignment: q_offset = Tk - Tq for decode-style calls).
    """
    b, hq, tq, hd = q.shape
    _, hkv, tk, _ = k.shape
    dv = v.shape[-1]          # may differ from hd (MLA)
    rep = hq // hkv
    qb = min(q_block, tq)
    kb = min(k_block, tk)
    scale = hd ** -0.5

    # pad K/V once to a block multiple; padded keys masked by position
    pad_k = (-tk) % kb
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    n_qb = -(-tq // qb)

    # grouped view avoids materializing repeated K/V
    qg = q.reshape(b, hkv, rep, tq, hd)

    outs = []
    for i in range(n_qb):
        q0 = i * qb
        cur_qb = min(qb, tq - q0)
        qi = jax.lax.dynamic_slice_in_dim(qg, q0, cur_qb, axis=3)
        # static key range reachable from this q block (causal block skip)
        hi = min(tk, q0 + q_offset + cur_qb) if causal else tk
        lo = 0
        if window > 0:
            lo = max(0, q0 + q_offset - window + 1)
        lo = (lo // kb) * kb
        hi = -(-max(hi, lo + 1) // kb) * kb
        n_kb = max(1, (hi - lo) // kb)

        m0 = jnp.full((b, hkv, rep, cur_qb, 1), _NEG, _F32)
        l0 = jnp.zeros((b, hkv, rep, cur_qb, 1), _F32)
        a0 = jnp.zeros((b, hkv, rep, cur_qb, dv), _F32)

        def body(carry, j, q0=q0, cur_qb=cur_qb, lo=lo, qi=qi):
            m_p, l_p, acc = carry
            k0 = lo + j * kb
            kj = jax.lax.dynamic_slice_in_dim(k, k0, kb, axis=2)
            vj = jax.lax.dynamic_slice_in_dim(v, k0, kb, axis=2)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qi.astype(_F32),
                           kj.astype(_F32)) * scale
            qpos = q0 + q_offset + jnp.arange(cur_qb)[:, None]
            kpos = k0 + jnp.arange(kb)[None, :]
            mask = kpos < tk
            if causal:
                mask &= kpos <= qpos
            if window > 0:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None, None], s, _NEG)
            m_n = jnp.maximum(m_p, s.max(axis=-1, keepdims=True))
            p = jnp.exp(s - m_n)
            alpha = jnp.exp(m_p - m_n)
            l_n = alpha * l_p + p.sum(axis=-1, keepdims=True)
            if probs_bf16:
                # halve the PV-matmul operand bytes; the normalizer and
                # accumulator stay f32 so the softmax is still exact
                pv = jnp.einsum("bgrqk,bgkd->bgrqd",
                                p.astype(jnp.bfloat16),
                                vj.astype(jnp.bfloat16),
                                preferred_element_type=_F32)
            else:
                pv = jnp.einsum("bgrqk,bgkd->bgrqd", p, vj.astype(_F32))
            acc = acc * alpha + pv
            return (m_n, l_n, acc), None

        (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                          jnp.arange(n_kb))
        blk = (acc / jnp.maximum(l_f, 1e-30)).astype(q.dtype)
        outs.append(blk)

    og = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    return og.reshape(b, hq, tq, dv)


def decode_attention(q, k, v, kv_len, lo=None):
    """q (B,Hq,1,hd) against cache k/v (B,Hkv,S,hd); kv_len masks unfilled.

    ``lo`` (optional) masks cache slots below it — the sliding-window
    bound when a windowed layer keeps the full-length cache."""
    b, hq, _, hd = q.shape
    _, hkv, s, _ = k.shape
    dv = v.shape[-1]
    rep = hq // hkv
    qg = q.reshape(b, hkv, rep, hd)
    logits = jnp.einsum("bgrd,bgkd->bgrk", qg.astype(_F32),
                        k.astype(_F32)) * (hd ** -0.5)
    pos = jnp.arange(s)[None, None, None]
    mask = pos < kv_len
    if lo is not None:
        mask &= pos >= lo
    logits = jnp.where(mask, logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bgrk,bgkd->bgrd", p, v.astype(_F32))
    return o.reshape(b, hq, 1, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# standard GQA block
# ---------------------------------------------------------------------------

def attn_init(rng, cfg, dtype):
    d, hd = cfg.d_model, cfg.head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, nq * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, nkv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, nkv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (nq * hd, d)) * (nq * hd) ** -0.5
               ).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention(params, x, cfg, *, positions, causal=True, window=0,
              cache=None, cache_len=None, kv_source=None):
    """Full attention block. Returns (out, new_cache | None).

    cache: dict(k (B,Hkv,S,hd), v, len()) for decode; when given and
    x has T==1, appends and attends over the cache.
    kv_source: encoder output for cross-attention (no cache logic here —
    prefill computes cross KV once and stores it in the cache).
    """
    from repro.models.layers import rms_norm, rotary
    b, t, d = x.shape
    hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads

    q = (x @ params["wq"]).reshape(b, t, nq, hd).transpose(0, 2, 1, 3)
    src = x if kv_source is None else kv_source
    ts = src.shape[1]
    k = (src @ params["wk"]).reshape(b, ts, nkv, hd).transpose(0, 2, 1, 3)
    v = (src @ params["wv"]).reshape(b, ts, nkv, hd).transpose(0, 2, 1, 3)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    if kv_source is None:  # self-attention: rotary on both
        q = rotary(q, positions[:, None, :], cfg.rope_theta)
        k = rotary(k, positions[:, None, :] if t == ts else
                   jnp.arange(ts)[None, None, :], cfg.rope_theta)

    new_cache = None
    if cache is not None and t == 1:
        # decode: append at the absolute position, or modulo the ring
        # size for window-capped caches (cfg.window_cache)
        pos = cache_len
        s_cache = cache["k"].shape[2]
        ring = window > 0 and s_cache <= window
        slot = pos % s_cache if ring else pos
        ck = _cache_append(cache["k"], k, slot)
        cv = _cache_append(cache["v"], v, slot)
        new_cache = {"k": ck, "v": cv}
        kv_len = jnp.minimum(pos + 1, s_cache) if ring else pos + 1
        lo = jnp.maximum(pos + 1 - window, 0) \
            if (window > 0 and not ring) else None
        out = decode_attention(q, ck, cv, kv_len, lo=lo)
    else:
        out = blockwise_attention(q, k, v, causal=causal, window=window,
                                  q_block=cfg.attn_q_block,
                                  k_block=cfg.attn_k_block,
                                  probs_bf16=cfg.attn_probs_bf16)
        if cache is not None:  # prefill into cache
            s = cache["k"].shape[2]
            if s < ts:
                # window-capped ring: keep the last s keys, stored at
                # row p % s so decode's ring append stays consistent
                shift = (ts - s) % s
                ck = jnp.roll(k[:, :, -s:], shift, axis=2)
                cv = jnp.roll(v[:, :, -s:], shift, axis=2)
            else:
                ck = jnp.pad(k, ((0, 0), (0, 0), (0, s - ts), (0, 0)))
                cv = jnp.pad(v, ((0, 0), (0, 0), (0, s - ts), (0, 0)))
            new_cache = {"k": ck.astype(cache["k"].dtype),
                         "v": cv.astype(cache["v"].dtype)}

    out = out.transpose(0, 2, 1, 3).reshape(b, t, nq * hd)
    return out @ params["wo"], new_cache


def _cache_append(buf, x, pos):
    """Append x (B,H,1,hd) at position pos (dynamic) in buf (B,H,S,hd)."""
    return jax.lax.dynamic_update_slice(
        buf, x.astype(buf.dtype), (0, 0, pos, 0))


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(rng, cfg, dtype):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(rng, 6)
    s = d ** -0.5
    qh = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": (jax.random.normal(ks[0], (d, m.q_lora_rank)) * s).astype(dtype),
        "w_uq": (jax.random.normal(ks[1], (m.q_lora_rank, h * qh))
                 * m.q_lora_rank ** -0.5).astype(dtype),
        "w_dkv": (jax.random.normal(ks[2], (d, m.kv_lora_rank)) * s).astype(dtype),
        "w_kr": (jax.random.normal(ks[3], (d, m.qk_rope_head_dim)) * s).astype(dtype),
        "w_ukv": (jax.random.normal(
            ks[4], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)))
            * m.kv_lora_rank ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[5], (h * m.v_head_dim, d))
               * (h * m.v_head_dim) ** -0.5).astype(dtype),
    }


def mla_attention(params, x, cfg, *, positions, cache=None, cache_len=None,
                  mesh=None, axes=None):
    """MLA with the compressed (c_kv, k_rope) cache. Returns (out, cache)."""
    from repro.models.layers import rotary
    m = cfg.mla
    b, t, d = x.shape
    h = cfg.n_heads
    nope, rope, vdim = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = ((x @ params["w_dq"]) @ params["w_uq"]).reshape(b, t, h, nope + rope)
    q = q.transpose(0, 2, 1, 3)                     # (B,H,T,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rotary(q_rope, positions[:, None, :], cfg.rope_theta)

    c_kv = x @ params["w_dkv"]                      # (B,T,r)
    k_rope = x @ params["w_kr"]                     # (B,T,rope) shared head
    k_rope = rotary(k_rope[:, None], positions[:, None, :],
                    cfg.rope_theta)[:, 0]

    new_cache = None
    if cache is not None and t == 1:
        pos = cache_len
        if cfg.mla_absorb and cfg.mla_cp_decode and mesh is not None:
            out, new_cache = mla_absorbed_decode_cp(
                params, cfg, q_nope, q_rope, c_kv[:, 0], k_rope[:, 0],
                cache, pos, mesh, axes)
            return out @ params["wo"], new_cache
        c_full = jax.lax.dynamic_update_slice(cache["c_kv"],
                                              c_kv.astype(cache["c_kv"].dtype),
                                              (0, pos, 0))
        r_full = jax.lax.dynamic_update_slice(cache["k_rope"],
                                              k_rope.astype(cache["k_rope"].dtype),
                                              (0, pos, 0))
        new_cache = {"c_kv": c_full, "k_rope": r_full}
        if cfg.mla_absorb:
            # DeepSeek weight absorption: attend in the LATENT space —
            # never re-expand K/V for the whole cache.  Per step:
            # O(B*H*S*(r+rope)) instead of O(B*S*r*H*(nope+v)), a ~2
            # orders-of-magnitude decode-compute cut at 32k
            # (EXPERIMENTS.md section Perf, deepseek decode cell).
            out = _mla_absorbed_decode(params, cfg, q_nope, q_rope,
                                       c_full, r_full, pos + 1)
            return out @ params["wo"], new_cache
        c_kv, k_rope = c_full, r_full
        s_len = c_kv.shape[1]
        kv_mask_len = pos + 1
    else:
        s_len = t
        kv_mask_len = None
        if cache is not None:
            s = cache["c_kv"].shape[1]
            new_cache = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, s - t), (0, 0))
                                ).astype(cache["c_kv"].dtype),
                "k_rope": jnp.pad(k_rope, ((0, 0), (0, s - t), (0, 0))
                                  ).astype(cache["k_rope"].dtype)}

    kv = (c_kv @ params["w_ukv"]).reshape(b, s_len, h, nope + vdim)
    kv = kv.transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, None], (b, h, s_len, rope))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)

    if kv_mask_len is not None:
        out = decode_attention(q_full, k, v, kv_mask_len)
    else:
        out = blockwise_attention(q_full, k, v, causal=True,
                                  q_block=cfg.attn_q_block,
                                  k_block=cfg.attn_k_block,
                                  probs_bf16=cfg.attn_probs_bf16)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, h * vdim)
    return out @ params["wo"], new_cache


def mla_absorbed_decode_cp(params, cfg, q_nope, q_rope, new_c, new_kr,
                           cache, pos, mesh, axes):
    """Context-parallel absorbed MLA decode: the compressed cache's
    SEQUENCE dim is sharded over the model axis; each rank attends its
    slice and a two-pass (flash-style) softmax combine merges partials:

      M = pmax(m_i);  l = psum(l_i * e^{m_i-M});  ctx = psum(ctx_i * ...)

    This is what makes a (128, 32k, 576) cache fit per-device HBM:
    18.4 GiB (data-sharded only, replicated over model) -> 1.15 GiB.
    Returns (out (B,1,H*vdim), new_cache).
    """
    m = cfg.mla
    b, h, _, nope = q_nope.shape
    r = m.kv_lora_rank
    vdim = m.v_head_dim
    w_full = params["w_ukv"].reshape(r, h, nope + vdim)
    w_uk, w_uv = w_full[:, :, :nope], w_full[:, :, nope:]
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, :, 0].astype(_F32),
                       w_uk.astype(_F32))                    # (B,H,r)
    qr = q_rope[:, :, 0].astype(_F32)                        # (B,H,rope)
    scale = (nope + m.qk_rope_head_dim) ** -0.5
    from jax.sharding import PartitionSpec as P

    def f(ql, qro, nc, nk, ckv, kr):
        rank = jax.lax.axis_index(axes.model)
        s_loc = ckv.shape[1]
        lpos = pos - rank * s_loc
        in_rng = (lpos >= 0) & (lpos < s_loc)
        lclip = jnp.clip(lpos, 0, s_loc - 1)
        upd_c = jax.lax.dynamic_update_slice(
            ckv, nc[:, None].astype(ckv.dtype), (0, lclip, 0))
        ckv = jnp.where(in_rng, upd_c, ckv)
        upd_k = jax.lax.dynamic_update_slice(
            kr, nk[:, None].astype(kr.dtype), (0, lclip, 0))
        kr = jnp.where(in_rng, upd_k, kr)

        cf = ckv.astype(_F32)
        s = jnp.einsum("bhr,bsr->bhs", ql, cf)
        s = s + jnp.einsum("bhp,bsp->bhs", qro, kr.astype(_F32))
        s = s * scale
        gpos = rank * s_loc + jnp.arange(s_loc)[None, None]
        s = jnp.where(gpos <= pos, s, _NEG)
        m_i = s.max(axis=-1)                                  # (B,H)
        e = jnp.exp(s - m_i[..., None])
        e = jnp.where(gpos <= pos, e, 0.0)
        l_i = e.sum(axis=-1)
        ctx_i = jnp.einsum("bhs,bsr->bhr", e, cf)
        m_g = jax.lax.pmax(m_i, axes.model)
        w = jnp.exp(m_i - m_g)
        l_g = jax.lax.psum(l_i * w, axes.model)
        ctx = jax.lax.psum(ctx_i * w[..., None], axes.model)
        ctx = ctx / jnp.maximum(l_g, 1e-30)[..., None]
        return ctx, ckv, kr

    d = axes.data
    bdim = q_lat.shape[0]
    n_data = 1
    for a in d:
        n_data *= mesh.shape[a]
    lead = d if bdim % n_data == 0 else None
    ctx, ckv2, kr2 = shard_map(
        f, mesh=mesh,
        in_specs=(P(lead, None, None), P(lead, None, None),
                  P(lead, None), P(lead, None),
                  P(lead, axes.model, None), P(lead, axes.model, None)),
        out_specs=(P(lead, None, None),
                   P(lead, axes.model, None), P(lead, axes.model, None)),
        check_vma=False,
    )(q_lat, qr, new_c, new_kr, cache["c_kv"], cache["k_rope"])
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(_F32))
    return (out.reshape(b, 1, h * vdim).astype(q_nope.dtype),
            {"c_kv": ckv2, "k_rope": kr2})


def _mla_absorbed_decode(params, cfg, q_nope, q_rope, c_kv, k_rope, kv_len):
    """Latent-space MLA decode (weight absorption).

    q_nope (B,H,1,nope), q_rope (B,H,1,rope); cache c_kv (B,S,r),
    k_rope (B,S,rope).  Scores: q_nope^T (W_uk c) = (W_uk^T q_nope)^T c,
    so queries are projected DOWN once and the cache is used as-is; the
    context is likewise accumulated in latent space and expanded once.
    Returns (B, 1, H*vdim).
    """
    m = cfg.mla
    b, h, _, nope = q_nope.shape
    r = m.kv_lora_rank
    vdim = m.v_head_dim
    w_full = params["w_ukv"].reshape(r, h, nope + vdim)
    w_uk = w_full[:, :, :nope]
    w_uv = w_full[:, :, nope:]

    cf = c_kv.astype(_F32)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, :, 0].astype(_F32),
                       w_uk.astype(_F32))                    # (B,H,r)
    scores = jnp.einsum("bhr,bsr->bhs", q_lat, cf)
    scores = scores + jnp.einsum("bhp,bsp->bhs",
                                 q_rope[:, :, 0].astype(_F32),
                                 k_rope.astype(_F32))
    scores = scores * ((nope + m.qk_rope_head_dim) ** -0.5)
    s = c_kv.shape[1]
    mask = jnp.arange(s)[None, None] < kv_len
    scores = jnp.where(mask, scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", probs, cf)              # (B,H,r)
    out = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(_F32))  # (B,H,v)
    return out.reshape(b, 1, h * vdim).astype(q_nope.dtype)
