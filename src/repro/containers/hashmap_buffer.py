"""BCL::HashMapBuffer (paper section 5.3): buffered hash-table insertion.

The paper's HashMapBuffer turns fine-grained latency-bound inserts into
bulk bandwidth-bound ones: inserts land in local per-destination
buffers; full buffers are pushed to a FastQueue on the owning node; a
``flush()`` drains every node's own queue with *local* fast inserts
(Table 3b).  Figure 4 shows the one-line user-code change.

This port keeps the exact same three-stage pipeline:

  insert()  ->  local append (cost l, zero collectives)
  spill()   ->  FastQueue.push of full buffers (one flow on an
                ExchangePlan, cost A + nW; ``spill_flow``/``spill_apply``
                let the push ride a caller's plan so the spill shares
                collectives with concurrent container ops)
  flush()   ->  owner drains its own queue, local bulk insert (cost l)

Buffer capacity is static; ``insert`` reports overflow so callers (or
the scan-driven benchmark loop) spill on a fixed cadence.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.exchange import (CommittedPlan, ExchangePlan,
                                 PendingResult)
from repro.core.promises import ConProm, Promise
from repro.containers import hashmap as hm
from repro.containers import queue as q
from repro.kernels import ops as kops

_U32 = jnp.uint32
_I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class HashMapBufferSpec:
    map_spec: hm.HashMapSpec
    queue_spec: q.QueueSpec
    buffer_cap: int      # local staging capacity (elements)

    @property
    def lanes(self) -> int:
        return self.map_spec.key_packer.lanes + self.map_spec.val_packer.lanes


class HashMapBufferState(NamedTuple):
    map: hm.HashMapState
    queue: q.QueueState
    buf: jax.Array      # (buffer_cap, Lk+Lv) u32
    buf_dest: jax.Array  # (buffer_cap,) i32 owner rank per staged item
    buf_n: jax.Array    # (1,) i32


def create(backend: Backend, map_spec: hm.HashMapSpec,
           map_state: hm.HashMapState, queue_capacity: int,
           buffer_cap: int) -> tuple[HashMapBufferSpec, HashMapBufferState]:
    """Wrap an existing hash map (paper Fig. 4 constructor)."""
    lanes = map_spec.key_packer.lanes + map_spec.val_packer.lanes
    qspec, qstate = q.queue_create(backend, queue_capacity, lanes)
    spec = HashMapBufferSpec(map_spec, qspec, buffer_cap)
    state = HashMapBufferState(
        map_state, qstate,
        jnp.zeros((buffer_cap, lanes), _U32),
        jnp.zeros((buffer_cap,), _I32),
        jnp.zeros((1,), _I32))
    return spec, state


def insert(spec: HashMapBufferSpec, state: HashMapBufferState,
           keys, vals, valid: jax.Array | None = None):
    """Stage a batch locally (no communication). Returns (state, overflow)."""
    ms = spec.map_spec
    klanes = ms.key_packer.pack(keys)
    vlanes = ms.val_packer.pack(vals)
    n = klanes.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    gblock = hm._block_of(ms, klanes, 0)
    owner = gblock // ms.nblocks_local

    rows = jnp.concatenate([klanes, vlanes], axis=1)
    pos = state.buf_n[0] + jnp.cumsum(valid.astype(_I32)) - valid.astype(_I32)
    in_cap = valid & (pos < spec.buffer_cap)
    slot = jnp.where(in_cap, pos, spec.buffer_cap)
    buf = state.buf.at[slot].set(rows, mode="drop")
    buf_dest = state.buf_dest.at[slot].set(owner, mode="drop")
    n_new = jnp.minimum(state.buf_n[0] + valid.sum().astype(_I32),
                        spec.buffer_cap)
    overflow = (state.buf_n[0] + valid.sum().astype(_I32)) - n_new
    costs.record("hashmap_buffer.insert", costs.Cost(local=n))
    return state._replace(buf=buf, buf_dest=buf_dest,
                          buf_n=n_new[None]), overflow


def spill_flow(plan: ExchangePlan, spec: HashMapBufferSpec,
               state: HashMapBufferState, capacity: int,
               ring_reply: bool = False) -> int:
    """Register the staged buffer's queue push as a flow on ``plan``.

    The spill is exactly the FastQueue push it wraps, so it rides
    whatever plan the caller is committing this round — fusing the
    spill's collective with any concurrent container ops — instead of
    demanding a round of its own, and the ragged wire (DESIGN.md
    section 1.5) guarantees the ride is free: the spill segment costs
    exactly its own ``Lk+Lv+1`` words per row however wide the host
    plan's other flows are.  Pair with :func:`spill_apply` after
    ``plan.commit``.

    ``ring_reply`` declares the 1-lane acceptance reply that closes the
    ring-full loss path (DESIGN.md section 1.6, same contract as
    ``queue.push(overflow="carry")``): :func:`spill_apply` stages the
    owner's ``_append`` accept mask on it, and after the plan's
    ``finish`` — the caller's, when the spill shares a plan —
    :func:`spill_absorb` folds BOTH ring rejects and wire leftovers
    back into the staging buffer.
    """
    live = jnp.arange(spec.buffer_cap, dtype=_I32) < state.buf_n[0]
    return plan.add(state.buf, state.buf_dest, capacity, valid=live,
                    reply_lanes=1 if ring_reply else 0,
                    op_name="queue.push")


def spill_apply(backend: Backend, committed: CommittedPlan, handle: int,
                spec: HashMapBufferSpec, state: HashMapBufferState,
                overflow: str = "drop"):
    """Owner-side half of the spill: ring-append the arrived flow.

    With ``overflow="carry"`` the items the wire could not admit (bucket
    rank beyond every retry round's window) are NOT dropped: the
    committed plan's :meth:`~repro.core.exchange.CommittedPlan.leftover`
    mask re-stages them at the front of the local buffer, to ride the
    next spill — the paper's re-insert-on-failed-fetch-and-add loop.
    The returned drop count then covers ring overflow only.

    When the flow declared the ring reply (``spill_flow(...,
    ring_reply=True)``) a carry spill stages the accept mask on the
    plan instead and leaves the buffer untouched; the caller finishes
    the plan (fused with its other flows' replies) and calls
    :func:`spill_absorb`, after which ring rejects are re-staged too
    and the drop count is zero.
    """
    view = committed.view(handle)
    qstate, _, full_drop, accept = q._append(spec.queue_spec, state.queue,
                                             view.payload, view.valid)
    a = q._amo_count(spec.queue_spec, ConProm.CircularQueue.push)
    costs.record("queue.push", costs.Cost(A=a, W=spec.buffer_cap))
    if overflow == "carry":
        if committed.reply_lanes(handle) > 0:
            # ring-full backpressure: the accept mask rides the plan's
            # inverse permutation; the buffer stays intact until
            # spill_absorb sees which rows actually landed
            committed.set_reply(handle, accept.astype(_U32))
            return state._replace(queue=qstate), jnp.int32(0)
        _, mask = committed.leftover(handle)
        # compact the carried rows to the buffer's front
        pos = jnp.cumsum(mask.astype(_I32)) - mask.astype(_I32)
        slot = jnp.where(mask, pos, spec.buffer_cap)
        buf = jnp.zeros_like(state.buf).at[slot].set(state.buf, mode="drop")
        buf_dest = jnp.zeros_like(state.buf_dest).at[slot].set(
            state.buf_dest, mode="drop")
        state = state._replace(queue=qstate, buf=buf, buf_dest=buf_dest,
                               buf_n=mask.sum().astype(_I32)[None])
        return state, backend.psum(full_drop)
    state = state._replace(queue=qstate, buf_n=jnp.zeros((1,), _I32))
    return state, view.dropped + backend.psum(full_drop)


def spill_absorb(outs: tuple, spec: HashMapBufferSpec,
                 state: HashMapBufferState) -> HashMapBufferState:
    """Requester-side close of a ring-reply carry spill.

    ``outs`` is the finished plan's entry for the spill flow —
    ``(accept_rows, answered)`` aligned with the staging buffer.  A row
    landed iff it shipped AND the owner's ring accepted it; every other
    live row (wire leftover or ring reject) compacts back to the front
    of the buffer to ride the next spill — one mask covers both loss
    paths, like ``queue.push(overflow="carry")``.
    """
    rows, answered = outs
    live = jnp.arange(spec.buffer_cap, dtype=_I32) < state.buf_n[0]
    landed = answered & (rows[:, 0] == 1) & live
    mask = live & ~landed
    pos = jnp.cumsum(mask.astype(_I32)) - mask.astype(_I32)
    slot = jnp.where(mask, pos, spec.buffer_cap)
    buf = jnp.zeros_like(state.buf).at[slot].set(state.buf, mode="drop")
    buf_dest = jnp.zeros_like(state.buf_dest).at[slot].set(
        state.buf_dest, mode="drop")
    return state._replace(buf=buf, buf_dest=buf_dest,
                          buf_n=mask.sum().astype(_I32)[None])


def spill(backend: Backend, spec: HashMapBufferSpec,
          state: HashMapBufferState, capacity: int,
          max_rounds: int = 1, overflow: str = "drop",
          transport=None, async_: bool = False):
    """Push staged items to the owners' FastQueues (paper: buffer full).

    Eager wrapper: a fresh single-flow plan around
    :func:`spill_flow`/:func:`spill_apply`.  With ``overflow="carry"``
    the flow declares the ring reply, so the spill is lossless against
    BOTH wire overflow and ring-full rejects (the drop count is then
    zero — everything unlanded is re-staged in the returned buffer).

    ``async_=True`` issues the plan split-phase (DESIGN.md section 1.9)
    and instead returns a :class:`~repro.core.PendingResult` whose
    ``finish()`` yields the same ``(state, dropped)``.
    """
    plan = ExchangePlan(name="queue.push")
    carrying = overflow == "carry"
    h = spill_flow(plan, spec, state, capacity, ring_reply=carrying)

    def complete(committed):
        st, dropped = spill_apply(backend, committed, h, spec, state,
                                  overflow=overflow)
        if carrying:
            st = spill_absorb(committed.finish(backend)[h], spec, st)
        return st, dropped

    if async_:
        pend = plan.commit_async(backend, max_rounds=max_rounds,
                                 overflow=overflow, transport=transport)
        return PendingResult(lambda: complete(pend.finish(backend)))
    return complete(plan.commit(backend, max_rounds=max_rounds,
                                overflow=overflow, transport=transport))


def flush(backend: Backend, spec: HashMapBufferSpec,
          state: HashMapBufferState, capacity: int,
          mode: int = kops.MODE_SET,
          max_rounds: int = 1, overflow: str = "drop",
          transport=None, async_: bool = False):
    """Spill + drain own queue with fast local inserts (paper flush()).

    Returns (state, dropped) — dropped counts route/ring/table overflow.
    With ``overflow="carry"`` neither wire overflow NOR ring-full
    rejects are dropped: unlanded items stay staged in the returned
    state's buffer (``buf_n > 0``) for the caller's next flush cycle,
    so repeated flushes are lossless as long as the table keeps up;
    ``max_rounds`` shrinks the number of cycles needed by retrying
    inside the spill itself.

    ``async_=True`` runs the SPILL wire split-phase: the caller's own
    compute overlaps the spill exchange (the drain + local insert stay
    ordered after the wire — they consume what the spill delivers), and
    the returned :class:`~repro.core.PendingResult` finishes to the
    same ``(state, dropped)``.
    """
    if async_:
        pend = spill(backend, spec, state, capacity,
                     max_rounds=max_rounds, overflow=overflow,
                     transport=transport, async_=True)
        return PendingResult(lambda: _flush_complete(
            backend, spec, *pend.finish(), mode=mode))
    st, dropped = spill(backend, spec, state, capacity,
                        max_rounds=max_rounds, overflow=overflow,
                        transport=transport)
    return _flush_complete(backend, spec, st, dropped, mode=mode)


def _flush_complete(backend, spec, state, dropped, mode):
    """Drain + local-insert half of :func:`flush` (both the synchronous
    and the split-phase path complete through here)."""
    backend.barrier()

    rows, got = q.local_drain(spec.queue_spec, state.queue)
    qstate = state.queue._replace(head=state.queue.tail)
    ms = spec.map_spec
    klanes = rows[:, :ms.key_packer.lanes]
    vlanes = rows[:, ms.key_packer.lanes:]
    mstate, ok = hm.insert(backend, ms, state.map,
                           ms.key_packer.unpack(klanes),
                           ms.val_packer.unpack(vlanes),
                           capacity=1, promise=ConProm.HashMap.local,
                           valid=got, mode=mode)
    failed = backend.psum((got & ~ok).sum()).astype(_I32)
    return state._replace(map=mstate, queue=qstate), dropped + failed
