"""BCL::HashMap — the distributed hash table (paper section 5.1).

Layout: a logically contiguous array of *blocks* of B buckets,
distributed block-wise across ranks (DESIGN.md: "blocked open
addressing").  A key hashes to a block; probing compares the key against
all B slots of the block at once (vectorized; the Pallas kernel's tile).
When a block fills, the container rehashes the failed items to a new
block — quadratic in the attempt number — with a bounded number of
attempts, mirroring the paper's quadratic probing plus its "insertion
may fail when full" semantics.

Concurrency promises select the schedule (paper Table 3):
  (a) find|insert   fully atomic   insert 2A + W     find 2A + R
  (b) local         local insert   l
  (c) find|insert   fully atomic find
  (d) find          phase-local find: one read, no AMOs     R

"Atomic" ops execute the paper's flag dance (reserve CAS / read-bit
fetch-or + fetch-and) as real owner-side RMW passes over the status
word, so their extra cost is measurable; promise-relaxed ops skip it.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.exchange import route, reply
from repro.core.hashing import hash_lanes
from repro.core.object_container import Packer, packer_for
from repro.core.promises import (Promise, find_only, fully_atomic_hashmap,
                                 local_only)
from repro.kernels import ops as kops

_U32 = jnp.uint32
_I32 = jnp.int32

# a "read bit" in the upper 30 bits of the status word (paper 5.1.2)
_READ_BIT = jnp.uint32(1 << 7)


@dataclasses.dataclass(frozen=True)
class HashMapSpec:
    nblocks_global: int
    nblocks_local: int
    block_size: int
    key_packer: Packer
    val_packer: Packer
    impl: str = "auto"   # kernel dispatch: auto|jnp|pallas|oracle

    @property
    def capacity(self) -> int:
        return self.nblocks_global * self.block_size


class HashMapState(NamedTuple):
    tkeys: jax.Array    # (nb_local, B, Lk) u32
    tvals: jax.Array    # (nb_local, B, Lv) u32
    status: jax.Array   # (nb_local, B) u32


def hashmap_create(backend: Backend, capacity: int, key_spec, val_spec,
                   block_size: int = 128,
                   impl: str = "auto") -> tuple[HashMapSpec, HashMapState]:
    """Collective constructor (paper 5.1.1): fixed size, fixed K/V types."""
    kp, vp = packer_for(key_spec), packer_for(val_spec)
    nprocs = backend.nprocs()
    nb_global = max(1, -(-capacity // block_size))
    nb_global = -(-nb_global // nprocs) * nprocs       # round up to P
    nb_local = nb_global // nprocs
    spec = HashMapSpec(nb_global, nb_local, block_size, kp, vp, impl)
    state = HashMapState(
        jnp.zeros((nb_local, block_size, kp.lanes), _U32),
        jnp.zeros((nb_local, block_size, vp.lanes), _U32),
        jnp.zeros((nb_local, block_size), _U32))
    return spec, state


def _block_of(spec: HashMapSpec, key_lanes: jax.Array,
              attempt: int) -> jax.Array:
    """Global block index; attempts rehash quadratically (paper 5.1)."""
    h1 = hash_lanes(key_lanes, seed=1)
    if attempt == 0:
        g = h1
    else:
        h2 = hash_lanes(key_lanes, seed=3) | _U32(1)
        g = h1 + jnp.uint32(attempt * attempt) * h2
    return (g % jnp.uint32(spec.nblocks_global)).astype(_I32)


def _owner_local(spec: HashMapSpec, gblock: jax.Array):
    return gblock // spec.nblocks_local, gblock % spec.nblocks_local


def insert(backend: Backend, spec: HashMapSpec, state: HashMapState,
           keys, vals, capacity: int,
           promise: Promise = Promise.FIND | Promise.INSERT,
           valid: jax.Array | None = None,
           mode: int = kops.MODE_SET,
           attempts: int = 2,
           return_success: bool = True):
    """Insert a batch of (key, value) pairs.

    Returns (state, success(N,) | None).  With ``promise=local`` the keys
    must hash to this rank's own blocks (cost l, no collectives) — the
    HashMapBuffer flush path (paper Table 3b).
    """
    klanes = spec.key_packer.pack(keys)
    vlanes = spec.val_packer.pack(vals)
    n = klanes.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)

    if local_only(promise):
        gblock = _block_of(spec, klanes, 0)
        _, lblock = _owner_local(spec, gblock)
        tk, tv, st, ok = kops.bulk_insert(
            state.tkeys, state.tvals, state.status, lblock, klanes, vlanes,
            valid, mode, impl=spec.impl)
        costs.record("hashmap.insert", costs.Cost(local=n))
        return HashMapState(tk, tv, st), ok

    atomic = fully_atomic_hashmap(promise)
    pending = valid
    success = jnp.zeros((n,), bool)
    new_state = state
    for a in range(max(1, attempts)):
        gblock = _block_of(spec, klanes, a)
        owner, lblock = _owner_local(spec, gblock)
        body = jnp.concatenate(
            [lblock.astype(_U32)[:, None], klanes, vlanes], axis=1)
        res = route(backend, body, owner, capacity, valid=pending,
                    op_name="hashmap.insert", impl=spec.impl)
        rb = jnp.where(res.valid, res.payload[:, 0].astype(_I32), 0)
        rk = res.payload[:, 1:1 + spec.key_packer.lanes]
        rv = res.payload[:, 1 + spec.key_packer.lanes:]

        tk, tv, st = new_state
        if atomic:
            # paper 5.1.3: CAS free->reserved ... XOR ->ready.  The state
            # machine is owner-serialized here, but we execute the reserve
            # pass so its traffic is real: a net-zero RMW on the status
            # word of every touched block.
            st = st.at[rb].add(_READ_BIT, mode="drop")
            st = st.at[rb].add(_U32(0) - _READ_BIT, mode="drop")
        tk, tv, st, ok_here = kops.bulk_insert(
            tk, tv, st, rb, rk, rv, res.valid, mode, impl=spec.impl)
        new_state = HashMapState(tk, tv, st)

        if return_success or attempts > 1:
            back, _ = reply(backend, res, ok_here.astype(_U32), n,
                            op_name="hashmap.insert")
            ok_src = (back[:, 0] == 1) & pending
            success = success | ok_src
            pending = pending & ~ok_src
        else:
            break
    costs.record("hashmap.insert",
                 costs.Cost(A=2 if atomic else 1, W=n))
    return new_state, (success if (return_success or attempts > 1) else None)


def _find_speculative(backend: Backend, spec: HashMapSpec,
                      state: HashMapState, klanes, capacity: int,
                      valid, atomic: bool):
    """Dual-attempt find in ONE round trip (2 collectives, not 4).

    Each key is routed to its attempt-0 AND attempt-1 owners in the same
    batch; the requester prefers the attempt-0 answer, which makes the
    result bit-identical to the sequential attempt loop whenever the
    route capacity admits every request (zero drops — the operating
    regime callers are expected to size for).  Under capacity overflow
    both schedules degrade to best-effort on *different* probe subsets:
    this path drops among 2N speculative requests at capacity 2C, the
    sequential loop drops per attempt at capacity C.  Halves the
    collective rounds of the default 2-attempt find at the price of one
    speculative lookup per key — the paper's aggregation trade (latency
    for bandwidth, section 4.2) applied to the probe path itself.
    """
    n = klanes.shape[0]
    owner0, lb0 = _owner_local(spec, _block_of(spec, klanes, 0))
    owner1, lb1 = _owner_local(spec, _block_of(spec, klanes, 1))
    owner = jnp.concatenate([owner0, owner1])
    lblock = jnp.concatenate([lb0, lb1])
    k2 = jnp.concatenate([klanes, klanes], axis=0)
    valid2 = jnp.concatenate([valid, valid])
    body = jnp.concatenate([lblock.astype(_U32)[:, None], k2], axis=1)
    res = route(backend, body, owner, 2 * capacity, valid=valid2,
                op_name="hashmap.find", impl=spec.impl)
    rb = jnp.where(res.valid, res.payload[:, 0].astype(_I32), 0)
    rk = res.payload[:, 1:]
    tk, tv, st = state
    if atomic:
        st = st.at[rb].add(_READ_BIT, mode="drop")
    found_here, vlanes = kops.bulk_find(tk, tv, st, rb, rk, res.valid,
                                        impl=spec.impl)
    if atomic:
        st = st.at[rb].add(_U32(0) - _READ_BIT, mode="drop")
        state = HashMapState(tk, tv, st)
    body_back = jnp.concatenate(
        [vlanes, found_here.astype(_U32)[:, None]], axis=1)
    back, _ = reply(backend, res, body_back, 2 * n, op_name="hashmap.find")
    got = back[:, -1] == 1
    got0 = got[:n] & valid
    got1 = got[n:] & valid
    found = got0 | got1
    vals = jnp.where(got0[:, None], back[:n, :-1], back[n:, :-1])
    vals = jnp.where(found[:, None], vals, 0)
    costs.record("hashmap.find",
                 costs.Cost(A=2 if atomic else 0, R=n))
    return state, spec.val_packer.unpack(vals), found


def find(backend: Backend, spec: HashMapSpec, state: HashMapState,
         keys, capacity: int,
         promise: Promise = Promise.FIND | Promise.INSERT,
         valid: jax.Array | None = None,
         attempts: int = 2,
         speculative: bool = True):
    """Find a batch of keys. Returns (state, values, found(N,)).

    State is returned because the fully-atomic path's read-bit dance
    writes (net-zero) to the status array, exactly like the paper's
    fetch-and-or / fetch-and-and pair.

    With ``speculative`` (the default) a 2-attempt find issues both
    probe attempts in one batched round trip — 2 collectives instead of
    4 — with identical results to the sequential attempt loop
    (``speculative=False``, the oracle schedule) as long as ``capacity``
    admits every request.  When requests overflow capacity (drops are
    counted, never silent) the two schedules probe different best-effort
    subsets; found keys always carry correct values either way.
    """
    klanes = spec.key_packer.pack(keys)
    n = klanes.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)

    if local_only(promise):
        gblock = _block_of(spec, klanes, 0)
        _, lblock = _owner_local(spec, gblock)
        found, vlanes = kops.bulk_find(state.tkeys, state.tvals, state.status,
                                       lblock, klanes, valid, impl=spec.impl)
        costs.record("hashmap.find", costs.Cost(local=n))
        return state, spec.val_packer.unpack(vlanes), found

    atomic = not find_only(promise)
    if speculative and attempts == 2:
        return _find_speculative(backend, spec, state, klanes, capacity,
                                 valid, atomic)
    pending = valid
    found_all = jnp.zeros((n,), bool)
    vals_all = jnp.zeros((n, spec.val_packer.lanes), _U32)
    for a in range(max(1, attempts)):
        gblock = _block_of(spec, klanes, a)
        owner, lblock = _owner_local(spec, gblock)
        body = jnp.concatenate([lblock.astype(_U32)[:, None], klanes], axis=1)
        res = route(backend, body, owner, capacity, valid=pending,
                    op_name="hashmap.find", impl=spec.impl)
        rb = jnp.where(res.valid, res.payload[:, 0].astype(_I32), 0)
        rk = res.payload[:, 1:]
        tk, tv, st = state
        if atomic:
            # fetch-and-or a read bit, read, fetch-and-and it away
            st = st.at[rb].add(_READ_BIT, mode="drop")
        found_here, vlanes = kops.bulk_find(tk, tv, st, rb, rk, res.valid,
                                            impl=spec.impl)
        if atomic:
            st = st.at[rb].add(_U32(0) - _READ_BIT, mode="drop")
            state = HashMapState(tk, tv, st)
        body_back = jnp.concatenate(
            [vlanes, found_here.astype(_U32)[:, None]], axis=1)
        back, _ = reply(backend, res, body_back, n, op_name="hashmap.find")
        got = (back[:, -1] == 1) & pending
        vals_all = jnp.where(got[:, None], back[:, :-1], vals_all)
        found_all = found_all | got
        pending = pending & ~got
        if attempts == 1:
            break
    costs.record("hashmap.find",
                 costs.Cost(A=2 if atomic else 0, R=n))
    return state, spec.val_packer.unpack(vals_all), found_all


def count_ready(backend: Backend, state: HashMapState) -> jax.Array:
    """Global number of occupied buckets."""
    from repro.kernels.ref import READY, bucket_state
    return backend.psum((bucket_state(state.status) == READY).sum())


def local_entries(spec: HashMapSpec, state: HashMapState):
    """This rank's (keys, vals, occupied) — flattened local view."""
    from repro.kernels.ref import READY, bucket_state
    nb, b = state.status.shape
    occ = (bucket_state(state.status) == READY).reshape(-1)
    keys = spec.key_packer.unpack(state.tkeys.reshape(nb * b, -1))
    vals = spec.val_packer.unpack(state.tvals.reshape(nb * b, -1))
    return keys, vals, occ


def resize(backend: Backend, spec: HashMapSpec, state: HashMapState,
           new_capacity: int, capacity_per_pair: int):
    """Collective resize (paper 5.1.5): rebuild and re-insert all entries."""
    backend.barrier()
    new_spec, new_state = hashmap_create(
        backend, new_capacity,
        spec.key_packer, spec.val_packer, spec.block_size, spec.impl)
    keys, vals, occ = local_entries(spec, state)
    new_state, _ = insert(backend, new_spec, new_state, keys, vals,
                          capacity_per_pair, valid=occ,
                          promise=Promise.INSERT, attempts=3)
    costs.record("hashmap.resize",
                 costs.Cost(B=1, W=int(occ.shape[0])))
    return new_spec, new_state
