"""BCL::HashMap — the distributed hash table (paper section 5.1).

Layout: a logically contiguous array of *blocks* of B buckets,
distributed block-wise across ranks (DESIGN.md: "blocked open
addressing").  A key hashes to a block; probing compares the key against
all B slots of the block at once (vectorized; the Pallas kernel's tile).
When a block fills, the container rehashes the failed items to a new
block — quadratic in the attempt number — with a bounded number of
attempts, mirroring the paper's quadratic probing plus its "insertion
may fail when full" semantics.

Concurrency promises select the schedule (paper Table 3):
  (a) find|insert   fully atomic   insert 2A + W     find 2A + R
  (b) local         local insert   l
  (c) find|insert   fully atomic find
  (d) find          phase-local find: one read, no AMOs     R

Promises also pick the *collective* schedule (DESIGN.md section 1.5):
the default 2-attempt find issues both probes as two flows of one
ExchangePlan (2 collectives), and ``find_insert`` fuses a find batch
and an insert batch into one plan under the
``ConProm.HashMap.find_insert`` promise; ``Promise.FINE`` at any
callsite forces the sequential one-op-per-round oracle.

"Atomic" ops execute the paper's flag dance (reserve CAS / read-bit
fetch-or + fetch-and) as real owner-side RMW passes over the status
word, so their extra cost is measurable; promise-relaxed ops skip it.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.exchange import ExchangePlan, PendingResult
from repro.core.hashing import hash_lanes
from repro.core.object_container import Packer, packer_for
from repro.core.promises import (Promise, find_only, fine_grained,
                                 fully_atomic_hashmap, local_only, validate)
from repro.kernels import ops as kops

_U32 = jnp.uint32
_I32 = jnp.int32

# a "read bit" in the upper 30 bits of the status word (paper 5.1.2)
_READ_BIT = jnp.uint32(1 << 7)


@dataclasses.dataclass(frozen=True)
class HashMapSpec:
    nblocks_global: int
    nblocks_local: int
    block_size: int
    key_packer: Packer
    val_packer: Packer
    impl: str = "auto"   # kernel dispatch: auto|jnp|pallas|oracle

    @property
    def capacity(self) -> int:
        return self.nblocks_global * self.block_size


class HashMapState(NamedTuple):
    tkeys: jax.Array    # (nb_local, B, Lk) u32
    tvals: jax.Array    # (nb_local, B, Lv) u32
    status: jax.Array   # (nb_local, B) u32


def hashmap_create(backend: Backend, capacity: int, key_spec, val_spec,
                   block_size: int = 128,
                   impl: str = "auto") -> tuple[HashMapSpec, HashMapState]:
    """Collective constructor (paper 5.1.1): fixed size, fixed K/V types."""
    kp, vp = packer_for(key_spec), packer_for(val_spec)
    nprocs = backend.nprocs()
    nb_global = max(1, -(-capacity // block_size))
    nb_global = -(-nb_global // nprocs) * nprocs       # round up to P
    nb_local = nb_global // nprocs
    spec = HashMapSpec(nb_global, nb_local, block_size, kp, vp, impl)
    state = HashMapState(
        jnp.zeros((nb_local, block_size, kp.lanes), _U32),
        jnp.zeros((nb_local, block_size, vp.lanes), _U32),
        jnp.zeros((nb_local, block_size), _U32))
    return spec, state


def _block_of(spec: HashMapSpec, key_lanes: jax.Array,
              attempt: int) -> jax.Array:
    """Global block index; attempts rehash quadratically (paper 5.1)."""
    h1 = hash_lanes(key_lanes, seed=1)
    if attempt == 0:
        g = h1
    else:
        h2 = hash_lanes(key_lanes, seed=3) | _U32(1)
        g = h1 + jnp.uint32(attempt * attempt) * h2
    return (g % jnp.uint32(spec.nblocks_global)).astype(_I32)


def _owner_local(spec: HashMapSpec, gblock: jax.Array):
    return gblock // spec.nblocks_local, gblock % spec.nblocks_local


def insert(backend: Backend, spec: HashMapSpec, state: HashMapState,
           keys, vals, capacity: int,
           promise: Promise = Promise.FIND | Promise.INSERT,
           valid: jax.Array | None = None,
           mode: int = kops.MODE_SET,
           attempts: int = 2,
           return_success: bool = True,
           max_rounds: int = 1,
           transport=None,
           dead_ranks=None,
           integrity: bool = False):
    """Insert a batch of (key, value) pairs.

    Returns (state, success(N,) | None).  With ``promise=local`` the keys
    must hash to this rank's own blocks (cost l, no collectives) — the
    HashMapBuffer flush path (paper Table 3b).  ``max_rounds`` adds
    carryover retry rounds to each exchange, absorbing skewed key
    distributions (hot blocks) without inflating ``capacity``.

    ``dead_ranks``/``integrity`` forward to :meth:`ExchangePlan.commit`
    (DESIGN.md section 1.8).  Items owned by a dead rank are masked at
    admission and simply stay unsuccessful (``success`` False) — a
    multi-``attempts`` insert retries them against their rehash block,
    which may land on a live rank.  With ``integrity=True`` a
    checksum-failed arrival never acks, so the requester sees it as
    unsuccessful and the attempt loop re-sends it.
    """
    validate(promise)
    klanes = spec.key_packer.pack(keys)
    vlanes = spec.val_packer.pack(vals)
    n = klanes.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)

    if local_only(promise):
        gblock = _block_of(spec, klanes, 0)
        _, lblock = _owner_local(spec, gblock)
        tk, tv, st, ok = kops.bulk_insert(
            state.tkeys, state.tvals, state.status, lblock, klanes, vlanes,
            valid, mode, impl=spec.impl)
        costs.record("hashmap.insert", costs.Cost(local=n))
        return HashMapState(tk, tv, st), ok

    atomic = fully_atomic_hashmap(promise)
    pending = valid
    success = jnp.zeros((n,), bool)
    new_state = state
    # success replies ride the plan's inverse permutation (through the
    # chosen transport); a fire-and-forget insert declares no reply
    rl = 1 if (return_success or attempts > 1) else 0
    for a in range(max(1, attempts)):
        gblock = _block_of(spec, klanes, a)
        owner, lblock = _owner_local(spec, gblock)
        body = jnp.concatenate(
            [lblock.astype(_U32)[:, None], klanes, vlanes], axis=1)
        plan = ExchangePlan(name="hashmap.insert")
        h = plan.add(body, owner, capacity, reply_lanes=rl, valid=pending,
                     op_name="hashmap.insert")
        c = plan.commit(backend, impl=spec.impl, max_rounds=max_rounds,
                        transport=transport, dead_ranks=dead_ranks,
                        integrity=integrity)
        res = c.view(h)

        tk, tv, st = new_state
        if atomic:
            # paper 5.1.3: CAS free->reserved ... XOR ->ready.  The state
            # machine is owner-serialized here, but we execute the reserve
            # pass so its traffic is real: a net-zero RMW on the status
            # word of every touched block.
            rb = jnp.where(res.valid, res.payload[:, 0].astype(_I32), 0)
            st = st.at[rb].add(_READ_BIT, mode="drop")
            st = st.at[rb].add(_U32(0) - _READ_BIT, mode="drop")
        # the arrival segment feeds the probe directly (DESIGN.md §1.10)
        tk, tv, st, ok_here = kops.bulk_insert_arrivals(
            tk, tv, st, res.payload, res.valid, mode, impl=spec.impl)
        new_state = HashMapState(tk, tv, st)

        if rl:
            c.set_reply(h, ok_here.astype(_U32))
            back, _ = c.finish(backend)[h]
            ok_src = (back[:, 0] == 1) & pending
            success = success | ok_src
            pending = pending & ~ok_src
        else:
            break
    costs.record("hashmap.insert",
                 costs.Cost(A=2 if atomic else 1, W=n))
    return new_state, (success if (return_success or attempts > 1) else None)


def _find_speculative(backend: Backend, spec: HashMapSpec,
                      state: HashMapState, klanes, capacity: int,
                      valid, atomic: bool, max_rounds: int = 1,
                      transport=None, dead_ranks=None,
                      integrity: bool = False):
    """Dual-attempt find in ONE round trip (2 collectives, not 4).

    Both probe attempts are two *flows* of one :class:`ExchangePlan`:
    each key is registered against its attempt-0 AND attempt-1 owners,
    the plan fuses both flows into a single request all-to-all, and the
    replies share a single inverse all-to-all.  The requester prefers
    the attempt-0 answer, which makes the result bit-identical to the
    sequential attempt loop whenever the per-flow capacity admits every
    request (zero drops — the operating regime callers are expected to
    size for).  Under capacity overflow both schedules degrade to
    best-effort on *different* probe subsets: here each attempt flow
    drops independently at capacity C per (src, dst, flow) segment.
    Halves the collective rounds of the default 2-attempt find at the
    price of one speculative lookup per key — the paper's aggregation
    trade (latency for bandwidth, section 4.2) applied to the probe
    path itself.
    """
    n = klanes.shape[0]
    owner0, lb0 = _owner_local(spec, _block_of(spec, klanes, 0))
    owner1, lb1 = _owner_local(spec, _block_of(spec, klanes, 1))
    rl = spec.val_packer.lanes + 1
    plan = ExchangePlan(name="hashmap.find")
    h0 = plan.add(jnp.concatenate([lb0.astype(_U32)[:, None], klanes], axis=1),
                  owner0, capacity, reply_lanes=rl, valid=valid,
                  op_name="hashmap.find")
    h1 = plan.add(jnp.concatenate([lb1.astype(_U32)[:, None], klanes], axis=1),
                  owner1, capacity, reply_lanes=rl, valid=valid,
                  op_name="hashmap.find")
    c = plan.commit(backend, impl=spec.impl, max_rounds=max_rounds,
                    transport=transport, dead_ranks=dead_ranks,
                    integrity=integrity)
    v0, v1 = c.view(h0), c.view(h1)

    seg = jnp.concatenate([v0.payload, v1.payload])
    rvalid = jnp.concatenate([v0.valid, v1.valid])
    tk, tv, st = state
    if atomic:
        rb = jnp.where(rvalid, seg[:, 0].astype(_I32), 0)
        st = st.at[rb].add(_READ_BIT, mode="drop")
    found_here, vlanes = kops.bulk_find_arrivals(tk, tv, st, seg, rvalid,
                                                 impl=spec.impl)
    if atomic:
        st = st.at[rb].add(_U32(0) - _READ_BIT, mode="drop")
        state = HashMapState(tk, tv, st)
    body_back = jnp.concatenate(
        [vlanes, found_here.astype(_U32)[:, None]], axis=1)
    m = v0.payload.shape[0]
    c.set_reply(h0, body_back[:m])
    c.set_reply(h1, body_back[m:])
    outs = c.finish(backend)
    b0, _ = outs[h0]
    b1, _ = outs[h1]
    got0 = (b0[:, -1] == 1) & valid
    got1 = (b1[:, -1] == 1) & valid
    found = got0 | got1
    vals = jnp.where(got0[:, None], b0[:, :-1], b1[:, :-1])
    vals = jnp.where(found[:, None], vals, 0)
    costs.record("hashmap.find",
                 costs.Cost(A=2 if atomic else 0, R=n))
    return state, spec.val_packer.unpack(vals), found


def find(backend: Backend, spec: HashMapSpec, state: HashMapState,
         keys, capacity: int,
         promise: Promise = Promise.FIND | Promise.INSERT,
         valid: jax.Array | None = None,
         attempts: int = 2,
         speculative: bool = True,
         max_rounds: int = 1,
         transport=None,
         dead_ranks=None,
         integrity: bool = False):
    """Find a batch of keys. Returns (state, values, found(N,)).

    State is returned because the fully-atomic path's read-bit dance
    writes (net-zero) to the status array, exactly like the paper's
    fetch-and-or / fetch-and-and pair.

    With ``speculative`` (the default) a 2-attempt find issues both
    probe attempts as two flows of one ExchangePlan — 2 collectives
    instead of 4 — with identical results to the sequential attempt loop
    (``speculative=False``, the oracle schedule) as long as ``capacity``
    admits every request.  When requests overflow capacity (drops are
    counted, never silent) the two schedules probe different best-effort
    subsets; found keys always carry correct values either way.
    ``Promise.FINE`` in the promise forces the sequential schedule.
    """
    validate(promise)
    if fine_grained(promise):
        speculative = False
    klanes = spec.key_packer.pack(keys)
    n = klanes.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)

    if local_only(promise):
        gblock = _block_of(spec, klanes, 0)
        _, lblock = _owner_local(spec, gblock)
        found, vlanes = kops.bulk_find(state.tkeys, state.tvals, state.status,
                                       lblock, klanes, valid, impl=spec.impl)
        costs.record("hashmap.find", costs.Cost(local=n))
        return state, spec.val_packer.unpack(vlanes), found

    atomic = not find_only(promise)
    if speculative and attempts == 2:
        return _find_speculative(backend, spec, state, klanes, capacity,
                                 valid, atomic, max_rounds=max_rounds,
                                 transport=transport, dead_ranks=dead_ranks,
                                 integrity=integrity)
    pending = valid
    found_all = jnp.zeros((n,), bool)
    vals_all = jnp.zeros((n, spec.val_packer.lanes), _U32)
    for a in range(max(1, attempts)):
        gblock = _block_of(spec, klanes, a)
        owner, lblock = _owner_local(spec, gblock)
        body = jnp.concatenate([lblock.astype(_U32)[:, None], klanes], axis=1)
        plan = ExchangePlan(name="hashmap.find")
        h = plan.add(body, owner, capacity,
                     reply_lanes=spec.val_packer.lanes + 1, valid=pending,
                     op_name="hashmap.find")
        c = plan.commit(backend, impl=spec.impl, max_rounds=max_rounds,
                        transport=transport, dead_ranks=dead_ranks,
                        integrity=integrity)
        res = c.view(h)
        tk, tv, st = state
        if atomic:
            # fetch-and-or a read bit, read, fetch-and-and it away
            rb = jnp.where(res.valid, res.payload[:, 0].astype(_I32), 0)
            st = st.at[rb].add(_READ_BIT, mode="drop")
        found_here, vlanes = kops.bulk_find_arrivals(tk, tv, st, res.payload,
                                                     res.valid,
                                                     impl=spec.impl)
        if atomic:
            st = st.at[rb].add(_U32(0) - _READ_BIT, mode="drop")
            state = HashMapState(tk, tv, st)
        c.set_reply(h, jnp.concatenate(
            [vlanes, found_here.astype(_U32)[:, None]], axis=1))
        back, _ = c.finish(backend)[h]
        got = (back[:, -1] == 1) & pending
        vals_all = jnp.where(got[:, None], back[:, :-1], vals_all)
        found_all = found_all | got
        pending = pending & ~got
        if attempts == 1:
            break
    costs.record("hashmap.find",
                 costs.Cost(A=2 if atomic else 0, R=n))
    return state, spec.val_packer.unpack(vals_all), found_all


def find_insert(backend: Backend, spec: HashMapSpec, state: HashMapState,
                find_keys, ins_keys, ins_vals, capacity: int,
                promise: Promise = Promise.FIND | Promise.INSERT,
                find_valid: jax.Array | None = None,
                ins_valid: jax.Array | None = None,
                mode: int = kops.MODE_SET,
                max_rounds: int = 1,
                transport=None,
                dead_ranks=None,
                integrity: bool = False,
                async_: bool = False):
    """Fused find + insert sharing ONE exchange round trip.

    Under ``ConProm.HashMap.find_insert`` the two batches are promised
    concurrent, so the runtime may serialize them however it likes; this
    schedule serializes find-before-insert (finds observe the table as
    it was before this batch's insertions) and fuses both ops' flows
    into one ExchangePlan: **2 collectives** per round trip where the
    ``Promise.FINE`` sequential schedule costs **4**, at EXACTLY the
    sum of the two ops' standalone wire bytes — the ragged layout
    (DESIGN.md section 1.5) keeps the narrower find rows and the 1-word
    insert-ok replies at their own widths (both pinned in
    tests/test_wire_format.py).  Both probes use attempt 0; callers
    needing rehash attempts issue the ops separately.

    Returns ``(state, values, found, ins_ok)`` — find results aligned
    with ``find_keys``, insert successes aligned with ``ins_keys``.

    ``async_=True`` issues the plan split-phase (DESIGN.md section 1.9)
    and instead returns a :class:`~repro.core.PendingResult` whose
    ``finish()`` yields the same 4-tuple: the request wire is in flight
    when the call returns, and everything the caller traces before
    ``finish()`` overlaps with it.
    """
    validate(promise)
    # per-op atomicity gates mirror the standalone ops exactly, so the
    # FINE oracle and the fused schedule agree on the A counts and the
    # status-word traffic for ANY promise, not just find_insert
    find_atomic = not find_only(promise)
    ins_atomic = fully_atomic_hashmap(promise)
    if fine_grained(promise) and not async_:
        state, vals, found = find(backend, spec, state, find_keys, capacity,
                                  promise=promise, valid=find_valid,
                                  attempts=1, max_rounds=max_rounds,
                                  transport=transport, dead_ranks=dead_ranks,
                                  integrity=integrity)
        state, ok = insert(backend, spec, state, ins_keys, ins_vals, capacity,
                           promise=promise, valid=ins_valid, mode=mode,
                           attempts=1, return_success=True,
                           max_rounds=max_rounds, transport=transport,
                           dead_ranks=dead_ranks, integrity=integrity)
        return state, vals, found, ok
    if fine_grained(promise):
        # split-phase FINE stays the sequential oracle: commit eagerly,
        # hand completion back through the same future type
        sync = find_insert(backend, spec, state, find_keys, ins_keys,
                           ins_vals, capacity, promise=promise,
                           find_valid=find_valid, ins_valid=ins_valid,
                           mode=mode, max_rounds=max_rounds,
                           transport=transport, dead_ranks=dead_ranks,
                           integrity=integrity)
        return PendingResult(lambda: sync)

    kf = spec.key_packer.pack(find_keys)
    ki = spec.key_packer.pack(ins_keys)
    vi = spec.val_packer.pack(ins_vals)
    nf, ni = kf.shape[0], ki.shape[0]
    lk = spec.key_packer.lanes
    if find_valid is None:
        find_valid = jnp.ones((nf,), bool)
    if ins_valid is None:
        ins_valid = jnp.ones((ni,), bool)
    owner_f, lb_f = _owner_local(spec, _block_of(spec, kf, 0))
    owner_i, lb_i = _owner_local(spec, _block_of(spec, ki, 0))

    plan = ExchangePlan(name="hashmap.find_insert")
    hf = plan.add(jnp.concatenate([lb_f.astype(_U32)[:, None], kf], axis=1),
                  owner_f, capacity, reply_lanes=spec.val_packer.lanes + 1,
                  valid=find_valid, op_name="hashmap.find")
    hi = plan.add(jnp.concatenate([lb_i.astype(_U32)[:, None], ki, vi],
                                  axis=1),
                  owner_i, capacity, reply_lanes=1,
                  valid=ins_valid, op_name="hashmap.insert")
    if async_:
        pend = plan.commit_async(backend, impl=spec.impl,
                                 max_rounds=max_rounds, transport=transport,
                                 dead_ranks=dead_ranks, integrity=integrity)
        return PendingResult(lambda: _find_insert_complete(
            backend, spec, state, pend.finish(backend), hf, hi, lk,
            find_valid, ins_valid, mode, find_atomic, ins_atomic, nf, ni))
    c = plan.commit(backend, impl=spec.impl, max_rounds=max_rounds,
                    transport=transport, dead_ranks=dead_ranks,
                    integrity=integrity)
    return _find_insert_complete(backend, spec, state, c, hf, hi, lk,
                                 find_valid, ins_valid, mode,
                                 find_atomic, ins_atomic, nf, ni)


def _find_insert_complete(backend, spec, state, c, hf, hi, lk,
                          find_valid, ins_valid, mode,
                          find_atomic, ins_atomic, nf, ni):
    """Owner-side work + reply round of :func:`find_insert` (both the
    synchronous and the split-phase path complete through here)."""
    vf, vw = c.view(hf), c.view(hi)

    # find against the pre-insert table (the chosen serialization); both
    # owner-side probes consume their arrival segments directly
    tk, tv, st = state
    if find_atomic:
        rb_f = jnp.where(vf.valid, vf.payload[:, 0].astype(_I32), 0)
        st = st.at[rb_f].add(_READ_BIT, mode="drop")
    found_here, vlanes = kops.bulk_find_arrivals(tk, tv, st, vf.payload,
                                                 vf.valid, impl=spec.impl)
    if find_atomic:
        st = st.at[rb_f].add(_U32(0) - _READ_BIT, mode="drop")

    # insert (same reserve dance as the standalone op)
    if ins_atomic:
        rb_i = jnp.where(vw.valid, vw.payload[:, 0].astype(_I32), 0)
        st = st.at[rb_i].add(_READ_BIT, mode="drop")
        st = st.at[rb_i].add(_U32(0) - _READ_BIT, mode="drop")
    tk, tv, st, ok_here = kops.bulk_insert_arrivals(tk, tv, st, vw.payload,
                                                    vw.valid, mode,
                                                    impl=spec.impl)
    state = HashMapState(tk, tv, st)

    c.set_reply(hf, jnp.concatenate(
        [vlanes, found_here.astype(_U32)[:, None]], axis=1))
    c.set_reply(hi, ok_here.astype(_U32))
    outs = c.finish(backend)
    bf, _ = outs[hf]
    bi, _ = outs[hi]
    found = (bf[:, -1] == 1) & find_valid
    vals = jnp.where(found[:, None], bf[:, :-1], 0)
    ok = (bi[:, 0] == 1) & ins_valid
    costs.record("hashmap.find",
                 costs.Cost(A=2 if find_atomic else 0, R=nf))
    costs.record("hashmap.insert",
                 costs.Cost(A=2 if ins_atomic else 1, W=ni))
    return state, spec.val_packer.unpack(vals), found, ok


def count_ready(backend: Backend, state: HashMapState) -> jax.Array:
    """Global number of occupied buckets."""
    from repro.kernels.ref import READY, bucket_state
    return backend.psum((bucket_state(state.status) == READY).sum())


def local_entries(spec: HashMapSpec, state: HashMapState):
    """This rank's (keys, vals, occupied) — flattened local view."""
    from repro.kernels.ref import READY, bucket_state
    nb, b = state.status.shape
    occ = (bucket_state(state.status) == READY).reshape(-1)
    keys = spec.key_packer.unpack(state.tkeys.reshape(nb * b, -1))
    vals = spec.val_packer.unpack(state.tvals.reshape(nb * b, -1))
    return keys, vals, occ


def export_state(spec: HashMapSpec, state: HashMapState) -> dict:
    """This rank's table shard as a checkpointable pytree (plain dict).

    The dict rides ``checkpoint.save_checkpoint`` unchanged; after a
    rank loss a survivor restores the dead rank's shard with
    :func:`restore_state` and re-inserts its live entries
    (``local_entries`` of the restored shard) through an ordinary
    ``insert`` — the re-injection path of DESIGN.md section 1.8.
    """
    return {"tkeys": state.tkeys, "tvals": state.tvals,
            "status": state.status}


def restore_state(spec: HashMapSpec, exported: dict) -> HashMapState:
    """Rebuild a HashMapState shard from :func:`export_state` output."""
    tk = jnp.asarray(exported["tkeys"], _U32)
    want = (spec.nblocks_local, spec.block_size, spec.key_packer.lanes)
    if tk.shape != want:
        raise ValueError(
            f"hashmap.restore_state: tkeys shape {tk.shape} does not "
            f"match spec {want}")
    return HashMapState(tk, jnp.asarray(exported["tvals"], _U32),
                        jnp.asarray(exported["status"], _U32))


def resize(backend: Backend, spec: HashMapSpec, state: HashMapState,
           new_capacity: int, capacity_per_pair: int):
    """Collective resize (paper 5.1.5): rebuild and re-insert all entries."""
    backend.barrier()
    new_spec, new_state = hashmap_create(
        backend, new_capacity,
        spec.key_packer, spec.val_packer, spec.block_size, spec.impl)
    keys, vals, occ = local_entries(spec, state)
    new_state, _ = insert(backend, new_spec, new_state, keys, vals,
                          capacity_per_pair, valid=occ,
                          promise=Promise.INSERT, attempts=3)
    costs.record("hashmap.resize",
                 costs.Cost(B=1, W=int(occ.shape[0])))
    return new_spec, new_state
