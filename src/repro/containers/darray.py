"""BCL::DArray — a distributed 1-D array (paper Table 1).

Block layout: global element g lives on rank ``g // local_n`` at local
offset ``g % local_n``.  ``rget``/``rput`` are the one-sided read/write
primitives: batches of global indices are routed to owners, served
locally, and (for rget) routed back — the TPU realization of an RDMA
get/put at cost R / W per element.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.exchange import route, reply
from repro.core.object_container import Packer, packer_for

_U32 = jnp.uint32
_I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class DArraySpec:
    global_n: int
    local_n: int
    packer: Packer

    @property
    def lanes(self) -> int:
        return self.packer.lanes


class DArrayState(NamedTuple):
    local: jax.Array  # (local_n, L) u32


def darray_create(backend: Backend, global_n: int, value_spec) -> tuple[DArraySpec, DArrayState]:
    packer = packer_for(value_spec)
    nprocs = backend.nprocs()
    if global_n % nprocs:
        global_n += nprocs - global_n % nprocs
    local_n = global_n // nprocs
    spec = DArraySpec(global_n, local_n, packer)
    state = DArrayState(jnp.zeros((local_n, packer.lanes), _U32))
    return spec, state


def owner_of(spec: DArraySpec, idx: jax.Array) -> tuple[jax.Array, jax.Array]:
    idx = idx.astype(_I32)
    return idx // spec.local_n, idx % spec.local_n


def rget(backend: Backend, spec: DArraySpec, state: DArrayState,
         idx: jax.Array, capacity: int):
    """Batched one-sided read of global indices. Returns (values, found)."""
    n = idx.shape[0]
    owner, off = owner_of(spec, idx)
    req = route(backend, off.astype(_U32)[:, None], owner, capacity,
                op_name="darray.rget")
    loff = jnp.where(req.valid, req.payload[:, 0].astype(_I32), 0)
    rows = state.local[loff]
    out, answered = reply(backend, req, rows, n, op_name="darray.rget")
    costs.record("darray.rget", costs.Cost(R=n))
    return spec.packer.unpack(out), answered


def rput(backend: Backend, spec: DArraySpec, state: DArrayState,
         idx: jax.Array, values, capacity: int, mode: str = "set"):
    """Batched one-sided write. mode='set'|'add'. Returns new state."""
    n = idx.shape[0]
    owner, off = owner_of(spec, idx)
    lanes = spec.packer.pack(values)
    body = jnp.concatenate([off.astype(_U32)[:, None], lanes], axis=1)
    res = route(backend, body, owner, capacity, op_name="darray.rput")
    loff = jnp.where(res.valid, res.payload[:, 0].astype(_I32), spec.local_n)
    rows = res.payload[:, 1:]
    if mode == "add":
        local = state.local.at[loff].add(rows, mode="drop")
    else:
        local = state.local.at[loff].set(rows, mode="drop")
    costs.record("darray.rput", costs.Cost(W=n))
    return DArrayState(local)


def local_read(spec: DArraySpec, state: DArrayState, off: jax.Array):
    return spec.packer.unpack(state.local[off.astype(_I32)])


def local_write(spec: DArraySpec, state: DArrayState, off: jax.Array, values):
    lanes = spec.packer.pack(values)
    return DArrayState(state.local.at[off.astype(_I32)].set(lanes))


def to_global(backend: Backend, spec: DArraySpec, state: DArrayState):
    """All-gather the full array (testing/debug; cost nR)."""
    shards = backend.all_gather(state.local)          # (P, local_n, L)
    flat = shards.reshape(-1, spec.packer.lanes)
    return spec.packer.unpack(flat)
