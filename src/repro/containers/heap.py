"""Hosted bump-allocator heap: the variable-length ObjectContainer path.

Paper section 6: serializers returning ``BCL::serial_ptr`` store their
payload behind a global pointer in globally-addressable memory.  The
heap provides that memory: each rank hosts a segment; ``store_local``
bump-allocates rows on the calling rank (a *local* fetch-and-add), and
``rget_rows`` reads arbitrary remote spans through the exchange.

Records inside other containers then carry (rank, offset, length) —
``SerialPtrPacker`` in core/object_container.py — while the bytes live
here.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.exchange import reply, route
from repro.core.pointers import GlobalPointer

_U32 = jnp.uint32
_I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class HeapSpec:
    local_rows: int
    lanes: int


class HeapState(NamedTuple):
    data: jax.Array   # (local_rows, lanes) u32
    top: jax.Array    # (1,) i32 bump pointer


def heap_create(backend: Backend, local_rows: int,
                lanes: int) -> tuple[HeapSpec, HeapState]:
    return (HeapSpec(local_rows, lanes),
            HeapState(jnp.zeros((local_rows, lanes), _U32),
                      jnp.zeros((1,), _I32)))


def store_local(backend: Backend, spec: HeapSpec, state: HeapState,
                rows: jax.Array, lengths: jax.Array):
    """Allocate contiguous spans on this rank; one record per span.

    rows (N, lanes) u32 — the concatenated span payload rows;
    lengths (K,) i32 — rows per record (sum == N).
    Returns (state, ptrs: GlobalPointer (K,), ok).
    """
    n = rows.shape[0]
    base = state.top[0]
    ok = base + n <= spec.local_rows
    idx = jnp.where(ok, base + jnp.arange(n, dtype=_I32), spec.local_rows)
    data = state.data.at[idx].set(rows.astype(_U32), mode="drop")
    offsets = base + jnp.concatenate(
        [jnp.zeros((1,), _I32), jnp.cumsum(lengths)[:-1].astype(_I32)])
    rank = jnp.broadcast_to(backend.rank(), offsets.shape)
    new_top = jnp.where(ok, state.top + n, state.top)
    costs.record("heap.store_local", costs.Cost(local=n))
    return (HeapState(data, new_top),
            GlobalPointer(rank, offsets),
            jnp.broadcast_to(ok, offsets.shape))


def rget_rows(backend: Backend, spec: HeapSpec, state: HeapState,
              ptrs: GlobalPointer, span: int, capacity: int):
    """Read ``span`` consecutive rows behind each pointer (static span).

    Returns (rows (K, span, lanes), found (K,)).  Variable-length
    records read their max span and slice by the stored length.
    """
    k = ptrs.rank.shape[0]
    # expand each pointer into `span` unit row-requests
    off = (ptrs.offset[:, None] + jnp.arange(span, dtype=_I32)[None]
           ).reshape(-1)
    dst = jnp.repeat(ptrs.rank, span)
    req = route(backend, off.astype(_U32)[:, None], dst,
                capacity=capacity * span, op_name="heap.rget")
    loff = jnp.where(req.valid, req.payload[:, 0].astype(_I32), 0)
    served = state.data[jnp.clip(loff, 0, spec.local_rows - 1)]
    out, answered = reply(backend, req, served, k * span,
                          op_name="heap.rget")
    costs.record("heap.rget", costs.Cost(R=k * span))
    return (out.reshape(k, span, spec.lanes),
            answered.reshape(k, span).all(axis=1))
