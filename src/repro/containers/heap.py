"""Hosted bump-allocator heap: the variable-length ObjectContainer path.

Paper section 6: serializers returning ``BCL::serial_ptr`` store their
payload behind a global pointer in globally-addressable memory.  The
heap provides that memory: each rank hosts a segment; ``store_local``
bump-allocates rows on the calling rank (a *local* fetch-and-add), and
``rget_rows`` reads arbitrary remote spans through the exchange.

Records inside other containers then carry (rank, offset, length) —
``SerialPtrPacker`` in core/object_container.py — while the bytes live
here.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.exchange import reply, route
from repro.core.pointers import GlobalPointer

_U32 = jnp.uint32
_I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class HeapSpec:
    local_rows: int
    lanes: int


class HeapState(NamedTuple):
    data: jax.Array   # (local_rows, lanes) u32
    top: jax.Array    # (1,) i32 bump pointer


def heap_create(backend: Backend, local_rows: int,
                lanes: int) -> tuple[HeapSpec, HeapState]:
    return (HeapSpec(local_rows, lanes),
            HeapState(jnp.zeros((local_rows, lanes), _U32),
                      jnp.zeros((1,), _I32)))


def store_local(backend: Backend, spec: HeapSpec, state: HeapState,
                rows: jax.Array, lengths: jax.Array):
    """Allocate contiguous spans on this rank; one record per span.

    rows (N, lanes) u32 — the concatenated span payload rows;
    lengths (K,) i32 — rows per record (sum == N).
    Returns (state, ptrs: GlobalPointer (K,), ok).
    """
    n = rows.shape[0]
    base = state.top[0]
    ok = base + n <= spec.local_rows
    idx = jnp.where(ok, base + jnp.arange(n, dtype=_I32), spec.local_rows)
    data = state.data.at[idx].set(rows.astype(_U32), mode="drop")
    offsets = base + jnp.concatenate(
        [jnp.zeros((1,), _I32), jnp.cumsum(lengths)[:-1].astype(_I32)])
    # a failed allocation must NOT hand out in-range offsets: they would
    # alias whatever record lands there next, and a later rget_rows
    # would silently read another record's rows.  Clamp failed pointers
    # to the out-of-range sentinel so reads report not-found instead.
    offsets = jnp.where(ok, offsets, spec.local_rows)
    rank = jnp.broadcast_to(backend.rank(), offsets.shape)
    new_top = jnp.where(ok, state.top + n, state.top)
    costs.record("heap.store_local", costs.Cost(local=n))
    return (HeapState(data, new_top),
            GlobalPointer(rank, offsets),
            jnp.broadcast_to(ok, offsets.shape))


def rget_rows(backend: Backend, spec: HeapSpec, state: HeapState,
              ptrs: GlobalPointer, span: int, capacity: int,
              max_rounds: int = 1):
    """Read ``span`` consecutive rows behind each pointer (static span).

    Returns ``(rows (K, span, lanes), found (K,), dropped () i32)``.
    Variable-length records read their max span and slice by the stored
    length.  ``found`` is False when the record's base offset is not a
    live heap row (dangling / failed-alloc sentinel pointers) or when
    any of its row-requests fell off the wire; ``dropped`` is the
    global overflow count, so callers can tell "record absent"
    (found=False, dropped=0) from "requests fell off the wire"
    (dropped>0) — and retry with a larger ``capacity`` or
    ``max_rounds`` in the latter case instead of mis-reporting absence.
    A short record near the heap end may legally overshoot with a
    larger static span: rows past the end read as zeros and do NOT
    unfind the record (callers slice by the stored length).
    """
    k = ptrs.rank.shape[0]
    # expand each pointer into `span` unit row-requests
    off = (ptrs.offset[:, None] + jnp.arange(span, dtype=_I32)[None]
           ).reshape(-1)
    dst = jnp.repeat(ptrs.rank, span)
    req = route(backend, off.astype(_U32)[:, None], dst,
                capacity=capacity * span, op_name="heap.rget",
                max_rounds=max_rounds)
    loff = jnp.where(req.valid, req.payload[:, 0].astype(_I32), 0)
    # serve only in-range offsets, and SAY so: the reply carries an
    # in-range flag lane, so a clamped gather can never masquerade as
    # another record's data on the requester side
    in_range = req.valid & (loff >= 0) & (loff < spec.local_rows)
    served = jnp.where(in_range[:, None],
                       state.data[jnp.clip(loff, 0, spec.local_rows - 1)], 0)
    body = jnp.concatenate([served, in_range.astype(_U32)[:, None]], axis=1)
    out, answered = reply(backend, req, body, k * span,
                          op_name="heap.rget")
    # found = every row-request came back AND the BASE row is live: the
    # in-range flag only gates the first row, so a span overshooting
    # the heap end doesn't unfind a short record, while sentinel /
    # dangling base offsets still read as absent
    base_live = (out[:, -1] == 1).reshape(k, span)[:, 0]
    costs.record("heap.rget", costs.Cost(R=k * span))
    return (out[:, :-1].reshape(k, span, spec.lanes),
            answered.reshape(k, span).all(axis=1) & base_live,
            req.dropped)
