"""BCL::BloomFilter — distributed *blocked* Bloom filter (paper 5.4.2).

A value hashes to one 64-bit block; k bit positions inside that block
come from double hashing.  Insertion is a single owner-side RMW on one
64-bit word (the paper's single fetch-and-or AMO), and it atomically
returns whether the value was already present — including among
duplicates within the same batch, where exactly the first inserter (in
deterministic arrival order) observes "not present".  This is the
property the paper shows a flat distributed Bloom filter cannot provide.

Cost model (paper Table 2): insert = A, find = R.

``insert_find`` fuses an insert batch and a membership-query batch into
one ExchangePlan round trip (DESIGN.md section 1.5) — the dedup
pipeline's contamination-check pattern; ``Promise.FINE`` recovers the
sequential schedule.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.exchange import ExchangePlan, PendingResult
from repro.core.hashing import double_hash, hash_lanes
from repro.core.object_container import Packer, packer_for
from repro.core.promises import Promise, fine_grained, validate
from repro.kernels import ops as kops
from repro.kernels.ref import bloom_words_ref

_U32 = jnp.uint32
_I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class BloomSpec:
    nblocks_global: int
    nblocks_local: int
    k: int
    packer: Packer
    impl: str = "auto"


class BloomState(NamedTuple):
    words: jax.Array   # (nb_local, 2) u32 — one 64-bit block per row


def bloom_create(backend: Backend, nbits: int, value_spec,
                 k: int = 4, impl: str = "auto") -> tuple[BloomSpec, BloomState]:
    packer = packer_for(value_spec)
    nprocs = backend.nprocs()
    nb_global = max(1, -(-nbits // 64))
    nb_global = -(-nb_global // nprocs) * nprocs
    nb_local = nb_global // nprocs
    spec = BloomSpec(nb_global, nb_local, k, packer, impl)
    return spec, BloomState(jnp.zeros((nb_local, 2), _U32))


def _words_of(spec: BloomSpec, items, valid):
    """Pack items into the wire body ``[local block | 2 bit-words]``."""
    lanes = spec.packer.pack(items)
    n = lanes.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    gblock = (hash_lanes(lanes, seed=11)
              % _U32(spec.nblocks_global)).astype(_I32)
    owner = gblock // spec.nblocks_local
    lblock = gblock % spec.nblocks_local
    words = bloom_words_ref(double_hash(lanes, spec.k, 64), spec.k)
    body = jnp.concatenate([lblock.astype(_U32)[:, None], words], axis=1)
    return n, body, owner, valid


def _route_words(backend: Backend, spec: BloomSpec, items, valid, capacity,
                 op_name: str, max_rounds: int = 1, transport=None):
    """Single-flow plan shipping ``[lblock | bit-words]`` rows; the
    1-word answer reply rides the committed plan's inverse permutation
    (through the chosen transport)."""
    n, body, owner, valid = _words_of(spec, items, valid)
    plan = ExchangePlan(name=op_name)
    h = plan.add(body, owner, capacity, reply_lanes=1, valid=valid,
                 op_name=op_name)
    c = plan.commit(backend, impl=spec.impl, max_rounds=max_rounds,
                    transport=transport)
    res = c.view(h)
    rb = jnp.where(res.valid, res.payload[:, 0].astype(_I32), 0)
    rw = res.payload[:, 1:3]
    return n, c, h, res, rb, rw


def insert(backend: Backend, spec: BloomSpec, state: BloomState,
           items, capacity: int, valid: jax.Array | None = None,
           max_rounds: int = 1, transport=None):
    """Atomic insert; returns (state, already_present(N,)).

    ``already_present[i]`` is True iff every one of item i's k bits was
    set before item i's own insertion — first-inserter-wins across the
    whole machine and within the batch (paper's atomicity invariant).
    """
    n, c, h, res, rb, rw = _route_words(
        backend, spec, items, valid, capacity, "bloom.insert",
        max_rounds=max_rounds, transport=transport)
    words, already = kops.bloom_insert(state.words, rb, rw, res.valid,
                                       impl=spec.impl)
    c.set_reply(h, already.astype(_U32))
    back, _ = c.finish(backend)[h]
    costs.record("bloom.insert", costs.Cost(A=1))
    return BloomState(words), back[:, 0] == 1


def find(backend: Backend, spec: BloomSpec, state: BloomState,
         items, capacity: int, valid: jax.Array | None = None,
         max_rounds: int = 1, transport=None):
    """Membership query; returns present(N,). Cost R."""
    n, c, h, res, rb, rw = _route_words(
        backend, spec, items, valid, capacity, "bloom.find",
        max_rounds=max_rounds, transport=transport)
    present = kops.bloom_find(state.words, rb, rw, res.valid, impl=spec.impl)
    c.set_reply(h, present.astype(_U32))
    back, _ = c.finish(backend)[h]
    costs.record("bloom.find", costs.Cost(R=n))
    return back[:, 0] == 1


def insert_find(backend: Backend, spec: BloomSpec, state: BloomState,
                ins_items, find_items, capacity_ins: int, capacity_find: int,
                ins_valid: jax.Array | None = None,
                find_valid: jax.Array | None = None,
                promise: Promise = Promise.NONE,
                max_rounds: int = 1,
                transport=None,
                async_: bool = False):
    """Fused insert + membership query sharing ONE exchange round trip.

    The insert is serialized before the find, so the query observes this
    batch's insertions (exactly the ``Promise.FINE`` sequential order).
    Both ops' flows ride one ExchangePlan: 2 collectives where the FINE
    schedule costs 4, at the exact sum of the standalone ops' wire
    bytes (ragged segments, DESIGN.md section 1.5 — the 1-bit answers
    ride 1-word reply rows).  Returns
    ``(state, already_present, present)``.

    ``async_=True`` issues the plan split-phase (DESIGN.md section 1.9)
    and instead returns a :class:`~repro.core.PendingResult` whose
    ``finish()`` yields the same triple.
    """
    validate(promise)
    if fine_grained(promise):
        def _fine():
            st, already = insert(backend, spec, state, ins_items,
                                 capacity_ins, valid=ins_valid,
                                 max_rounds=max_rounds, transport=transport)
            present = find(backend, spec, st, find_items, capacity_find,
                           valid=find_valid, max_rounds=max_rounds,
                           transport=transport)
            return st, already, present
        # split-phase FINE stays the sequential oracle: run eagerly
        return PendingResult(lambda s=_fine(): s) if async_ else _fine()

    ni, body_i, owner_i, ins_valid = _words_of(spec, ins_items, ins_valid)
    nf, body_f, owner_f, find_valid = _words_of(spec, find_items, find_valid)
    plan = ExchangePlan(name="bloom.insert_find")
    hi = plan.add(body_i, owner_i, capacity_ins, reply_lanes=1,
                  valid=ins_valid, op_name="bloom.insert")
    hf = plan.add(body_f, owner_f, capacity_find, reply_lanes=1,
                  valid=find_valid, op_name="bloom.find")
    if async_:
        pend = plan.commit_async(backend, impl=spec.impl,
                                 max_rounds=max_rounds, transport=transport)
        return PendingResult(lambda: _insert_find_complete(
            backend, spec, state, pend.finish(backend), hi, hf, nf))
    c = plan.commit(backend, impl=spec.impl, max_rounds=max_rounds,
                    transport=transport)
    return _insert_find_complete(backend, spec, state, c, hi, hf, nf)


def _insert_find_complete(backend, spec, state, c, hi, hf, nf):
    """Owner-side work + reply round of :func:`insert_find` (both the
    synchronous and the split-phase path complete through here)."""
    vi, vf = c.view(hi), c.view(hf)

    rb_i = jnp.where(vi.valid, vi.payload[:, 0].astype(_I32), 0)
    words, already = kops.bloom_insert(state.words, rb_i, vi.payload[:, 1:3],
                                       vi.valid, impl=spec.impl)
    rb_f = jnp.where(vf.valid, vf.payload[:, 0].astype(_I32), 0)
    present = kops.bloom_find(words, rb_f, vf.payload[:, 1:3], vf.valid,
                              impl=spec.impl)
    c.set_reply(hi, already.astype(_U32))
    c.set_reply(hf, present.astype(_U32))
    outs = c.finish(backend)
    bi, _ = outs[hi]
    bf, _ = outs[hf]
    costs.record("bloom.insert", costs.Cost(A=1))
    costs.record("bloom.find", costs.Cost(R=nf))
    return BloomState(words), bi[:, 0] == 1, bf[:, 0] == 1


def fill_fraction(backend: Backend, state: BloomState) -> jax.Array:
    """Fraction of set bits (diagnostic for false-positive estimation)."""
    pop = jax.lax.population_count(state.words).sum()
    tot = backend.psum(pop)
    nbits = backend.psum(jnp.int32(state.words.size * 32))
    return tot / nbits
