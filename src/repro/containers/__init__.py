"""BCL distributed data structures (paper section 5), JAX edition.

Containers are functional: state is a NamedTuple pytree of per-rank
shards (usable inside ``jax.shard_map``), specs are static Python
objects carrying packers/geometry, and every method returns new state.

=====================  ===========  =========================================
Container              Locality     Description
=====================  ===========  =========================================
DHashMap               distributed  blocked open-addressing hash table
FastQueue              hosted       multi-reader OR multi-writer ring buffer
CircularQueue          hosted       multi-reader AND multi-writer ring buffer
HashMapBuffer          distributed  aggregates hash-table insertions
BloomFilter            distributed  blocked Bloom filter (atomic insert)
DArray                 distributed  1-D array
Heap                   hosted       bump-allocator for varlen payloads
=====================  ===========  =========================================
"""

from repro.containers.darray import DArraySpec, darray_create, rget, rput
from repro.containers.hashmap import HashMapSpec, hashmap_create
from repro.containers.queue import QueueSpec, queue_create
from repro.containers.bloom import BloomSpec, bloom_create
from repro.containers.hashmap_buffer import HashMapBufferSpec

__all__ = [
    "DArraySpec", "darray_create", "rget", "rput",
    "HashMapSpec", "hashmap_create",
    "QueueSpec", "queue_create",
    "BloomSpec", "bloom_create",
    "HashMapBufferSpec",
]
