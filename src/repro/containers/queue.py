"""BCL queues (paper section 5.2): FastQueue and CircularQueue.

Both are *hosted* ring buffers: every rank hosts one ring, and any rank
may push to / pop from any ring (a single-host queue is the special case
where all traffic targets one rank; the "many" pattern of the paper's
microbenchmarks is the general case).

RDMA BCL reserves ring slots with remote fetch-and-add.  Here the
reservation is owner-side: routed items arrive in a deterministic order
(source rank, then source position), and an exclusive prefix sum over
the arrivals assigns disjoint slots — associative fetch-and-add.

Remote ops lower through the ExchangePlan scheduler (DESIGN.md
section 1.5): ``push``/``pop`` are eager single-flow plans, and
``push_pop`` — the ``ConProm.CircularQueue.push_pop`` promise made
operational — fuses both ops' flows into one collective round trip
(``Promise.FINE`` recovers the sequential schedule).

Cost model (paper Table 2):
  FastQueue      push = A + nW     pop = A + nR
  CircularQueue  push = 2A + nW    pop = 2A + nR   (extra AMO maintains
                 the ready cursors that make concurrent push/pop safe)
  local_nonatomic_pop = l           resize = B + l   migrate = B + nW
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.exchange import ExchangePlan, PendingResult, route
from repro.core.object_container import Packer, packer_for
from repro.core.promises import (Promise, fine_grained, fully_atomic_queue,
                                 validate)

_U32 = jnp.uint32
_I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class QueueSpec:
    capacity: int          # ring capacity per host rank
    packer: Packer
    circular: bool = False  # CircularQueue: maintains ready cursors

    @property
    def lanes(self) -> int:
        return self.packer.lanes


class QueueState(NamedTuple):
    data: jax.Array        # (capacity, L) u32
    head: jax.Array        # (1,) i32 — monotone pop cursor
    tail: jax.Array        # (1,) i32 — monotone push cursor
    tail_ready: jax.Array  # (1,) i32 — CircularQueue publish cursor
    head_ready: jax.Array  # (1,) i32


def queue_create(backend: Backend, capacity: int, value_spec,
                 circular: bool = False) -> tuple[QueueSpec, QueueState]:
    packer = packer_for(value_spec)
    spec = QueueSpec(capacity, packer, circular)
    z = lambda: jnp.zeros((1,), _I32)
    state = QueueState(jnp.zeros((capacity, packer.lanes), _U32),
                       z(), z(), z(), z())
    return spec, state


def size(state: QueueState) -> jax.Array:
    return (state.tail - state.head)[0]


def _amo_count(spec: QueueSpec, promise: Promise) -> int:
    """AMOs per op per the paper's Tables 2/4."""
    if promise & Promise.LOCAL:
        return 0
    return 2 if spec.circular else 1


def push(backend: Backend, spec: QueueSpec, state: QueueState,
         values, dest: jax.Array, capacity: int,
         valid: jax.Array | None = None,
         promise: Promise = Promise.PUSH,
         max_rounds: int = 1,
         overflow: str = "drop",
         transport=None,
         dead_ranks=None,
         integrity: bool = False,
         impl: str = "auto"):
    """Push each value to the ring hosted on ``dest[i]``.

    Returns (state, pushed_here, dropped):
      pushed_here  items this rank's ring accepted
      dropped      global count rejected (route overflow or ring full)

    ``max_rounds=R`` retries wire overflow with carryover rounds — an
    all-to-one or zipf-skewed destination pattern keeps every item as
    long as the hottest (src,dst) pair stays under R*capacity.

    ``overflow="carry"`` closes the LAST loss path — ring-full rejects
    (DESIGN.md section 1.6).  The push then declares a 1-lane reply
    carrying the owner's per-arrival acceptance bit back over the
    inverse all-to-all, and the return value grows to
    ``(state, pushed_here, dropped=0, carry)``: ``carry`` marks, in the
    ORIGINAL batch, every valid item that either never shipped (wire
    overflow beyond all retry rounds) or shipped and was refused by a
    full ring.  The caller re-injects exactly those rows next cycle —
    nothing is dropped, at the price of the reply collective a
    fire-and-forget push normally skips.  A LOCAL push honors the same
    4-tuple contract straight from its local accept mask, with zero
    collectives.

    ``dead_ranks``/``integrity``/``impl`` pass straight to
    :meth:`ExchangePlan.commit` (DESIGN.md sections 1.8/1.10): items bound for
    a dead rank are masked at admission (reappearing in ``carry`` so a
    caller can re-target them), and with ``integrity=True`` arrivals
    whose wire segment fails its checksum are invalidated — under
    ``overflow="carry"`` such items never receive an accept ack, so the
    carry mask re-injects them and a retry heals transient corruption.
    """
    validate(promise)
    if overflow not in ("drop", "carry"):
        raise ValueError(
            f'queue.push overflow must be "drop" or "carry", '
            f"got {overflow!r}")
    lanes = spec.packer.pack(values)
    n = lanes.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)

    if promise & Promise.LOCAL:
        # local push: no collectives, CPU-only ring append (paper 4c);
        # carry needs no reply wire here — the accept mask IS local
        costs.record("queue.push", costs.Cost(local=n))
        state, pushed, full_drop, accept = _append(spec, state, lanes, valid)
        if overflow == "carry":
            return state, pushed, jnp.int32(0), valid & ~accept
        return state, pushed, full_drop

    if overflow == "carry":
        plan = ExchangePlan(name="queue.push")
        h = plan.add(lanes, dest, capacity, reply_lanes=1, valid=valid,
                     op_name="queue.push")
        c = plan.commit(backend, impl=impl, max_rounds=max_rounds,
                        transport=transport, dead_ranks=dead_ranks,
                        integrity=integrity)
        res = c.view(h)
        state, pushed, _, accept = _append(spec, state, res.payload,
                                           res.valid)
        c.set_reply(h, accept.astype(_U32))
        out, answered = c.finish(backend)[h]
        a = _amo_count(spec, promise)
        costs.record("queue.push", costs.Cost(A=a, W=n))
        landed = answered & (out[:, 0] == 1) & valid
        return state, pushed, jnp.int32(0), valid & ~landed

    res = route(backend, lanes, dest, capacity, valid=valid,
                op_name="queue.push", impl=impl, max_rounds=max_rounds,
                transport=transport, dead_ranks=dead_ranks,
                integrity=integrity)
    state, pushed, full_drop, _ = _append(spec, state, res.payload,
                                          res.valid)
    a = _amo_count(spec, promise)
    costs.record("queue.push", costs.Cost(A=a, W=n))
    dropped = res.dropped + backend.psum(full_drop)
    return state, pushed, dropped


def _append(spec: QueueSpec, state: QueueState, rows: jax.Array,
            valid: jax.Array):
    """Owner-side ring append in deterministic arrival order.

    Returns ``(state, n_accepted, n_rejected, accept)``; ``accept`` is
    the per-arrival acceptance mask in wire order — exactly the rows a
    reply-side carry (``push(overflow="carry")``) reports back so
    ring-full rejects are re-injected instead of lost.
    """
    pos = jnp.cumsum(valid.astype(_I32)) - valid.astype(_I32)  # exclusive
    total = valid.sum().astype(_I32)
    used = (state.tail - state.head)[0]
    room = jnp.maximum(spec.capacity - used, 0)
    accept = valid & (pos < room)
    n_acc = jnp.minimum(total, room)
    slot = jnp.where(accept, (state.tail[0] + pos) % spec.capacity,
                     spec.capacity)
    data = state.data.at[slot].set(rows, mode="drop")
    tail = state.tail + n_acc
    tail_ready = tail if spec.circular else state.tail_ready
    new = QueueState(data, state.head, tail, tail_ready, state.head_ready)
    return new, n_acc, (total - n_acc), accept


def _grant(spec: QueueSpec, state: QueueState, req_valid: jax.Array,
           promise: Promise):
    """Owner-side pop grant in deterministic arrival order (FAA analogue).

    Returns ``(new_state, body)`` where ``body`` rows are
    ``[value lanes | granted flag]`` aligned with the request arrivals.
    """
    arrival = jnp.cumsum(req_valid.astype(_I32)) - req_valid.astype(_I32)
    limit = state.tail[0] - state.head[0]
    if spec.circular and fully_atomic_queue(promise):
        limit = state.tail_ready[0] - state.head[0]
    grant = req_valid & (arrival < limit)
    idx = jnp.where(grant, (state.head[0] + arrival) % spec.capacity, 0)
    rows = jnp.where(grant[:, None], state.data[idx], 0)
    n_grant = jnp.minimum(req_valid.sum().astype(_I32), limit)
    head = state.head + n_grant
    head_ready = head if spec.circular else state.head_ready
    new = QueueState(state.data, head, state.tail, state.tail_ready,
                     head_ready)
    body = jnp.concatenate([rows, grant.astype(_U32)[:, None]], axis=1)
    return new, body


def _src_ranks(src: jax.Array | int, n: int) -> jax.Array:
    if isinstance(src, int):
        return jnp.full((n,), src, _I32)
    if src.ndim == 0:
        return jnp.broadcast_to(src, (n,)).astype(_I32)
    return src.astype(_I32)


def pop(backend: Backend, spec: QueueSpec, state: QueueState,
        n: int, src: jax.Array | int,
        promise: Promise = Promise.POP,
        max_rounds: int = 1,
        transport=None,
        dead_ranks=None,
        integrity: bool = False,
        impl: str = "auto"):
    """Pop up to ``n`` items from the ring hosted on rank ``src``.

    Every rank issues its own request; the owner grants ranges in
    deterministic requester order (the FAA analogue).  Returns
    (state, values, got_mask).
    """
    validate(promise)
    src = _src_ranks(src, n)

    if promise & Promise.LOCAL:
        return local_nonatomic_pop(spec, state, n)

    # unit requests: one row per wanted item (per-(src,dst) capacity = n);
    # a single-flow plan so the grant reply rides the transport's exact
    # inverse hop sequence (dense: the one inverse all-to-all)
    plan = ExchangePlan(name="queue.pop")
    h = plan.add(jnp.zeros((n, 1), _U32), src, n,
                 reply_lanes=spec.lanes + 1, op_name="queue.pop")
    c = plan.commit(backend, impl=impl, max_rounds=max_rounds,
                    transport=transport, dead_ranks=dead_ranks,
                    integrity=integrity)
    req = c.view(h)
    new, body = _grant(spec, state, req.valid, promise)
    c.set_reply(h, body)
    out, _ = c.finish(backend)[h]
    got = out[:, -1] == 1
    values = spec.packer.unpack(out[:, :-1])
    a = _amo_count(spec, promise)
    costs.record("queue.pop", costs.Cost(A=a, R=n))
    return new, values, got


def push_pop(backend: Backend, spec: QueueSpec, state: QueueState,
             values, dest: jax.Array, capacity: int,
             n: int, src: jax.Array | int,
             valid: jax.Array | None = None,
             promise: Promise = Promise.PUSH | Promise.POP,
             max_rounds: int = 1,
             overflow: str = "drop",
             transport=None,
             dead_ranks=None,
             integrity: bool = False,
             async_: bool = False,
             impl: str = "auto"):
    """Fused push + pop sharing ONE exchange round trip.

    Under ``ConProm.CircularQueue.push_pop`` the two ops are promised
    concurrent, so the runtime may serialize them; this schedule applies
    the push before granting the pop (items pushed this round are
    poppable this round) and fuses both ops' flows into one
    ExchangePlan: 2 collectives where the ``Promise.FINE`` sequential
    schedule costs 3 (push has no reply).  The ragged wire (DESIGN.md
    section 1.5) keeps the pop's unit requests at 2 u32 words per row
    no matter how wide the pushed values are — fusing costs exactly the
    two ops' standalone bytes.  Returns
    ``(state, pushed, dropped, out_values, got)``.

    ``overflow="carry"`` gives the fused push the same ring-full
    backpressure as ``push(overflow="carry")`` (DESIGN.md section 1.6):
    the push flow declares a 1-lane reply carrying the owner's
    ``_append`` accept mask — it rides the pop's inverse all-to-all, so
    the carry costs ZERO extra collectives here — and the return grows
    to ``(state, pushed, dropped=0, out_values, got, carry)`` where
    ``carry`` marks every valid item that never shipped or was refused
    by a full ring.

    ``async_=True`` issues the plan split-phase (DESIGN.md section 1.9)
    and instead returns a :class:`~repro.core.PendingResult` whose
    ``finish()`` yields the same tuple — the request wire overlaps with
    whatever the caller traces before finishing.
    """
    validate(promise)
    if overflow not in ("drop", "carry"):
        raise ValueError(
            f'queue.push_pop overflow must be "drop" or "carry", '
            f"got {overflow!r}")
    if async_ and fine_grained(promise):
        # split-phase FINE stays the sequential oracle: run eagerly,
        # hand completion back through the same future type
        sync = push_pop(backend, spec, state, values, dest, capacity, n,
                        src, valid=valid, promise=promise,
                        max_rounds=max_rounds, overflow=overflow,
                        transport=transport, dead_ranks=dead_ranks,
                        integrity=integrity, impl=impl)
        return PendingResult(lambda: sync)
    if fine_grained(promise):
        if overflow == "carry":
            state, pushed, dropped, carry = push(
                backend, spec, state, values, dest, capacity, valid=valid,
                promise=promise, max_rounds=max_rounds, overflow="carry",
                transport=transport, dead_ranks=dead_ranks,
                integrity=integrity, impl=impl)
            state, out, got = pop(backend, spec, state, n, src,
                                  promise=promise, max_rounds=max_rounds,
                                  transport=transport, dead_ranks=dead_ranks,
                                  integrity=integrity, impl=impl)
            return state, pushed, dropped, out, got, carry
        state, pushed, dropped = push(backend, spec, state, values, dest,
                                      capacity, valid=valid, promise=promise,
                                      max_rounds=max_rounds,
                                      transport=transport,
                                      dead_ranks=dead_ranks,
                                      integrity=integrity, impl=impl)
        state, out, got = pop(backend, spec, state, n, src, promise=promise,
                              max_rounds=max_rounds, transport=transport,
                              dead_ranks=dead_ranks, integrity=integrity,
                              impl=impl)
        return state, pushed, dropped, out, got

    lanes = spec.packer.pack(values)
    nv = lanes.shape[0]
    if valid is None:
        valid = jnp.ones((nv,), bool)
    src = _src_ranks(src, n)
    carrying = overflow == "carry"

    plan = ExchangePlan(name="queue.push_pop")
    hp = plan.add(lanes, dest, capacity, valid=valid,
                  reply_lanes=1 if carrying else 0, op_name="queue.push")
    hq = plan.add(jnp.zeros((n, 1), _U32), src, n,
                  reply_lanes=spec.lanes + 1, op_name="queue.pop")
    if async_:
        pend = plan.commit_async(backend, impl=impl, max_rounds=max_rounds,
                                 transport=transport, dead_ranks=dead_ranks,
                                 integrity=integrity)
        return PendingResult(lambda: _push_pop_complete(
            backend, spec, state, pend.finish(backend), hp, hq, valid,
            promise, carrying, nv, n))
    c = plan.commit(backend, impl=impl, max_rounds=max_rounds,
                    transport=transport, dead_ranks=dead_ranks,
                    integrity=integrity)
    return _push_pop_complete(backend, spec, state, c, hp, hq, valid,
                              promise, carrying, nv, n)


def _push_pop_complete(backend, spec, state, c, hp, hq, valid, promise,
                       carrying, nv, n):
    """Owner-side work + reply round of :func:`push_pop` (both the
    synchronous and the split-phase path complete through here)."""
    vp, vq = c.view(hp), c.view(hq)

    state, pushed, full_drop, accept = _append(spec, state, vp.payload,
                                               vp.valid)
    state, body = _grant(spec, state, vq.valid, promise)
    if carrying:
        c.set_reply(hp, accept.astype(_U32))
    c.set_reply(hq, body)
    outs = c.finish(backend)
    out, _ = outs[hq]
    got = out[:, -1] == 1
    out_values = spec.packer.unpack(out[:, :-1])
    a = _amo_count(spec, promise)
    costs.record("queue.push", costs.Cost(A=a, W=nv))
    costs.record("queue.pop", costs.Cost(A=a, R=n))
    if carrying:
        outp, answered = outs[hp]
        landed = answered & (outp[:, 0] == 1) & valid
        return (state, pushed, jnp.int32(0), out_values, got,
                valid & ~landed)
    dropped = vp.dropped + backend.psum(full_drop)
    return state, pushed, dropped, out_values, got


def local_nonatomic_pop(spec: QueueSpec, state: QueueState, n: int):
    """Pop n items from this rank's own ring; no collectives (paper 4f)."""
    avail = state.tail[0] - state.head[0]
    take = jnp.arange(n, dtype=_I32)
    got = take < avail
    idx = jnp.where(got, (state.head[0] + take) % spec.capacity, 0)
    rows = jnp.where(got[:, None], state.data[idx], 0)
    n_got = jnp.minimum(jnp.int32(n), avail)
    head = state.head + n_got
    head_ready = head if spec.circular else state.head_ready
    new = QueueState(state.data, head, state.tail, state.tail_ready,
                     head_ready)
    costs.record("queue.local_nonatomic_pop", costs.Cost(local=n))
    return new, spec.packer.unpack(rows), got


def local_drain(spec: QueueSpec, state: QueueState):
    """Read the whole local ring in FIFO order (the ``as_vector`` of the
    paper's Fig. 3); state unchanged.  Returns (rows, valid)."""
    take = jnp.arange(spec.capacity, dtype=_I32)
    avail = state.tail[0] - state.head[0]
    got = take < avail
    idx = (state.head[0] + take) % spec.capacity
    rows = jnp.where(got[:, None], state.data[idx], 0)
    return spec.packer.unpack(rows), got


def export_state(spec: QueueSpec, state: QueueState) -> dict:
    """This rank's ring as a checkpointable pytree (plain dict of arrays).

    The dict rides ``checkpoint.save_checkpoint`` unchanged; a survivor
    restores a dead rank's shard with :func:`restore_state` and
    re-injects its live rows (``local_drain`` of the restored state)
    through an ordinary ``push`` — the recovery path of DESIGN.md
    section 1.8.
    """
    return {"data": state.data, "head": state.head, "tail": state.tail,
            "tail_ready": state.tail_ready, "head_ready": state.head_ready}


def restore_state(spec: QueueSpec, exported: dict) -> QueueState:
    """Rebuild a QueueState from :func:`export_state` output."""
    data = jnp.asarray(exported["data"], _U32)
    if data.shape != (spec.capacity, spec.lanes):
        raise ValueError(
            f"queue.restore_state: data shape {data.shape} does not match "
            f"spec (capacity={spec.capacity}, lanes={spec.lanes})")
    as_i32 = lambda k: jnp.asarray(exported[k], _I32).reshape((1,))
    return QueueState(data, as_i32("head"), as_i32("tail"),
                      as_i32("tail_ready"), as_i32("head_ready"))


def resize(backend: Backend, spec: QueueSpec, state: QueueState,
           new_capacity: int) -> tuple[QueueSpec, QueueState]:
    """Collective resize (paper cost B + l)."""
    backend.barrier()
    rows, got = local_drain(spec, state)
    lanes = spec.packer.pack(rows)
    new_spec = dataclasses.replace(spec, capacity=new_capacity)
    m = jnp.minimum((state.tail - state.head)[0], new_capacity)
    take = jnp.arange(spec.capacity, dtype=_I32)
    data = jnp.zeros((new_capacity, spec.lanes), _U32)
    data = data.at[jnp.where(got & (take < m), take, new_capacity)].set(
        lanes, mode="drop")
    z = jnp.zeros((1,), _I32)
    tail = m[None]
    costs.record("queue.resize", costs.Cost(B=1, local=int(spec.capacity)))
    return new_spec, QueueState(data, z, tail,
                                tail if spec.circular else z, z)


def migrate(backend: Backend, spec: QueueSpec, state: QueueState,
            shift: int = 1) -> QueueState:
    """Collective migration: ring moves to (rank + shift) % P (B + nW)."""
    nprocs = backend.nprocs()
    if nprocs == 1:
        return state
    backend.barrier()
    perm = [(i, (i + shift) % nprocs) for i in range(nprocs)]
    moved = jax.tree_util.tree_map(lambda x: backend.ppermute(x, perm), state)
    costs.record("queue.migrate", costs.Cost(B=1, W=int(spec.capacity)))
    return moved
