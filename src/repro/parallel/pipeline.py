"""GPipe-style pipeline parallelism over a 'stage' mesh axis.

The PP option of DESIGN.md section 5: layers are partitioned into S
stage groups sharded over a mesh axis; microbatches flow through a
collective-permute ring with a scan over S + M - 1 ticks (fill + steady
state + drain).  Stage handoff is one ppermute per tick — the TPU-native
point-to-point (the closest collective to an RDMA put, which is why it
lives here next to the BCL core).

Used by the training driver when a config requests pp_stages > 1 (the
mandated dry-run mesh exercises DP x TP x pod; PP composes with them on
a 4-axis mesh).  Correctness: tests/spmd_check.py proves a 4-stage
pipeline equals the sequential composition of the stages.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map


def gpipe(stage_fn: Callable, stacked_params, x_microbatches, mesh: Mesh,
          axis: str = "stage"):
    """Run ``stage_fn`` as an S-stage pipeline.

    stage_fn(params_slice, x) -> y with x and y the same shape
    stacked_params: pytree with leading dim S (sharded over ``axis``)
    x_microbatches: (M, mb, ...) microbatches
    Returns (M, mb, ...) outputs of the final stage.
    """
    s = mesh.shape[axis]

    def per_stage(params_s, x_all):
        # params_s: this stage's slice (leading dim 1 from sharding)
        params_local = jax.tree_util.tree_map(lambda p: p[0], params_s)
        sid = jax.lax.axis_index(axis)
        m = x_all.shape[0]
        ticks = m + s - 1
        perm = [(i, (i + 1) % s) for i in range(s)]

        def step(buf, t):
            # stage 0 ingests microbatch t; later stages consume the ring
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(sid == 0, x_all[mb_idx], buf)
            y = stage_fn(params_local, x_in)
            nxt = jax.lax.ppermute(y, axis, perm)
            return nxt, y

        _, ys = jax.lax.scan(step, jnp.zeros_like(x_all[0]),
                             jnp.arange(ticks))
        # the final stage emits microbatch i at tick i + (s-1)
        out = jax.lax.dynamic_slice_in_dim(ys, s - 1, m, axis=0)
        out = jnp.where(sid == s - 1, out, 0)
        return jax.lax.psum(out, axis)      # broadcast the result

    nd = x_microbatches.ndim
    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P(*([None] * nd))),
        out_specs=P(*([None] * nd)),
        check_vma=False,
    )(stacked_params, x_microbatches)
