"""internvl2-76b [vlm] — arXiv:2404.16821 (unverified).

Language backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256 (Llama-3-70B-style).  InternViT frontend is a stub per the
assignment: input_specs provides precomputed patch embeddings
(B, 256, D) prepended to the text sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, layer_pattern="g",
    frontend="patch", frontend_len=256,
    activation="swiglu", rope_theta=5e5,
    tie_embeddings=False, fsdp=True,
)
