"""Architecture configuration schema.

One frozen dataclass drives the whole stack: model assembly
(models/lm.py), sharding rules (models/sharding.py), input specs
(configs/shapes.py) and the dry-run.  Every assigned architecture gets a
``configs/<id>.py`` exporting ``CONFIG`` built from this schema, plus a
``reduced()`` variant for CPU smoke tests.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    shared_experts: int = 0          # DeepSeek: always-on shared expert(s)
    dense_residual: bool = False     # Arctic: parallel dense FFN residual
    first_k_dense: int = 0           # DeepSeek: first k layers stay dense
    capacity_factor: float = 1.5     # exchange slot slack
    aux_loss_coef: float = 0.001
    bias_update_rate: float = 0.0    # >0: DeepSeek aux-loss-free bias routing


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64                # Mamba2 state dim / RWKV head dim
    d_conv: int = 4                  # Mamba2 short conv width
    expand: int = 2                  # Mamba2 inner expansion
    n_heads: int = 0                 # 0 => derive from d_model / d_state
    chunk: int = 128                 # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 => d_model // n_heads

    # attention flavor
    qk_norm: bool = False
    sliding_window: int = 0          # window for 'l' layers
    layer_pattern: str = "g"         # repeating unit: g=global attn,
                                     # l=local attn, m=mamba2, r=rwkv6,
                                     # a=shared attn (zamba)
    rope_theta: float = 1e4
    activation: str = "swiglu"       # swiglu|geglu|gelu|relu2

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    mtp: bool = False                # DeepSeek multi-token prediction head

    # encoder-decoder (audio) / multimodal (vlm)
    encoder_layers: int = 0          # >0 => enc-dec; decoder = n_layers
    frontend: Optional[str] = None   # None|"frame"|"patch" (stub embeddings)
    frontend_len: int = 256          # patches/frames consumed by the stub

    tie_embeddings: bool = True
    norm_eps: float = 1e-5

    # numerics / memory
    dtype: str = "bfloat16"
    remat: str = "block"             # none|block
    scan_layers: bool = True

    # parallelism hints (see models/sharding.py)
    fsdp: bool = False               # ZeRO-3 over the data axis
    ep_over_model: bool = True       # expert parallelism over model axis
    optimizer_dtype: str = "float32"  # adam moments dtype
    factored_second_moment: bool = False   # adafactor-style v

    # exchange capacity model for MoE dispatch (tokens per (src,dst) pair
    # as a multiple of the uniform expectation)
    moe_capacity_slack: float = 1.5
    # carryover retry rounds for the dispatch exchange: round r re-ships
    # tokens with per-(src,dst) rank in [r*C, (r+1)*C), so hot experts
    # tolerate up to rounds x slack of the uniform load before any token
    # is dropped — skew tolerance without widening every round's wire
    moe_dispatch_rounds: int = 1
    # physical collective layer for the dispatch exchange (DESIGN.md
    # section 1.7): "dense" = one tiled all-to-all over the expert axis,
    # "hier" = two-stage Pr x Pc exchange with sqrt(P) peers per hop
    exchange_transport: str = "dense"

    sub_quadratic: bool = False      # eligible for long_500k

    # ---- perf knobs (EXPERIMENTS.md section Perf) — defaults are the
    # paper-faithful baseline; hillclimbed cells override them ----
    grad_accum: int = 1              # microbatches per step (memory /k)
    remat_policy: str = "default"    # default|nothing|dots
    mla_absorb: bool = False         # DeepSeek weight-absorbed MLA decode
    mla_cp_decode: bool = False      # shard the MLA cache sequence over
                                     # 'model' (context-parallel decode,
                                     # two-pass softmax combine)
    attn_probs_bf16: bool = False    # cast softmax probs to bf16 for PV
    window_cache: bool = False       # cap 'l'-layer decode caches at window
    moe_payload_dtype: str = "float32"   # bfloat16 halves exchange bytes
    moe_dedup_dispatch: bool = False     # one copy per distinct owner rank
    moe_async_dispatch: bool = False     # split-phase dispatch: issue the
                                         # exchange, overlap the always-on
                                         # (shared/dense) paths, then finish
                                         # (DESIGN.md section 1.9)
    attn_q_block: int = 2048
    attn_k_block: int = 1024
    xent_chunk: int = 512

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the embedding shards evenly over any mesh
        axis we use (512 = lcm headroom for model=16 and lane tiling)."""
        return -(-self.vocab // 512) * 512

    @property
    def pattern_unit(self) -> str:
        return self.layer_pattern

    def layer_plan(self) -> tuple[int, str]:
        """(n_full_units, remainder_pattern) for scan-over-layers."""
        u = len(self.layer_pattern)
        return self.n_layers // u, self.layer_pattern[: self.n_layers % u]

    def param_count(self) -> int:
        """Approximate parameter count (for 6*N*D model-flops)."""
        d, ff, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd
        unit = self.layer_pattern or "g"

        def attn_params():
            if self.mla:
                m = self.mla
                qp = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.qk_rope_head_dim)
                kvp = d * (m.kv_lora_rank + m.qk_rope_head_dim) + \
                    m.kv_lora_rank * self.n_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)
                op = self.n_heads * m.v_head_dim * d
                return qp + kvp + op
            return d * (n_q + 2 * n_kv) + n_q * d

        def mlp_params(width):
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            return mult * d * width

        def ssm_params():
            inner = (self.ssm.expand if self.ssm else 2) * d
            return d * inner * 2 + inner * d + inner * 64  # rough

        total = 0
        counts = {c: 0 for c in "glmar"}
        for i in range(L):
            counts[unit[i % len(unit)]] += 1
        n_attn = counts["g"] + counts["l"]
        n_ssm = counts["m"] + counts["r"]
        total += n_attn * attn_params()
        if counts["a"]:
            total += attn_params() + counts["a"] * 0  # shared weights
            n_attn += 0
        total += n_ssm * ssm_params()
        if self.moe:
            mo = self.moe
            n_moe = L - mo.first_k_dense
            total += mo.first_k_dense * mlp_params(ff if not self.moe else
                                                   max(ff, 4 * d))
            total += n_moe * (mo.n_experts + mo.shared_experts) * \
                mlp_params(mo.expert_d_ff)
            if mo.dense_residual:
                total += n_moe * mlp_params(ff)
            total += n_moe * d * mo.n_experts  # router
        else:
            total += (n_attn + n_ssm + counts["a"]) * 0
            total += L * mlp_params(ff) if "m" not in unit and "r" not in unit \
                else (counts["g"] + counts["l"] + counts["a"]) * mlp_params(ff)
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            total += self.encoder_layers * (attn_params() + mlp_params(ff)) \
                + self.n_layers * attn_params()  # cross-attn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k + shared only)."""
        if not self.moe:
            return self.param_count()
        mo = self.moe
        full = self.param_count()
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        n_moe = self.n_layers - mo.first_k_dense
        all_experts = n_moe * (mo.n_experts + mo.shared_experts) * \
            mult * self.d_model * mo.expert_d_ff
        active_experts = n_moe * (mo.top_k + mo.shared_experts) * \
            mult * self.d_model * mo.expert_d_ff
        return int(full - all_experts + active_experts)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    changes = dict(
        n_layers=max(2, len(cfg.layer_pattern)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) or 4,
        d_ff=128,
        vocab=256,
        head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_len=8 if cfg.frontend else 0,
        scan_layers=cfg.scan_layers,
        fsdp=False,
        dtype="float32",
        optimizer_dtype="float32",
    )
    if cfg.moe:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=8, top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=64, first_k_dense=min(cfg.moe.first_k_dense, 1))
    if cfg.mla:
        changes["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                   qk_nope_head_dim=16, qk_rope_head_dim=8,
                                   v_head_dim=16)
    if cfg.ssm:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, chunk=16)
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)
