"""arctic-480b [moe] — hf:Snowflake/snowflake-arctic-base (hf).

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000.
Dense-MoE hybrid: every layer has a dense residual MLP in parallel with
a 128-expert top-2 MoE (Arctic's architecture).  Expert dispatch runs on
the BCL exchange (models/moe.py) — this arch is a primary carrier of the
paper's technique.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, layer_pattern="g",
    activation="swiglu", rope_theta=1e6,
    moe=MoEConfig(n_experts=128, top_k=2, expert_d_ff=4864,
                  dense_residual=True, capacity_factor=1.5),
    tie_embeddings=False, fsdp=True,
    optimizer_dtype="bfloat16",
)
