"""deepseek-v3-671b [moe] — arXiv:2412.19437 (hf).

61L d_model=7168 128H d_ff=2048(expert) vocab=129280.
MLA (q_lora 1536 / kv_lora 512 / rope 64), 1 shared + 256 routed
experts top-8, first 3 layers dense (d_ff 18432), MTP head, aux-free
bias routing.  The technique-representative hillclimb cell: the heaviest
BCL-exchange traffic in the pool.
"""
import dataclasses
from repro.configs.base import ArchConfig, MoEConfig, MLAConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,  # dense (first_k_dense) layers; experts use expert_d_ff
    vocab=129280, layer_pattern="g",
    activation="swiglu", rope_theta=1e4,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, expert_d_ff=2048,
                  shared_experts=1, first_k_dense=3,
                  bias_update_rate=0.001, capacity_factor=1.3),
    mtp=True,
    tie_embeddings=False, fsdp=True,
    optimizer_dtype="bfloat16", factored_second_moment=True,
)
