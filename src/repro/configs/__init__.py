"""Assigned architecture configs (+ the paper's own app configs).

Every module exports CONFIG (the exact assigned configuration) and the
registry below maps --arch ids to them.  ``reduced(CONFIG)`` gives the
CPU smoke-test variant.
"""

from repro.configs.base import ArchConfig, MoEConfig, MLAConfig, SSMConfig, reduced
from repro.configs.shapes import SHAPES, ShapeSpec, input_specs, shape_applicable


def get_config(name: str) -> ArchConfig:
    import importlib
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


ARCH_IDS = [
    "stablelm-1.6b",
    "nemotron-4-15b",
    "gemma3-4b",
    "qwen3-4b",
    "seamless-m4t-medium",
    "internvl2-76b",
    "arctic-480b",
    "deepseek-v3-671b",
    "rwkv6-1.6b",
    "zamba2-7b",
]

__all__ = ["ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig", "reduced",
           "SHAPES", "ShapeSpec", "input_specs", "shape_applicable",
           "get_config", "ARCH_IDS"]
