"""zamba2-7b [hybrid] — arXiv:2411.15242 (unverified).

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64.
Mamba2 backbone with a SHARED attention block woven in every 6th slot
(one attention parameter set reused — Zamba's signature).  81 = 13 x
"mmmmma" + "mmm" remainder.  Sub-quadratic end-to-end state => runs
long_500k (the shared-attention KV cache is the only seq-len state).
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, layer_pattern="mmmmma",
    ssm=SSMConfig(d_state=64, expand=2),
    activation="swiglu",
    tie_embeddings=True, fsdp=True,
    sub_quadratic=True,
)
