"""nemotron-4-15b [dense] — arXiv:2402.16819 (unverified).

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000.
Squared-ReLU MLP, no gating.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab=256000, layer_pattern="g",
    activation="relu2", rope_theta=1e4,
    tie_embeddings=False, fsdp=True,
)
