"""Assigned input shapes and ShapeDtypeStruct input specs.

Four shapes per LM architecture (assignment):
  train_4k      seq 4,096    global_batch 256    lowers train_step
  prefill_32k   seq 32,768   global_batch 32     lowers prefill
  decode_32k    seq 32,768   global_batch 128    lowers decode_step
  long_500k     seq 524,288  global_batch 1      lowers decode_step
                (sub-quadratic archs only — skips recorded in the table)

``input_specs`` returns weak-type-correct ShapeDtypeStructs: the dry-run
lowers against them with zero allocation.  Modality frontends are stubs
per the assignment: [audio] provides precomputed frame embeddings,
[vlm] precomputed patch embeddings.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k decode requires "
                       "sub-quadratic mixing (DESIGN.md section 6)")
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, t = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    d = cfg.d_model

    if shape.kind == "train":
        if cfg.frontend == "patch":
            n_txt = t - cfg.frontend_len
            return {"tokens": SDS((b, n_txt + 1), i32),
                    "patch_embeds": SDS((b, cfg.frontend_len, d), f32),
                    "loss_mask": SDS((b, n_txt), f32)}
        if cfg.frontend == "frame":
            return {"tokens": SDS((b, t + 1), i32),
                    "src_embeds": SDS((b, max(t // 4, 8), d), f32),
                    "loss_mask": SDS((b, t), f32)}
        return {"tokens": SDS((b, t + 1), i32),
                "loss_mask": SDS((b, t), f32)}

    if shape.kind == "prefill":
        batch = {"tokens": SDS((b, t), i32)}
        if cfg.frontend == "patch":
            batch = {"tokens": SDS((b, t - cfg.frontend_len), i32),
                     "patch_embeds": SDS((b, cfg.frontend_len, d), f32)}
        if cfg.frontend == "frame":
            batch["src_embeds"] = SDS((b, max(t // 4, 8), d), f32)
        return batch

    # decode: one new token against a cache of seq_len
    from repro.models.lm import cache_init
    cross = max(t // 4, 8) if cfg.frontend == "frame" else 0
    cache = jax.eval_shape(lambda: cache_init(cfg, b, t, cross))
    return {"tokens": SDS((b, 1), i32), "cache": cache}
