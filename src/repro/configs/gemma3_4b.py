"""gemma3-4b [dense] — hf:google/gemma-3-*-pt family (unverified).

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
5 local(sliding-1024):1 global layer pattern; 128k-ready rope base.
34 = 5x"lllllg" + "llll" remainder (the assembler unrolls the tail).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab=262144, layer_pattern="lllllg",
    sliding_window=1024, qk_norm=True,
    activation="geglu", rope_theta=1e6,
    tie_embeddings=True, fsdp=False,
)
