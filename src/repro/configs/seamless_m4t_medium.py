"""seamless-m4t-medium [audio] — arXiv:2308.11596 (hf).

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206; encoder-decoder.
Frontend is a stub per the assignment: input_specs provides precomputed
frame embeddings (B, T/4, D); the speech encoder conv stack is out of
scope (the transformer backbone is what's specified).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, layer_pattern="g",
    encoder_layers=12, frontend="frame",
    activation="gelu", rope_theta=1e4,
    tie_embeddings=False, fsdp=False,
)
