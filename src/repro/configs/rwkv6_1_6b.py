"""rwkv6-1.6b [ssm] — Finch, arXiv:2404.05892 (unverified).

24L d_model=2048 d_ff=7168 vocab=65536; attention-free data-dependent
decay linear recurrence.  Sub-quadratic: runs the long_500k shape.
The paper's technique (exchange/containers) is inapplicable to the
mixing layer (no attention, no MoE) — embedding rget only
(DESIGN.md section 6); the arch is built regardless.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, layer_pattern="r",
    ssm=SSMConfig(d_state=64),
    activation="relu2",
    tie_embeddings=False, fsdp=False,
    sub_quadratic=True,
)
