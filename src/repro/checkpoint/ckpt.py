"""Fault-tolerant checkpointing: sharded, atomic, elastic.

Layout (one directory per step):

  <dir>/step_000123.tmp/           written first
      index.json                   tree structure, shapes, dtypes, step
      arr_<n>.npz                  one file per host-local batch of leaves
  <dir>/step_000123/               atomic rename on completion

Properties required at scale (DESIGN.md section 5):
  * atomicity: a crash mid-save never corrupts the latest checkpoint —
    readers only ever see fully-renamed directories;
  * elasticity: restore() re-shards onto whatever mesh the restarting
    job has (save stores full logical arrays per leaf batch; device
    placement is reapplied with the new shardings) — save on mesh A,
    restore on mesh B is a tested path;
  * retention: keep the newest K checkpoints;
  * async: save can run on a background thread (the train driver
    overlaps it with the next step).

On a real multi-host pod each host writes only the shards it owns (the
addressable-shard loop below); in this single-process container every
shard is addressable, which exercises the same code path.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zipfile
import zlib
from typing import Any

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A checkpoint directory failed its integrity check on restore."""


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_checksums(arrays: dict[str, np.ndarray]) -> dict[str, int]:
    """crc32 over each leaf's raw bytes (shape/dtype pinned by index.json)."""
    return {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
            for k, v in arrays.items()}


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(tree)
    index = {"step": step,
             "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
             if hasattr(jax.tree_util.tree_structure(tree),
                        "serialize_using_proto") else None,
             "n_leaves": len(leaves),
             "leaves": []}

    arrays = {}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        arrays[f"leaf_{i}"] = arr
        index["leaves"].append({"i": i, "shape": list(arr.shape),
                                "dtype": str(arr.dtype)})
    np.savez(os.path.join(tmp, "arr_0.npz"), **arrays)
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    # integrity sidecar: per-leaf crc32 verified on restore, so a torn or
    # bit-rotted checkpoint is detected instead of silently restored
    with open(os.path.join(tmp, "checksums.json"), "w") as f:
        json.dump(_leaf_checksums(arrays), f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish
    _retain(directory, keep)
    return final


def restore_checkpoint(directory: str, step: int | None, like: Any,
                       shardings: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``like``; reshard onto ``shardings``.

    ``like`` supplies the treedef (and dtype casts if they changed);
    ``shardings`` (optional tree of NamedSharding) supports elastic
    restore onto a different mesh.

    Every leaf is verified against the ``checksums.json`` sidecar
    written by :func:`save_checkpoint`; a torn file, truncated archive,
    or bit-rotted array raises :class:`CheckpointCorruptError` rather
    than restoring silently-wrong state.  (Checkpoints predating the
    sidecar restore unverified.)
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    try:
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        data = np.load(os.path.join(path, "arr_0.npz"))
        arrays = {k: data[k] for k in data.files}
    except (OSError, ValueError, KeyError, json.JSONDecodeError,
            zipfile.BadZipFile, zlib.error) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path} is unreadable: {e}") from e
    ck_path = os.path.join(path, "checksums.json")
    if os.path.exists(ck_path):
        with open(ck_path) as f:
            want = json.load(f)
        got = _leaf_checksums(arrays)
        bad = sorted(k for k in want if got.get(k) != want[k])
        if bad or set(want) != set(got):
            raise CheckpointCorruptError(
                f"checkpoint {path} failed integrity check "
                f"(leaves {bad or sorted(set(want) ^ set(got))})")
    data = arrays

    leaves_like, treedef = _flatten(like)
    if index["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {index['n_leaves']} leaves, expected "
            f"{len(leaves_like)} — structure changed")
    new_leaves = []
    for i, ref in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
            arr = arr.astype(ref.dtype)
        new_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step


def all_steps(directory: str) -> list[int]:
    """Published checkpoint steps under ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for d in os.listdir(directory)
                  if (m := re.fullmatch(r"step_(\d+)", d)))


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return max(steps) if steps else None


def _retain(directory: str, keep: int) -> None:
    steps = sorted(int(m.group(1)) for d in os.listdir(directory)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:09d}"),
                      ignore_errors=True)


class CheckpointManager:
    """Async save + restore-latest convenience with retention."""

    def __init__(self, directory: str, keep: int = 3,
                 save_interval: int = 100):
        self.directory = directory
        self.keep = keep
        self.save_interval = save_interval
        self._thread: threading.Thread | None = None

    def maybe_save(self, step: int, tree: Any, blocking: bool = False):
        if step % self.save_interval:
            return False
        self.wait()
        # device_get on the caller thread (cheap copy), IO on the worker
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            save_checkpoint(self.directory, step, host_tree, self.keep)
        else:
            self._thread = threading.Thread(
                target=save_checkpoint,
                args=(self.directory, step, host_tree, self.keep),
                daemon=True)
            self._thread.start()
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, like, shardings=None):
        """Restore the newest INTACT checkpoint.

        A corrupt newest step (torn write that still got published,
        bit rot) falls back to the next-newest step that passes its
        integrity check, so one bad directory never bricks recovery.
        Raises the newest step's :class:`CheckpointCorruptError` only
        when every retained checkpoint is corrupt.
        """
        self.wait()
        steps = all_steps(self.directory)
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        first_err: CheckpointCorruptError | None = None
        for step in reversed(steps):
            try:
                return restore_checkpoint(self.directory, step, like,
                                          shardings)
            except CheckpointCorruptError as e:
                first_err = first_err or e
        raise first_err
