"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak: float = 1.0, warmup: int = 100,
                  total: int = 10000, floor: float = 0.1):
    """Multiplier in [floor*peak, peak]; pass as lr_scale to adamw_update."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return peak * warm * cos
