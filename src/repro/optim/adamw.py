"""AdamW with large-model memory policies.

Features used by the big configs (DESIGN.md section 5):
  * moment dtype policy (f32 default; bf16 for 480B/671B)
  * adafactor-style factored second moment for matrices (cuts v from
    O(nm) to O(n+m) — what makes 671B optimizer state fit 512 chips)
  * global-norm clipping, decoupled weight decay
  * optimizer state inherits each parameter's sharding (ZeRO by
    construction: sharded params => sharded moments)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

_F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    factored: bool = False           # factored v for >=2-D params
    factored_min_size: int = 128


def _is_factored(cfg: AdamWConfig, shape) -> bool:
    return (cfg.factored and len(shape) >= 2 and
            shape[-1] >= cfg.factored_min_size and
            shape[-2] >= cfg.factored_min_size)


def adamw_init(cfg: AdamWConfig, params):
    mdt = jnp.dtype(cfg.moment_dtype)

    def one(p):
        st = {"m": jnp.zeros(p.shape, mdt)}
        if _is_factored(cfg, p.shape):
            st["vr"] = jnp.zeros(p.shape[:-1], _F32)        # row stats
            st["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:], _F32)
        else:
            st["v"] = jnp.zeros(p.shape, mdt)
        return st

    return {"step": jnp.zeros((), jnp.int32),
            "per_param": jax.tree_util.tree_map(one, params)}


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    sf = step.astype(_F32)

    # global-norm clip
    gsq = sum(jnp.sum(jnp.square(g.astype(_F32)))
              for g in jax.tree_util.tree_leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    bc1 = 1 - cfg.b1 ** sf
    bc2 = 1 - cfg.b2 ** sf
    lr = cfg.lr * lr_scale

    def one(p, g, st):
        g = g.astype(_F32) * scale
        m = cfg.b1 * st["m"].astype(_F32) + (1 - cfg.b1) * g
        if "vr" in st:
            g2 = jnp.square(g) + 1e-30
            vr = cfg.b2 * st["vr"] + (1 - cfg.b2) * g2.mean(axis=-1)
            vc = cfg.b2 * st["vc"] + (1 - cfg.b2) * g2.mean(axis=-2)
            # rank-1 reconstruction (Adafactor)
            denom = vr[..., None] * vc[..., None, :] / jnp.maximum(
                vr.mean(axis=-1)[..., None, None], 1e-30)
            v_hat = denom / bc2
            new_st = {"m": m.astype(st["m"].dtype), "vr": vr, "vc": vc}
        else:
            v = cfg.b2 * st["v"].astype(_F32) + (1 - cfg.b2) * jnp.square(g)
            v_hat = v / bc2
            new_st = {"m": m.astype(st["m"].dtype),
                      "v": v.astype(st["v"].dtype)}
        m_hat = m / bc1
        upd = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = p.astype(_F32) - lr * (upd + decay * p.astype(_F32))
        return new_p.astype(p.dtype), new_st

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["per_param"])
    out = [one(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_per = tdef.unflatten([o[1] for o in out])
    return new_params, {"step": step, "per_param": new_per}, \
        {"grad_norm": gnorm}


def opt_shardings(param_shardings, opt_state_shape, mesh):
    """Optimizer state shardings derived from parameter shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    flat_ps, _ = jax.tree_util.tree_flatten(param_shardings)

    def per_param(psh, st):
        out = {}
        for k, leaf in st.items():
            spec = psh.spec
            if k == "vr":
                out[k] = NamedSharding(mesh, P(*spec[:-1]))
            elif k == "vc":
                out[k] = NamedSharding(mesh, P(*(spec[:-2] + spec[-1:])))
            else:
                out[k] = psh
        return out

    per = jax.tree_util.tree_map(
        per_param, param_shardings, opt_state_shape["per_param"],
        is_leaf=lambda x: isinstance(x, NamedSharding))
    return {"step": NamedSharding(mesh, P()), "per_param": per}
