"""Int8 error-feedback gradient compression for the cross-pod hop.

Distributed-optimization trick (DESIGN.md section 5): intra-pod
gradients reduce at full precision over fast ICI; the slow cross-pod
all-reduce runs on int8 with per-row scales.  Quantization error is fed
back into the next step's gradient (error-feedback / EF-SGD), which
keeps convergence intact (1-bit Adam / PowerSGD lineage).

The train driver enables this when the mesh has a 'pod' axis; tests
check the EF invariant (sum of quantized + residual == original).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_F32 = jnp.float32


def int8_compress(g, residual=None):
    """g (...) f32 -> (q int8, scale f32 rowwise, new_residual)."""
    if residual is not None:
        g = g.astype(_F32) + residual
    else:
        g = g.astype(_F32)
    flat = g.reshape(-1, g.shape[-1]) if g.ndim > 1 else g.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(_F32) * scale
    new_residual = (flat - deq).reshape(g.shape)
    return q.reshape(g.shape), scale.reshape(
        g.shape[:-1] + (1,) if g.ndim > 1 else (1, 1)), new_residual


def int8_decompress(q, scale, shape=None):
    out = q.astype(_F32) * scale
    return out if shape is None else out.reshape(shape)


def compressed_psum(x, axis_name: str, residual=None):
    """All-reduce x over ``axis_name`` in int8 with error feedback.

    Implemented as an int8 all-gather + local dequantized sum so the
    bytes on the wire (and in the dry-run HLO) really are 1/4 of an f32
    all-reduce; per-rank scales ride along (one f32 per row).
    Returns (summed f32, new_residual).
    """
    q, scale, new_res = int8_compress(x, residual)
    qs = jax.lax.all_gather(q, axis_name)       # s8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)
    summed = (qs.astype(_F32) * ss).sum(axis=0)
    return summed, new_res
