"""Synthetic genomics data for the Meraculous / k-mer benchmarks.

Generates a random genome, error-prone reads, and packed k-mers exactly
shaped like the paper's chr14 workflow: k-mer counting feeds a histogram
hash table (+ Bloom pre-filter), contig generation builds a de Bruijn
hash table keyed by k-mer with (prev_base, next_base) extensions and
walks it.

K-mers pack 2 bits/base into u32 lanes (ObjectContainer-friendly:
k<=31 -> 2 lanes).
"""

from __future__ import annotations

import dataclasses

import numpy as np

_BASES = np.array(list("ACGT"))


@dataclasses.dataclass
class GenomeSim:
    genome_len: int = 1 << 16
    read_len: int = 100
    coverage: int = 8
    error_rate: float = 0.01
    seed: int = 0

    def genome(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.integers(0, 4, self.genome_len).astype(np.uint8)

    def reads(self) -> np.ndarray:
        """(n_reads, read_len) u8 base codes with substitution errors."""
        rng = np.random.default_rng(self.seed + 1)
        g = self.genome()
        n = self.genome_len * self.coverage // self.read_len
        starts = rng.integers(0, self.genome_len - self.read_len, n)
        idx = starts[:, None] + np.arange(self.read_len)[None]
        reads = g[idx]
        errs = rng.random(reads.shape) < self.error_rate
        reads = np.where(errs, (reads + rng.integers(1, 4, reads.shape)) % 4,
                         reads).astype(np.uint8)
        return reads


def extract_kmers(seqs: np.ndarray, k: int) -> np.ndarray:
    """(N, L) base codes -> (M, k) all k-mers from every sequence."""
    n, length = seqs.shape
    m = length - k + 1
    idx = np.arange(m)[:, None] + np.arange(k)[None]
    return seqs[:, idx].reshape(n * m, k)


def pack_kmers(kmers: np.ndarray) -> np.ndarray:
    """(M, k<=31) 2-bit pack into (M, 2) u32 lanes (the key record)."""
    m, k = kmers.shape
    if k > 31:
        raise ValueError("k must be <= 31 for 2-lane packing")
    val = np.zeros((m,), np.uint64)
    for i in range(k):
        val = (val << np.uint64(2)) | kmers[:, i].astype(np.uint64)
    lo = (val & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (val >> np.uint64(32)).astype(np.uint32)
    return np.stack([hi, lo], axis=1)


def unpack_kmers(lanes: np.ndarray, k: int) -> np.ndarray:
    val = (lanes[:, 0].astype(np.uint64) << np.uint64(32)) | \
        lanes[:, 1].astype(np.uint64)
    out = np.zeros((lanes.shape[0], k), np.uint8)
    for i in range(k - 1, -1, -1):
        out[:, i] = (val & np.uint64(3)).astype(np.uint8)
        val >>= np.uint64(2)
    return out


def kmer_neighbors(lanes: np.ndarray, k: int):
    """For contig walking: the 4 possible next k-mers of each k-mer."""
    val = (lanes[:, 0].astype(np.uint64) << np.uint64(32)) | \
        lanes[:, 1].astype(np.uint64)
    mask = (np.uint64(1) << np.uint64(2 * k)) - np.uint64(1)
    out = []
    for b in range(4):
        nxt = ((val << np.uint64(2)) | np.uint64(b)) & mask
        out.append(np.stack([(nxt >> np.uint64(32)).astype(np.uint32),
                             (nxt & np.uint64(0xFFFFFFFF)).astype(np.uint32)],
                            axis=1))
    return out
