from repro.data.tokens import TokenStream, synth_batch
from repro.data.genomics import GenomeSim, extract_kmers, pack_kmers

__all__ = ["TokenStream", "synth_batch", "GenomeSim", "extract_kmers",
           "pack_kmers"]
