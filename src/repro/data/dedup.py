"""Training-data dedup/counting on the BCL containers (DESIGN.md section 3).

The k-mer counting pipeline re-skinned for LM data: documents hash to
shingle fingerprints (n-gram rolling hashes); a blocked BloomFilter
drops first-seen shingles cheaply, and a DHashMap counts repeated ones.
Documents whose shingles are mostly already-seen are near-duplicates.

Used by the data pipeline as a pre-tokenization filter; this module is
pure-container logic so it runs serial (tests) or SPMD (shard over the
corpus) unchanged.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from repro.core.backend import Backend
from repro.containers import bloom as bl
from repro.containers import hashmap as hm
from repro.kernels.ops import MODE_ADD

_U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class DedupSpec:
    ngram: int = 8
    nbits: int = 1 << 22
    table_capacity: int = 1 << 16
    dup_threshold: float = 0.5      # duplicate if > this frac seen before
    max_rounds: int = 1             # exchange carryover retry rounds.
    #                                 Dedup traffic must be lossless, so
    #                                 per-round wire capacity is sized
    #                                 ceil(m / max_rounds): rounds x cap
    #                                 always covers the batch, and R > 1
    #                                 trades extra all-to-all launches
    #                                 for 1/R the per-round wire footprint
    #                                 (the win when shingle hashing skews
    #                                 traffic onto few owner ranks)


class Deduper:
    """Stateful wrapper (host-side) over the bloom+hashmap pair."""

    def __init__(self, backend: Backend, spec: DedupSpec = DedupSpec()):
        self.backend = backend
        self.spec = spec
        kspec = {"hi": SDS((), jnp.uint32), "lo": SDS((), jnp.uint32)}
        self.bspec, self.bstate = bl.bloom_create(
            backend, spec.nbits, kspec, k=4)
        self.hspec, self.hstate = hm.hashmap_create(
            backend, spec.table_capacity, kspec, SDS((), jnp.uint32),
            block_size=64)

    def shingles(self, tokens: np.ndarray) -> dict:
        """(B, T) token ids -> rolling n-gram fingerprints (B, T-n+1)."""
        b, t = tokens.shape
        n = self.spec.ngram
        h = np.zeros((b, t - n + 1), np.uint64)
        for i in range(n):
            h = h * np.uint64(1099511628211) ^ \
                tokens[:, i:t - n + 1 + i].astype(np.uint64)
        return {"hi": jnp.asarray((h >> np.uint64(32)).astype(np.uint32)),
                "lo": jnp.asarray((h & np.uint64(0xFFFFFFFF))
                                  .astype(np.uint32))}

    def _flat_shingles(self, tokens: np.ndarray):
        sh = self.shingles(tokens)
        flat = {k: v.reshape(-1) for k, v in sh.items()}
        return flat, tokens.shape[0], sh["hi"].shape[1]

    def _cap(self, m: int) -> int:
        """Per-round wire capacity: rounds x cap >= m keeps every
        exchange lossless while R > 1 shrinks each launch R-fold."""
        return max(1, -(-m // self.spec.max_rounds))

    def _count_seen(self, flat: dict, m: int, seen, b: int, n_sh: int):
        """Shared ingest tail: count repeated shingles, rate the docs.

        Repeated shingles only — the Bloom pre-pass keeps singletons out
        of the count table, the paper's memory win.  Both the eager
        ``observe`` and the fused ``observe_and_probe`` paths must stay
        on this one implementation so their semantics cannot diverge.
        """
        self.hstate, _ = hm.insert(self.backend, self.hspec, self.hstate,
                                   flat, jnp.ones((m,), _U32),
                                   capacity=self._cap(m),
                                   valid=seen, mode=MODE_ADD, attempts=3,
                                   max_rounds=self.spec.max_rounds)
        dup_frac = np.asarray(seen).reshape(b, n_sh).mean(axis=1)
        return dup_frac, dup_frac > self.spec.dup_threshold

    def observe(self, tokens: np.ndarray):
        """Ingest a batch of documents.

        Returns (dup_frac (B,), is_duplicate (B,)) and updates the
        filter + count table.
        """
        flat, b, n_sh = self._flat_shingles(tokens)
        m = b * n_sh
        self.bstate, seen = bl.insert(self.backend, self.bspec, self.bstate,
                                      flat, capacity=self._cap(m),
                                      max_rounds=self.spec.max_rounds)
        return self._count_seen(flat, m, seen, b, n_sh)

    def observe_and_probe(self, tokens: np.ndarray, probe_tokens: np.ndarray):
        """Ingest ``tokens`` while probing ``probe_tokens`` membership.

        The bloom insert (ingest) and bloom find (probe) are fused into
        one ExchangePlan — one collective round trip for both ops, at
        exactly the sum of the two standalone ops' wire bytes (ragged
        segments, DESIGN.md section 1.5) — the contamination-check
        pattern: observe a training batch and test an eval batch
        against the filter in the same round.  The probe observes the
        filter *after* this batch's insertions (identical to the
        ``Promise.FINE`` sequential schedule).

        Returns ``(dup_frac (B,), is_duplicate (B,), probe_seen_frac
        (Bp,))``.
        """
        flat, b, n_sh = self._flat_shingles(tokens)
        flatp, bp, _ = self._flat_shingles(probe_tokens)
        m, mp = b * n_sh, flatp["hi"].shape[0]

        self.bstate, seen, probed = bl.insert_find(
            self.backend, self.bspec, self.bstate, flat, flatp,
            capacity_ins=self._cap(m), capacity_find=self._cap(mp),
            max_rounds=self.spec.max_rounds)
        dup_frac, is_dup = self._count_seen(flat, m, seen, b, n_sh)
        probe_frac = np.asarray(probed).reshape(bp, -1).mean(axis=1)
        return dup_frac, is_dup, probe_frac

    def count_of(self, tokens: np.ndarray):
        """Occurrence counts (beyond first sighting) of a doc's shingles."""
        sh = self.shingles(tokens)
        flat = {k: v.reshape(-1) for k, v in sh.items()}
        m = flat["hi"].shape[0]
        self.hstate, v, found = hm.find(self.backend, self.hspec,
                                        self.hstate, flat,
                                        capacity=self._cap(m),
                                        max_rounds=self.spec.max_rounds)
        counts = np.where(np.asarray(found), np.asarray(v) + 1, 1)
        return counts.reshape(tokens.shape[0], -1)
