"""Deterministic synthetic LM data pipeline.

Design requirements from DESIGN.md section 5 (fault tolerance):
  * the stream is a pure function of (seed, step, shard) — restart or
    elastic rescale reproduces exactly the same global batch sequence;
  * state is one integer (step), checkpointed alongside the model;
  * host-side numpy generation with per-step prefetch, zero file deps.

"Documents" are Zipf-ish token runs with markov structure so the LM
loss actually decreases (quickstart/train examples assert that).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, d: dict) -> None:
        self.step = int(d["step"])
        self.seed = int(d["seed"])

    def next_batch(self, n_shards: int = 1, shard: int = 0) -> dict:
        """Returns this shard's slice of the global batch for this step."""
        if self.global_batch % n_shards:
            raise ValueError("global_batch must divide by n_shards")
        per = self.global_batch // n_shards
        rows = [self._row(self.step, shard * per + i) for i in range(per)]
        self.step += 1
        toks = np.stack(rows)
        return {"tokens": toks,
                "loss_mask": np.ones((per, self.seq_len), np.float32)}

    def _row(self, step: int, row: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, row]))
        t = self.seq_len + 1
        out = np.empty((t,), np.int32)
        # markov-ish: each doc has a topic offset; tokens cluster near it
        pos = 0
        while pos < t:
            doc_len = int(rng.integers(64, 512))
            topic = int(rng.integers(0, max(self.vocab - 256, 1)))
            base = rng.zipf(1.5, size=doc_len).clip(1, 256) - 1
            seq = (topic + base) % self.vocab
            # first-order structure: even positions echo predecessor
            seq[1::2] = (seq[:-1:2] + 1) % self.vocab
            take = min(doc_len, t - pos)
            out[pos:pos + take] = seq[:take]
            pos += take
        return out


def synth_batch(cfg, shape, rng: np.random.Generator, batch_override=None):
    """One materialized batch matching configs/shapes.input_specs."""
    b = batch_override or shape.global_batch
    t = shape.seq_len
    d = cfg.d_model
    out = {}
    if shape.kind == "train":
        if cfg.frontend == "patch":
            n_txt = t - cfg.frontend_len
            out["tokens"] = rng.integers(0, cfg.vocab, (b, n_txt + 1),
                                         dtype=np.int32)
            out["patch_embeds"] = rng.standard_normal(
                (b, cfg.frontend_len, d), dtype=np.float32)
            out["loss_mask"] = np.ones((b, n_txt), np.float32)
        else:
            out["tokens"] = rng.integers(0, cfg.vocab, (b, t + 1),
                                         dtype=np.int32)
            out["loss_mask"] = np.ones((b, t), np.float32)
            if cfg.frontend == "frame":
                out["src_embeds"] = rng.standard_normal(
                    (b, max(t // 4, 8), d), dtype=np.float32)
    else:
        out["tokens"] = rng.integers(0, cfg.vocab, (b, t), dtype=np.int32)
        if cfg.frontend == "patch":
            out["tokens"] = out["tokens"][:, :t - cfg.frontend_len]
            out["patch_embeds"] = rng.standard_normal(
                (b, cfg.frontend_len, d), dtype=np.float32)
        if cfg.frontend == "frame":
            out["src_embeds"] = rng.standard_normal(
                (b, max(t // 4, 8), d), dtype=np.float32)
    return out
