"""Exchange transports: the physical collective layer (DESIGN.md §1.7).

The paper's portability story is a *separation*: containers are written
once against the BCL Core primitive set, and the physical data movement
is whichever backend is fastest for the machine (MPI, OpenSHMEM,
GASNet-EX, UPC++); DASH makes the same move with hierarchical teams
matched to the machine topology.  This module is that separation for
the TPU exchange engine: :mod:`repro.core.exchange` owns the *logical*
exchange — binning, ragged wire layout, carryover retry rounds,
overflow policy, requester-local send maps — and a :class:`Transport`
owns the *physical* request/reply movement.

Two transports ship:

  :class:`DenseTransport`      today's one-shot tiled all-to-all over the
                               full rank axis.  The oracle: container
                               results and the wire-format cost pins are
                               exactly the pre-transport engine's.

  :class:`HierarchicalTransport`  factors the rank axis ``P = Pr x Pc``
                               (a 2-D mesh or a virtual factorization of
                               one flat axis) and exchanges in two
                               stages: stage 1 bins items by destination
                               *column* and all-to-alls over the row
                               sub-axis; the relay re-bins by final rank
                               and stage 2 all-to-alls over the column
                               sub-axis.  Replies ride the exact inverse
                               two-hop permutation back to the original
                               send slots.  Each collective has only
                               sqrt(P)-ish peers and each hop's padded
                               capacity is sized to per-stage load, so
                               sparse/skewed destination sets stop
                               paying ``P``-wide padding.

Hierarchical wire format: each hop's row is the flow's dense row
(``L_f`` payload lanes + the meta lane) plus ONE hop lane packing
``rank << 20 | o`` where ``o`` is the item's within-(dest, flow)-bucket
rank from the ONE dense binning pass.  On the source->relay hop the
rank field is the final destination (the relay re-bins on it); the
relay rewrites it to the source rank (recovered positionally from the
stage-1 arrival block) so the owner can scatter each arrival straight
into the dense layout slot ``src * R*C_f + o`` — which is what makes
hierarchical results bit-identical to :class:`DenseTransport` whenever
the stage capacities admit every dense-admitted item (the default
sizing guarantees it).  The packing bounds the transport to
``P <= 4096`` ranks and effective capacities below ``2**20``.

Cost attribution (DESIGN.md §1.7): the hop that touches the requester
is charged under the flow's own ``op_name`` (request ``bytes_out``,
reply ``bytes_in``); the hop between relay and owner is charged under
``"<op_name>.relay"``; each physical launch records
``collectives/rounds/hops`` under the plan op (2 hops per hierarchical
launch, 1 per dense).  Per-hop re-binning passes record
``"exchange.bin"`` entries exactly like the main pass, so binning work
stays pinned.
"""

from __future__ import annotations

import abc
import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.object_container import ragged_offsets
from repro.kernels import ops as kops

_U32 = jnp.uint32
_I32 = jnp.int32

_VALID_BIT = jnp.uint32(1 << 31)

#: hop lane packing: bits [20, 32) = rank, bits [0, 20) = within-bucket rank
_HOP_SHIFT = 20
_HOP_MASK = (1 << _HOP_SHIFT) - 1
_MAX_RANKS = 1 << (32 - _HOP_SHIFT)


@dataclasses.dataclass(frozen=True)
class FlowWire:
    """Static wire description of one flow (from the ExchangePlan)."""

    capacity: int       # per-round per-(src,dst) slot count C_f
    rounds: int         # effective retry rounds R_f (already clamped)
    roww: int           # dense row words: payload lanes L_f + meta lane
    reply_lanes: int    # declared reply words per row (0 = no reply)
    n: int              # flow batch size N_f
    op_name: str

    @property
    def cap_e(self) -> int:
        """Effective capacity R_f * C_f (retry rounds concatenate)."""
        return self.rounds * self.capacity


@dataclasses.dataclass
class RequestArgs:
    """Everything a transport needs to move one committed plan's requests.

    The logical exchange state — the ONE ``multi_bin_offsets`` pass over
    composite (dest, flow) buckets — is computed by the plan and shared
    by every transport, so admission (which items ship, which drop) is
    transport-independent by construction.
    """

    specs: list[FlowWire]
    bodies: list[jax.Array]   # per flow (N_f, roww_f) u32, meta lane last
    dest: jax.Array           # (N,) i32 concatenated over flows
    flow_id: jax.Array        # (N,) i32
    offsets: jax.Array        # (N,) i32 within-(dest, flow) bucket ranks
    valid: jax.Array          # (N,) bool
    plan_op: str
    impl: str


@dataclasses.dataclass
class InFlight:
    """Handle for a split-phase request (DESIGN.md §1.9).

    ``request_start`` returns one; ``request_wait`` consumes it.  The
    window between the two calls is where callers place independent
    compute — every collective counted in ``launched`` is already in
    the traced program when start returns, so the scheduler can overlap
    it with whatever the caller traces before the wait.
    """

    launched: int   # collectives issued before start returned
    state: Any      # transport-private completion state


class Transport(abc.ABC):
    """Physical movement strategy for the exchange engine's collectives."""

    #: stable identifier ("dense" / "hier") used by config/benchmark knobs
    name: str

    def request_start(self, backend: Backend, args: RequestArgs) -> InFlight:
        """Issue the request's collectives; completion deferred to wait.

        Default: the synchronous one-shot — every launch is issued (and
        the owner segments fully materialized) before start returns, so
        :meth:`request_wait` just unwraps.  Dense keeps this default
        (its single hop leaves nothing to defer: start IS the oracle
        path); transports with dependent hops override both halves to
        leave later hops for the wait.
        """
        nrounds = max(s.rounds for s in args.specs)
        return InFlight(nrounds, self.request(backend, args))

    def request_wait(self, backend: Backend, handle: InFlight
                     ) -> tuple[list[jax.Array], jax.Array | None, Any]:
        """Complete a :meth:`request_start`; returns what request returns."""
        return handle.state

    @abc.abstractmethod
    def request(self, backend: Backend, args: RequestArgs
                ) -> tuple[list[jax.Array], jax.Array | None, Any]:
        """Move every flow's admitted items to their owners.

        Returns ``(segments, extra_dropped, ctx)``: per-flow owner-side
        segments ``(P * cap_e_f, roww_f)`` in the DENSE layout (row
        ``s * cap_e + o`` holds the rank-``o`` arrival from rank ``s``),
        an optional per-flow global count of transport-stage drops
        (``None`` when the transport can never drop beyond the dense
        admission), and an opaque context for :meth:`reply`.
        """

    @abc.abstractmethod
    def reply(self, backend: Backend, ctx: Any,
              staged: dict[int, jax.Array]) -> dict[int, jax.Array]:
        """Move owner replies back to the requesters' send slots.

        ``staged[fi]`` is ``(P * cap_e_f, R_f)`` aligned with the owner
        segment rows (already masked to valid arrivals); the result maps
        each flow to the same-shape array in the REQUESTER's dense
        send-slot layout (row ``d * cap_e + o`` answers the item this
        rank placed in that slot), which the plan resolves to batch
        positions with its local send maps.
        """


# ---------------------------------------------------------------------------
# dense: one-shot tiled all-to-all over the full rank axis
# ---------------------------------------------------------------------------

def _pad_rows(mats: list[jax.Array], wmax: int) -> jax.Array:
    """Right-pad per-flow row matrices to one (N, wmax) u32 matrix.

    The fused wire kernel (``kops.pack_rows``) takes all flows' rows in
    item order with each flow using its own first ``roww_f`` lanes; the
    pad lanes never reach the wire (the kernel masks ``lane < roww_f``).
    """
    return jnp.concatenate(
        [m if m.shape[1] == wmax else jnp.pad(m, ((0, 0), (0, wmax - m.shape[1])))
         for m in mats], axis=0).astype(_U32)


@dataclasses.dataclass
class _DenseCtx:
    specs: list[FlowWire]
    plan_op: str
    impl: str


class DenseTransport(Transport):
    """The pre-transport engine's movement, verbatim (the oracle).

    One ragged-word all-to-all per launch over all P ranks; retry round
    ``r`` is a narrower launch carrying the flows still retrying, masked
    off the ONE binning pass (DESIGN.md §1.6).  The reply is ONE inverse
    all-to-all whose tiled layout lands every reply in the requester's
    original send slot (§1.2).
    """

    name = "dense"

    def request(self, backend, args):
        specs = args.specs
        nprocs = backend.nprocs()
        nflows = len(specs)
        caps_arr = jnp.asarray([s.capacity for s in specs], _I32)
        rounds_arr = jnp.asarray([s.rounds for s in specs], _I32)
        roww_arr = jnp.asarray([s.roww for s in specs], _I32)
        nrounds = max(s.rounds for s in specs)

        # round r's all-to-all carries only the flows still retrying at
        # r, each in its own ragged word segment of this round's
        # (narrower) wire; the fused kernel turns the ONE binning pass's
        # ranks into word slots AND packs the rows in the same pass
        # (items outside the round's capacity window, and flows done
        # retrying, drop at the sentinel) — one HBM write of the wire
        # per launch (DESIGN.md §1.10)
        wmax = max(s.roww for s in specs)
        rows_all = _pad_rows(args.bodies, wmax)
        recvs, woffs_by_round = [], []
        for r in range(nrounds):
            live = [fi for fi in range(nflows) if specs[fi].rounds > r]
            starts, w_r = ragged_offsets(
                [specs[fi].capacity * specs[fi].roww for fi in live])
            woff_map = dict(zip(live, starts))
            woff_round = jnp.asarray(
                [woff_map.get(fi, 0) for fi in range(nflows)], _I32)
            send = kops.pack_rows(
                rows_all, args.dest, args.flow_id, args.offsets, args.valid,
                r, woff_round, roww_arr, caps_arr, rounds_arr, w_r,
                nprocs * w_r, impl=args.impl)
            recvs.append(backend.all_to_all(send).reshape(nprocs, w_r))
            woffs_by_round.append(woff_map)

        segments = []
        for fi, s in enumerate(specs):
            # rounds concatenate per source: owner row s*(R*C_f) + o holds
            # the rank-o arrival from rank s, exactly the single-round
            # layout at capacity R*C_f; the flow's word segment reshapes
            # straight to its own (rows, L_f+1) width
            parts = [recvs[r][:, woffs_by_round[r][fi]:
                              woffs_by_round[r][fi] + s.capacity * s.roww]
                     .reshape(nprocs, s.capacity, s.roww)
                     for r in range(s.rounds)]
            segments.append(jnp.stack(parts, axis=1)
                            .reshape(nprocs * s.cap_e, s.roww))

        # cost attribution: per-flow wire segments are ragged, so each
        # flow's bytes are EXACT — its own capacity x its own row width,
        # equal to the single-flow route() cost; the physical collective,
        # its round, and its single hop once per launch, under the plan's
        # op name — retry launches land under "<op>.retry" so skew
        # tolerance is priced separately from the base round
        for s in specs:
            fb = nprocs * s.capacity * s.roww * 4
            costs.record(s.op_name, costs.Cost(bytes_moved=fb, bytes_out=fb))
            if s.rounds > 1:
                rb = fb * (s.rounds - 1)
                costs.record(f"{s.op_name}.retry",
                             costs.Cost(bytes_moved=rb, bytes_out=rb))
        costs.record(args.plan_op, costs.Cost(collectives=1, rounds=1,
                                              hops=1))
        for _ in range(nrounds - 1):
            costs.record(f"{args.plan_op}.retry",
                         costs.Cost(collectives=1, rounds=1, hops=1))
        return segments, None, _DenseCtx(specs, args.plan_op, args.impl)

    def reply(self, backend, ctx, staged):
        specs = ctx.specs
        nprocs = backend.nprocs()
        replying = sorted(staged)
        rls = {fi: staged[fi].shape[1] for fi in replying}
        # ragged reply wire: only replying flows get a word segment,
        # exactly R_f words per row, spanning the EFFECTIVE capacity so
        # the single inverse all-to-all answers every round's arrivals
        starts, wtot = ragged_offsets(
            [specs[fi].cap_e * rls[fi] for fi in replying])
        seg_off = dict(zip(replying, starts))

        send = jnp.zeros((nprocs * wtot,), _U32)
        for fi in replying:
            cap = specs[fi].cap_e
            rl = rls[fi]
            # owner arrival row s*C_f + j  ->  words
            # [s*wtot + seg_f + j*R_f, ... + R_f) — the flow's own ragged
            # segment, exactly R_f words per reply
            ar = jnp.arange(nprocs * cap, dtype=_I32)
            base = (ar // cap) * wtot + seg_off[fi] + (ar % cap) * rl
            send = kops.place_rows(send, base, staged[fi], impl=ctx.impl)

        back2 = backend.all_to_all(send).reshape(nprocs, wtot)

        # the inverse all-to-all lands flow f's replies in its own word
        # segment of each source block; slicing the segment recovers the
        # flow-local send-slot layout
        outs = {}
        for fi in replying:
            cap = specs[fi].cap_e
            rl = rls[fi]
            seg = back2[:, seg_off[fi]:seg_off[fi] + cap * rl]
            outs[fi] = seg.reshape(nprocs * cap, rl)
            fb = nprocs * cap * rl * 4
            costs.record(specs[fi].op_name,
                         costs.Cost(bytes_moved=fb, bytes_in=fb))
        costs.record(ctx.plan_op, costs.Cost(collectives=1, rounds=1,
                                             hops=1))
        return outs


# ---------------------------------------------------------------------------
# hierarchical: two-stage exchange over a Pr x Pc factorization
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _HierRound:
    """Per-launch inverse-permutation state retained for the reply."""

    live: list[int]
    # source side, per flow: (stage-1 send row index (N_f,), dense
    # requester slot (N_f,)); sentinels past-the-end drop
    src: dict[int, tuple[jax.Array, jax.Array]]
    # relay side, per flow: stage-2 send row index per stage-1 arrival
    rel: dict[int, jax.Array]
    # owner side, per flow: dense owner slot per stage-2 arrival
    own: dict[int, jax.Array]


@dataclasses.dataclass
class _HierCtx:
    specs: list[FlowWire]
    plan_op: str
    impl: str
    pr: int
    pc: int
    c1: list[int]
    c2: list[int]
    row_groups: tuple
    col_groups: tuple
    rounds: list[_HierRound]


@dataclasses.dataclass
class _HierPre:
    """Launch-invariant state shared by every round's two stages."""

    args: RequestArgs
    pr: int
    pc: int
    row_groups: tuple
    col_groups: tuple
    myrow: jax.Array
    caps_arr: jax.Array
    rounds_arr: jax.Array
    w1: list[int]
    w1_arr: jax.Array
    c1: list[int]
    c2: list[int]
    c1_arr: jax.Array
    c2_arr: jax.Array
    nrounds: int
    destcol: jax.Array
    hop1: jax.Array
    rows1: jax.Array   # (N, max w1) right-padded stage-1 rows, hop lane last


@dataclasses.dataclass
class _Stage1Out:
    """One round's source->relay hop, awaiting its relay->owner hop."""

    live: list[int]
    woff1_map: dict[int, int]
    recv1: jax.Array
    src: dict[int, tuple[jax.Array, jax.Array]]
    extra: jax.Array


@dataclasses.dataclass
class _RoundOut:
    """One completed round: inverse-permutation state + owner scatters."""

    rnd: _HierRound
    scatters: dict[int, tuple[jax.Array, jax.Array]]  # fi -> (dslot, rows)
    extra: jax.Array


class HierarchicalTransport(Transport):
    """Two-stage all-to-all over the factored rank axis ``P = Pr x Pc``.

    Rank ``r`` sits at mesh coordinate ``(r // Pc, r % Pc)``.  Stage 1
    bins each item by its destination's COLUMN and all-to-alls over the
    row sub-axis (Pc peers): item for rank ``(i', j')`` moves from
    ``(i, j)`` to the relay ``(i, j')``.  The relay re-bins arrivals by
    destination ROW and stage 2 all-to-alls over the column sub-axis
    (Pr peers), landing everything at ``(i', j')``.  Per-stage padded
    capacities are per *flow*:

      stage 1 (per (src, dest-column) bucket)  default min(Pr*C_f, N_f)
      stage 2 (per (relay, dest-rank) bucket)  default Pc*min(C_f, N_f)

    The defaults are the worst-case bounds of dense-admitted traffic,
    so results are bit-identical to :class:`DenseTransport` out of the
    box.  Callers with sparse/skewed destination knowledge size them
    down via ``stage_caps={op_name: (c1, c2)}`` — that is where the
    sqrt(P)-peers wire saving comes from — at the price of counted
    stage drops if the hint under-provisions (the dense admission's
    send maps still mark such items as shipped, so size stage caps to
    load, like ``capacity`` itself).

    ``pr``/``pc`` pin the factorization (e.g. to match a physical 2-D
    mesh); by default ``P`` is factored as close to square as possible.
    """

    name = "hier"

    def __init__(self, pr: int | None = None, pc: int | None = None,
                 stage_caps: dict[str, tuple[int, int]] | None = None):
        self.pr = pr
        self.pc = pc
        self.stage_caps = dict(stage_caps or {})

    def _factor(self, nprocs: int) -> tuple[int, int]:
        pr, pc = self.pr, self.pc
        if pr is None and pc is None:
            pr = int(math.isqrt(nprocs))
            while nprocs % pr:
                pr -= 1
        elif pr is None:
            pr = nprocs // int(pc)
        pr = int(pr)
        pc = nprocs // pr if pc is None else int(pc)
        if pr < 1 or pc < 1 or pr * pc != nprocs:
            raise ValueError(
                f"HierarchicalTransport: {pr} x {pc} does not factor the "
                f"{nprocs}-rank axis")
        return pr, pc

    def _stage_caps(self, s: FlowWire, pr: int, pc: int) -> tuple[int, int]:
        if s.op_name in self.stage_caps:
            c1, c2 = self.stage_caps[s.op_name]
            return int(c1), int(c2)
        # worst-case bounds of dense-admitted traffic in ONE launch: a
        # source ships <= min(C_f, N_f) to each of a column's Pr ranks;
        # a relay forwards <= min(C_f, N_f) per (row source, dest rank)
        return (min(pr * s.capacity, s.n), pc * min(s.capacity, s.n))

    def _pre(self, backend, args):
        """Validate, factor the axis, and derive launch-invariant state."""
        specs = args.specs
        nprocs = backend.nprocs()
        pr, pc = self._factor(nprocs)
        if nprocs > _MAX_RANKS:
            raise ValueError(
                f"HierarchicalTransport hop lane packs rank<<{_HOP_SHIFT}: "
                f"{nprocs} ranks exceeds the {_MAX_RANKS} bound")
        for s in specs:
            if s.cap_e > _HOP_MASK:
                raise ValueError(
                    f"flow '{s.op_name}': effective capacity {s.cap_e} "
                    f"exceeds the hop lane's {_HOP_MASK} bound")
        row_groups = tuple(tuple(i * pc + j for j in range(pc))
                           for i in range(pr))
        col_groups = tuple(tuple(i * pc + j for i in range(pr))
                           for j in range(pc))
        myrow = backend.rank() // pc

        caps_arr = jnp.asarray([s.capacity for s in specs], _I32)
        rounds_arr = jnp.asarray([s.rounds for s in specs], _I32)
        w1 = [s.roww + 1 for s in specs]          # + hop lane
        w1_arr = jnp.asarray(w1, _I32)
        c1 = [self._stage_caps(s, pr, pc)[0] for s in specs]
        c2 = [self._stage_caps(s, pr, pc)[1] for s in specs]
        nrounds = max(s.rounds for s in specs)

        destcol = (args.dest % pc).astype(_I32)
        # hop lane, source->relay: final dest rank | dense bucket rank o
        hop1 = ((args.dest.astype(_U32) << _HOP_SHIFT)
                | (args.offsets.astype(_U32) & _U32(_HOP_MASK)))
        # stage-1 rows (body + hop lane), launch-invariant: every round
        # packs a window of the same matrix through the fused kernel
        row0, w1max = 0, max(w1)
        mats = []
        for fi, s in enumerate(specs):
            mats.append(jnp.concatenate(
                [args.bodies[fi],
                 hop1[row0:row0 + s.n].astype(_U32)[:, None]], axis=1))
            row0 += s.n
        rows1 = _pad_rows(mats, w1max)
        return _HierPre(args, pr, pc, row_groups, col_groups, myrow,
                        caps_arr, rounds_arr, w1, w1_arr, c1, c2,
                        jnp.asarray(c1, _I32), jnp.asarray(c2, _I32),
                        nrounds, destcol, hop1, rows1)

    def _stage1(self, backend, pre, r):
        """Round r's source->relay hop: bin by dest column, row a2a."""
        args, specs = pre.args, pre.args.specs
        nflows = len(specs)
        pc, w1, c1 = pre.pc, pre.w1, pre.c1
        live = [fi for fi in range(nflows) if specs[fi].rounds > r]
        live_arr = jnp.asarray(
            [1 if specs[fi].rounds > r else 0 for fi in range(nflows)],
            _I32)
        # this launch ships exactly the dense round-r window — the
        # same items DenseTransport's round r ships
        fl = args.flow_id
        in_round = (args.valid & (pre.rounds_arr[fl] > r)
                    & (args.offsets >= r * pre.caps_arr[fl])
                    & (args.offsets < (r + 1) * pre.caps_arr[fl]))

        costs.record("exchange.bin",
                     costs.Cost(local=int(args.dest.shape[0])))
        cnt1, off1 = kops.multi_bin_offsets(pre.destcol, fl, pc, nflows,
                                            in_round, impl=args.impl)
        starts1, w1r = ragged_offsets([c1[fi] * w1[fi] for fi in live])
        woff1_map = dict(zip(live, starts1))
        woff1 = jnp.asarray(
            [woff1_map.get(fi, 0) for fi in range(nflows)], _I32)
        # fused wire pack: the stage form is the round-0 window with the
        # per-flow live mask as "rounds" (kops.stage_slots's contract)
        send1 = kops.pack_rows(pre.rows1, pre.destcol, fl, off1, in_round,
                               0, woff1, pre.w1_arr, pre.c1_arr, live_arr,
                               w1r, pc * w1r, impl=args.impl)
        src_state = {}
        row0 = 0
        nprocs = backend.nprocs()
        for fi, s in enumerate(specs):
            sl = slice(row0, row0 + s.n)
            if s.rounds > r:
                ship1 = in_round[sl] & (off1[sl] < c1[fi])
                r1 = jnp.where(ship1, pre.destcol[sl] * c1[fi] + off1[sl],
                               pc * c1[fi]).astype(_I32)
                dslot = jnp.where(
                    ship1, args.dest[sl] * s.cap_e + args.offsets[sl],
                    nprocs * s.cap_e).astype(_I32)
                src_state[fi] = (r1, dslot)
            row0 += s.n
        extra = jnp.maximum(cnt1 - pre.c1_arr[None, :], 0).sum(0)
        recv1 = backend.all_to_all(send1, groups=pre.row_groups) \
            .reshape(pc, w1r)
        return _Stage1Out(live, woff1_map, recv1, src_state, extra)

    def _stage2(self, backend, pre, s1):
        """One round's relay re-bin + relay->owner hop + owner scatter."""
        args, specs = pre.args, pre.args.specs
        nflows = len(specs)
        pr, pc, w1, c1, c2 = pre.pr, pre.pc, pre.w1, pre.c1, pre.c2
        live, woff1_map, recv1 = s1.live, s1.woff1_map, s1.recv1
        nprocs = backend.nprocs()

        # ---- relay: recover source positionally, re-bin by row ----
        rel_bins, rel_flow, rel_valid, rel_rows = [], [], [], []
        for fi in live:
            s = specs[fi]
            seg = recv1[:, woff1_map[fi]:
                        woff1_map[fi] + c1[fi] * w1[fi]] \
                .reshape(pc * c1[fi], w1[fi])
            meta = seg[:, s.roww - 1]
            hop = seg[:, s.roww]
            rv = (meta & _VALID_BIT) != 0
            dst = (hop >> _HOP_SHIFT).astype(_I32)
            o = (hop & _U32(_HOP_MASK))
            # stage-1 arrival block index IS the source's column
            src_col = jnp.arange(pc * c1[fi], dtype=_I32) // c1[fi]
            src = (pre.myrow * pc + src_col).astype(_U32)
            hop2 = (src << _HOP_SHIFT) | o
            rel_rows.append(jnp.concatenate(
                [seg[:, :s.roww], hop2[:, None]], axis=1))
            rel_bins.append(jnp.where(rv, dst // pc, 0))
            rel_flow.append(jnp.full((pc * c1[fi],), fi, _I32))
            rel_valid.append(rv)
        rbins = jnp.concatenate(rel_bins)
        rflow = jnp.concatenate(rel_flow)
        rvalid = jnp.concatenate(rel_valid)

        # ---- stage 2: bin by destination row, column all-to-all ----
        costs.record("exchange.bin",
                     costs.Cost(local=int(rbins.shape[0])))
        cnt2, off2 = kops.multi_bin_offsets(rbins, rflow, pr, nflows,
                                            rvalid, impl=args.impl)
        live_arr = jnp.asarray(
            [1 if fi in live else 0 for fi in range(nflows)], _I32)
        starts2, w2r = ragged_offsets([c2[fi] * w1[fi] for fi in live])
        woff2_map = dict(zip(live, starts2))
        woff2 = jnp.asarray(
            [woff2_map.get(fi, 0) for fi in range(nflows)], _I32)
        send2 = kops.pack_rows(
            _pad_rows(rel_rows, max(w1[fi] for fi in live)), rbins, rflow,
            off2, rvalid, 0, woff2, pre.w1_arr, pre.c2_arr, live_arr, w2r,
            pr * w2r, impl=args.impl)
        rel_state = {}
        m0 = 0
        for fi in live:
            mfi = pc * c1[fi]
            sl = slice(m0, m0 + mfi)
            ship2 = rvalid[sl] & (off2[sl] < c2[fi])
            rel_state[fi] = jnp.where(
                ship2, rbins[sl] * c2[fi] + off2[sl],
                pr * c2[fi]).astype(_I32)
            m0 += mfi
        extra = s1.extra + jnp.maximum(cnt2 - pre.c2_arr[None, :], 0).sum(0)
        recv2 = backend.all_to_all(send2, groups=pre.col_groups) \
            .reshape(pr, w2r)

        # ---- owner: recover dense slots for the scatter ----
        own_state = {}
        scatters = {}
        for fi in live:
            s = specs[fi]
            seg2 = recv2[:, woff2_map[fi]:
                         woff2_map[fi] + c2[fi] * w1[fi]] \
                .reshape(pr * c2[fi], w1[fi])
            meta2 = seg2[:, s.roww - 1]
            hop2v = seg2[:, s.roww]
            v2 = (meta2 & _VALID_BIT) != 0
            src2 = (hop2v >> _HOP_SHIFT).astype(_I32)
            o2 = (hop2v & _U32(_HOP_MASK)).astype(_I32)
            dslot = jnp.where(v2, src2 * s.cap_e + o2,
                              nprocs * s.cap_e).astype(_I32)
            scatters[fi] = (dslot, seg2[:, :s.roww])
            own_state[fi] = dslot
        return _RoundOut(_HierRound(live, s1.src, rel_state, own_state),
                         scatters, extra)

    def _assemble(self, backend, pre, rounds):
        """Fold completed rounds into owner segments + cost records."""
        args, specs = pre.args, pre.args.specs
        nflows = len(specs)
        pr, pc, w1, c1, c2 = pre.pr, pre.pc, pre.w1, pre.c1, pre.c2
        nprocs = backend.nprocs()

        seg_out = [jnp.zeros((nprocs * s.cap_e, s.roww), _U32)
                   for s in specs]
        extra = jnp.zeros((nflows,), _I32)
        for out in rounds:
            for fi, (dslot, rows) in out.scatters.items():
                # dense-slot owner scatter through the in-kernel placer:
                # word slot = row slot * row width, sentinel rows (dslot
                # == P * cap_e) land exactly at the buffer size and drop
                s = specs[fi]
                seg_out[fi] = kops.place_rows(
                    seg_out[fi].reshape(-1), dslot * s.roww, rows,
                    impl=args.impl).reshape(nprocs * s.cap_e, s.roww)
            extra = extra + out.extra

        # cost attribution: the requester-side hop under the flow's own
        # op (retry launches under "<op>.retry"); ALL relay->owner hop
        # bytes (every launch) under "<op>.relay"; each launch is 2
        # collectives / 2 dependent rounds / 2 hops under the plan op
        for fi, s in enumerate(specs):
            b1 = pc * c1[fi] * w1[fi] * 4
            b2 = pr * c2[fi] * w1[fi] * 4
            costs.record(s.op_name, costs.Cost(bytes_moved=b1, bytes_out=b1))
            if s.rounds > 1:
                rb = b1 * (s.rounds - 1)
                costs.record(f"{s.op_name}.retry",
                             costs.Cost(bytes_moved=rb, bytes_out=rb))
            rel = b2 * s.rounds
            costs.record(f"{s.op_name}.relay",
                         costs.Cost(bytes_moved=rel, bytes_out=rel))
        costs.record(args.plan_op, costs.Cost(collectives=2, rounds=2,
                                              hops=2))
        for _ in range(pre.nrounds - 1):
            costs.record(f"{args.plan_op}.retry",
                         costs.Cost(collectives=2, rounds=2, hops=2))

        dropped = backend.psum(extra).astype(_I32)
        ctx = _HierCtx(specs, args.plan_op, args.impl, pr, pc, c1, c2,
                       pre.row_groups, pre.col_groups,
                       [out.rnd for out in rounds])
        return seg_out, dropped, ctx

    def request(self, backend, args):
        # synchronous path: the stages interleave per round, exactly the
        # pre-split launch order [s1_r0, s2_r0, s1_r1, s2_r1, ...] — the
        # fault-injection launch numbering and every cost pin depend on
        # this ordering staying put
        pre = self._pre(backend, args)
        rounds = [self._stage2(backend, pre, self._stage1(backend, pre, r))
                  for r in range(pre.nrounds)]
        return self._assemble(backend, pre, rounds)

    def request_start(self, backend, args):
        # split-phase: issue EVERY round's source->relay hop up front
        # (the hops are mutually independent — each ships its own dense
        # round window), deferring relays, owner hops, and scatters to
        # the wait.  Launch order becomes [s1_r0 .. s1_rk, s2_r0 ..],
        # overlapping the two hops across the caller's window.
        pre = self._pre(backend, args)
        s1s = [self._stage1(backend, pre, r) for r in range(pre.nrounds)]
        return InFlight(pre.nrounds, (pre, s1s))

    def request_wait(self, backend, handle):
        pre, s1s = handle.state
        rounds = [self._stage2(backend, pre, s1) for s1 in s1s]
        return self._assemble(backend, pre, rounds)

    def reply(self, backend, ctx, staged):
        specs = ctx.specs
        nprocs = backend.nprocs()
        pr, pc, c1, c2 = ctx.pr, ctx.pc, ctx.c1, ctx.c2
        rls = {fi: staged[fi].shape[1] for fi in staged}

        # ---- inverse stage 2: owner -> relay, ONE collective covering
        # every launch (per-launch blocks concatenate along words) ----
        blocks2, layout = [], []
        for rnd in ctx.rounds:
            rf = [fi for fi in rnd.live if fi in staged]
            parts = []
            for fi in rf:
                s = specs[fi]
                dslot = rnd.own[fi]                    # (pr*c2,) sentinel
                in_r = dslot < nprocs * s.cap_e
                rows = jnp.where(
                    in_r[:, None],
                    staged[fi][jnp.minimum(dslot, nprocs * s.cap_e - 1)], 0)
                parts.append(rows.reshape(pr, c2[fi] * rls[fi]))
            layout.append(rf)
            blocks2.append(jnp.concatenate(parts, axis=1) if parts
                           else jnp.zeros((pr, 0), _U32))
        send2 = jnp.concatenate(blocks2, axis=1)
        wtot2 = send2.shape[1]
        back2 = backend.all_to_all(send2.reshape(-1), groups=ctx.col_groups) \
            .reshape(pr, wtot2)

        # ---- inverse stage 1: relay -> source, ONE collective ----
        blocks1 = []
        woff = 0
        for rnd, rf in zip(ctx.rounds, layout):
            parts = []
            for fi in rf:
                rl = rls[fi]
                rep2 = back2[:, woff:woff + c2[fi] * rl] \
                    .reshape(pr * c2[fi], rl)
                woff += c2[fi] * rl
                r2 = rnd.rel[fi]                       # (pc*c1,) sentinel
                in_r = r2 < pr * c2[fi]
                rows = jnp.where(
                    in_r[:, None],
                    rep2[jnp.minimum(r2, pr * c2[fi] - 1)], 0)
                parts.append(rows.reshape(pc, c1[fi] * rl))
            blocks1.append(jnp.concatenate(parts, axis=1) if parts
                           else jnp.zeros((pc, 0), _U32))
        send1 = jnp.concatenate(blocks1, axis=1)
        wtot1 = send1.shape[1]
        back1 = backend.all_to_all(send1.reshape(-1), groups=ctx.row_groups) \
            .reshape(pc, wtot1)

        # ---- source: land replies in the dense send-slot layout ----
        outs = {fi: jnp.zeros((nprocs * specs[fi].cap_e, rls[fi]), _U32)
                for fi in staged}
        woff = 0
        for rnd, rf in zip(ctx.rounds, layout):
            for fi in rf:
                s = specs[fi]
                rl = rls[fi]
                rep1 = back1[:, woff:woff + c1[fi] * rl] \
                    .reshape(pc * c1[fi], rl)
                woff += c1[fi] * rl
                r1, dslot = rnd.src[fi]
                in_r = r1 < pc * c1[fi]
                rows = jnp.where(
                    in_r[:, None],
                    rep1[jnp.minimum(r1, pc * c1[fi] - 1)], 0)
                outs[fi] = kops.place_rows(
                    outs[fi].reshape(-1), dslot * rl, rows,
                    impl=ctx.impl).reshape(nprocs * s.cap_e, rl)

        for fi in sorted(staged):
            s = specs[fi]
            b1 = pc * c1[fi] * rls[fi] * 4 * s.rounds
            b2 = pr * c2[fi] * rls[fi] * 4 * s.rounds
            costs.record(s.op_name, costs.Cost(bytes_moved=b1, bytes_in=b1))
            costs.record(f"{s.op_name}.relay",
                         costs.Cost(bytes_moved=b2, bytes_in=b2))
        costs.record(ctx.plan_op, costs.Cost(collectives=2, rounds=2,
                                             hops=2))
        return outs


#: process-wide default transport: unchanged programs compile unchanged
DENSE = DenseTransport()


def make_transport(name: str | Transport | None,
                   pr: int | None = None,
                   pc: int | None = None) -> Transport:
    """Transport factory for config/benchmark knobs.

    ``None``/``"dense"`` return the shared :data:`DENSE` singleton;
    ``"hier"`` builds a :class:`HierarchicalTransport` (optionally with
    a pinned ``pr x pc`` factorization); an existing transport passes
    through — the "user-injected backend" path.
    """
    if name is None:
        return DENSE
    if isinstance(name, Transport):
        return name
    if name == "dense":
        return DENSE
    if name == "hier":
        return HierarchicalTransport(pr, pc)
    raise ValueError(f"unknown transport {name!r} (want 'dense' or 'hier')")
