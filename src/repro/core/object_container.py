"""BCL ObjectContainers (paper section 6): transparent, low-overhead
serialization of complex element types into distributed memory.

The C++ original stores elements as fixed-size byte-copyable containers,
using compile-time type introspection to (a) skip serialization entirely
for trivially-copyable types ("copy elision") and (b) spill variable-
length serializations behind a global pointer (``BCL::serial_ptr``).

The JAX port stores elements as fixed-width **u32 lane matrices**
``(N, L)`` — the unit every container and the exchange engine moves.
Trace-time dtype introspection plays the role of C++ template
introspection:

  * a single 32-bit array packs via one ``bitcast_convert_type`` — a
    layout no-op for XLA, i.e. genuine copy elision;
  * a struct (dict of fields) packs each field to u32 lanes and
    concatenates; widths are static so everything unrolls;
  * variable-length payloads pack as a 3-lane ``SerialPtr`` record
    (rank, offset, length) pointing into a heap container
    (``repro.containers.heap``), mirroring ``BCL::serial_ptr``.

Users with custom types subclass :class:`Packer` — the analogue of
injecting a serialization struct into the BCL namespace.
"""

from __future__ import annotations

import abc
from typing import Any

import jax
import jax.numpy as jnp
from jax import ShapeDtypeStruct

_U32 = jnp.uint32


def _lanes_for_dtype(dtype) -> int:
    """u32 lanes needed per scalar of ``dtype``."""
    size = jnp.dtype(dtype).itemsize
    if size <= 4:
        return 1
    if size == 8:
        return 2
    raise TypeError(f"unsupported element dtype {dtype}")


def _to_u32(x: jax.Array) -> jax.Array:
    """Bitcast any <=32-bit array (N,) or (N, d) to u32 lanes (N, d')."""
    if x.ndim == 1:
        x = x[:, None]
    dt = x.dtype
    if dt == jnp.uint32:
        return x
    if dt.itemsize == 4:
        return jax.lax.bitcast_convert_type(x, _U32)
    if dt.itemsize == 2:
        return jax.lax.bitcast_convert_type(x, jnp.uint16).astype(_U32)
    if dt.itemsize == 1:
        return jax.lax.bitcast_convert_type(x, jnp.uint8).astype(_U32)
    raise TypeError(f"unsupported dtype {dt}")


def _from_u32(lanes: jax.Array, dtype, inner: int) -> jax.Array:
    """Invert :func:`_to_u32` back to ``dtype`` with trailing dim ``inner``."""
    dt = jnp.dtype(dtype)
    if dt.itemsize == 4:
        out = jax.lax.bitcast_convert_type(lanes, dt)
    elif dt.itemsize == 2:
        out = jax.lax.bitcast_convert_type(lanes.astype(jnp.uint16), dt)
    elif dt.itemsize == 1:
        out = jax.lax.bitcast_convert_type(lanes.astype(jnp.uint8), dt)
    else:
        raise TypeError(f"unsupported dtype {dt}")
    if inner == 0:
        return out[:, 0]
    return out


def ragged_offsets(widths) -> tuple[list[int], int]:
    """Word offsets of back-to-back ragged segments.

    The exchange engine's fused wire is a flat u32 word buffer per
    destination in which flow ``f`` owns a contiguous segment of
    ``C_f * widths[f]`` words (DESIGN.md section 1.5) — the serialized
    analogue of this module's lane matrices, with no cross-flow padding.
    Returns ``(starts, total)`` where ``starts[f]`` is the first word of
    segment ``f`` and ``total`` is the words per destination block.
    Packing goes through :func:`scatter_rows`; unpacking is free — a
    segment's rows are contiguous, so every owner view is a slice plus
    reshape, never a gather.
    """
    starts, off = [], 0
    for w in widths:
        starts.append(off)
        off += int(w)
    return starts, off


def scatter_rows(flat: jax.Array, base: jax.Array, rows: jax.Array,
                 widths: jax.Array | None = None) -> jax.Array:
    """Pack (N, W) u32 rows into a flat word buffer at per-row offsets.

    Row ``i`` lands at words ``[base[i], base[i] + W)``; a sentinel
    ``base[i] >= flat.size`` drops the row.  This is the ragged wire's
    serializer and the declared fallback/oracle for the fused Pallas
    wire (``kernels/ops.pack_rows`` — DESIGN.md section 1.10): the hot
    path packs in-kernel, this XLA scatter stays as the jnp reference.

    With ``widths`` (per-row word counts <= W), lanes past ``widths[i]``
    are dropped — one rectangular call packs right-padded rows of mixed
    flow widths bit-identically to per-flow calls on disjoint slots.
    """
    w = rows.shape[1]
    lane = jnp.arange(w, dtype=base.dtype)[None, :]
    idx = base[:, None] + lane
    if widths is not None:
        idx = jnp.where(lane < widths[:, None].astype(base.dtype), idx,
                        flat.shape[0])
    return flat.at[idx].set(rows.astype(_U32), mode="drop")


class Packer(abc.ABC):
    """Serialize a record pytree <-> a fixed-width u32 lane matrix."""

    #: static number of u32 lanes per element
    lanes: int

    @abc.abstractmethod
    def pack(self, value: Any) -> jax.Array:
        """(pytree of (N,...) arrays) -> (N, lanes) u32."""

    @abc.abstractmethod
    def unpack(self, mat: jax.Array) -> Any:
        """(N, lanes) u32 -> pytree of (N, ...) arrays."""

    def example(self, n: int) -> Any:
        """Zero-filled example value with batch size n (testing aid)."""
        return self.unpack(jnp.zeros((n, self.lanes), _U32))


class IdentityPacker(Packer):
    """Copy-elision fast path: a single 32-bit field, packed by bitcast.

    Mirrors ``BCL::identity_serialize<T>``: XLA lowers the bitcast to a
    view change, so no copy is materialized.
    """

    def __init__(self, dtype, inner: int = 0):
        self.dtype = jnp.dtype(dtype)
        self.inner = inner  # 0 => scalar field (N,), else (N, inner)
        if self.dtype.itemsize != 4:
            raise TypeError("IdentityPacker requires a 32-bit dtype")
        self.lanes = max(inner, 1)

    def pack(self, value: jax.Array) -> jax.Array:
        return _to_u32(value)

    def unpack(self, mat: jax.Array) -> jax.Array:
        return _from_u32(mat, self.dtype, self.inner)


class StructPacker(Packer):
    """Fixed-size struct: dict of named fields, each <=32-bit scalar/vector."""

    def __init__(self, fields: dict[str, ShapeDtypeStruct]):
        # fields: name -> ShapeDtypeStruct with shape () or (inner,) per element
        self.fields = dict(sorted(fields.items()))
        self.layout: list[tuple[str, Any, int, int]] = []  # name,dtype,inner,lanes
        off = 0
        for name, sds in self.fields.items():
            if len(sds.shape) > 1:
                raise TypeError(f"field {name}: per-element shape must be scalar/vector")
            inner = sds.shape[0] if sds.shape else 0
            width = max(inner, 1) * _lanes_for_dtype(sds.dtype)
            if jnp.dtype(sds.dtype).itemsize == 8:
                raise TypeError(
                    f"field {name}: 64-bit fields unsupported without x64; "
                    "split into two u32 fields")
            self.layout.append((name, sds.dtype, inner, width))
            off += width
        self.lanes = off

    def pack(self, value: dict[str, jax.Array]) -> jax.Array:
        cols = []
        for name, _dtype, _inner, _width in self.layout:
            cols.append(_to_u32(value[name]))
        return jnp.concatenate(cols, axis=1)

    def unpack(self, mat: jax.Array) -> dict[str, jax.Array]:
        out = {}
        off = 0
        for name, dtype, inner, width in self.layout:
            out[name] = _from_u32(mat[:, off:off + width], dtype, inner)
            off += width
        return out


class SerialPtrPacker(Packer):
    """Variable-length indirection record: (rank, offset, length).

    The payload bytes live in a heap container; this record is what gets
    stored inside hash tables / queues — the ``BCL::serial_ptr`` path.
    """

    lanes = 3

    def pack(self, value: dict[str, jax.Array]) -> jax.Array:
        return jnp.stack(
            [value["rank"].astype(_U32), value["offset"].astype(_U32),
             value["length"].astype(_U32)], axis=1)

    def unpack(self, mat: jax.Array) -> dict[str, jax.Array]:
        return {"rank": mat[:, 0].astype(jnp.int32),
                "offset": mat[:, 1].astype(jnp.int32),
                "length": mat[:, 2].astype(jnp.int32)}


def packer_for(spec: Any) -> Packer:
    """Trace-time type introspection: pick the cheapest packer for ``spec``.

    ``spec`` is a ShapeDtypeStruct (single field), a dict of them
    (struct), an int (u32 vector of that many lanes), or an existing
    Packer (passed through, the "user-injected serializer" path).
    """
    if isinstance(spec, Packer):
        return spec
    if isinstance(spec, int):
        return IdentityPacker(_U32, inner=spec if spec > 1 else 0)
    if isinstance(spec, ShapeDtypeStruct):
        inner = spec.shape[0] if spec.shape else 0
        if jnp.dtype(spec.dtype).itemsize == 4:
            return IdentityPacker(spec.dtype, inner)
        return StructPacker({"value": spec})
    if isinstance(spec, dict):
        return StructPacker(spec)
    if isinstance(spec, jax.Array) or hasattr(spec, "dtype"):
        inner = spec.shape[1] if spec.ndim > 1 else 0
        return packer_for(ShapeDtypeStruct((inner,) if inner else (), spec.dtype))
    raise TypeError(f"cannot derive a Packer for {spec!r}")


def u64_from_u32_pair(hi: jax.Array, lo: jax.Array) -> dict[str, jax.Array]:
    """Convenience for 64-bit keys stored as two u32 lanes."""
    return {"hi": hi.astype(_U32), "lo": lo.astype(_U32)}
