"""Concurrency promises (paper section 7).

A concurrency promise is a callsite annotation listing which data-structure
operations may execute concurrently with the one being issued.  The promise
lets a container statically select a cheaper implementation with weaker
atomicity guarantees (paper Tables 3 and 4).

In the C++ original the promise chooses between AMO-heavy and AMO-free code
paths at template-instantiation time.  Here the promise is a Python-level
(trace-time) constant, so it selects between different *collective
schedules and kernels* at jit-trace time — same mechanism, same zero
runtime cost.

Promise algebra: promises are bitflags and combine with ``|`` exactly as in
the paper (``ConProm.HashMap.find | ConProm.HashMap.insert``).

Promise -> schedule (DESIGN.md section 1.5): promises tell the runtime
which ops may share a collective round.  The ExchangePlan scheduler
(``core/exchange.py``) fuses the flows of concurrent ops into one
request all-to-all and one reply all-to-all; ``Promise.FINE`` opts a
callsite out of fusion, forcing the sequential one-op-per-round
schedule — the oracle every fused path is tested against.  ``FINE``
composes with any remote promise (``find_insert | FINE`` is the
sequential find-then-insert) but contradicts ``LOCAL`` (a local op has
no collective rounds to schedule): :func:`validate` raises on it.
"""

from __future__ import annotations

import enum


class Promise(enum.IntFlag):
    """Operations that may run concurrently with the annotated callsite."""

    NONE = 0
    FIND = enum.auto()     # hash-map find may be concurrent
    INSERT = enum.auto()   # hash-map insert may be concurrent
    PUSH = enum.auto()     # queue push may be concurrent
    POP = enum.auto()      # queue pop may be concurrent
    LOCAL = enum.auto()    # op targets this process' own shard exclusively
    FINE = enum.auto()     # caller wants fine-grained (per-op) issue, no batching


class _HashMapProms:
    """``ConProm.HashMap.*`` namespace (paper spelling)."""

    find = Promise.FIND
    insert = Promise.INSERT
    local = Promise.LOCAL
    find_insert = Promise.FIND | Promise.INSERT


class _QueueProms:
    """``ConProm.CircularQueue.*`` namespace (paper spelling)."""

    push = Promise.PUSH
    pop = Promise.POP
    local = Promise.LOCAL
    push_pop = Promise.PUSH | Promise.POP


class ConProm:
    """Namespace mirroring the paper's ``ConProm::HashMap::find`` etc."""

    HashMap = _HashMapProms
    CircularQueue = _QueueProms
    FastQueue = _QueueProms

    NONE = Promise.NONE
    FIND = Promise.FIND
    INSERT = Promise.INSERT
    PUSH = Promise.PUSH
    POP = Promise.POP
    LOCAL = Promise.LOCAL
    FINE = Promise.FINE


def validate(promise: Promise) -> Promise:
    """Reject contradictory promise combinations at trace time.

    ``FINE`` requests a per-op collective schedule; ``LOCAL`` promises
    the op never leaves this rank, so there is no schedule to pick —
    the combination is nonsense, not merely redundant, and silently
    honoring either half would mask a caller bug.
    """
    if (promise & Promise.FINE) and (promise & Promise.LOCAL):
        raise ValueError(
            f"contradictory promise {promise!r}: FINE selects a "
            "sequential collective schedule but LOCAL promises the op "
            "issues no collectives at all")
    return promise


def fine_grained(promise: Promise) -> bool:
    """True when the callsite opted out of cross-op fusion (Promise.FINE)."""
    return bool(promise & Promise.FINE)


def fully_atomic_hashmap(promise: Promise) -> bool:
    """True when the callsite must assume concurrent finds AND inserts."""
    return bool(promise & Promise.FIND) and bool(promise & Promise.INSERT)


def find_only(promise: Promise) -> bool:
    return bool(promise & Promise.FIND) and not (promise & Promise.INSERT)


def local_only(promise: Promise) -> bool:
    return bool(promise & Promise.LOCAL)


def fully_atomic_queue(promise: Promise) -> bool:
    return bool(promise & Promise.PUSH) and bool(promise & Promise.POP)
