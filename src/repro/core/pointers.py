"""Global pointers (paper section 3).

A BCL global pointer is ``(rank, offset)`` into that rank's shared memory
segment.  Here a *segment* is a container shard: every rank holds a local
``(local_n, ...)`` slice of a logically global ``(nprocs * local_n, ...)``
array.  A ``GlobalPointer`` is a pytree of i32 arrays, so pointers can be
stored inside other containers, communicated through the exchange engine,
and manipulated with ordinary pointer arithmetic — exactly the paper's
"global pointers are regular data objects".
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class GlobalPointer(NamedTuple):
    """(rank, offset) pair; both i32 arrays of matching shape."""

    rank: jax.Array
    offset: jax.Array

    # -- pointer arithmetic (paper: "analogous to local pointer arithmetic")

    def __add__(self, n) -> "GlobalPointer":
        return GlobalPointer(self.rank, self.offset + jnp.int32(n))

    def __sub__(self, n) -> "GlobalPointer":
        return GlobalPointer(self.rank, self.offset - jnp.int32(n))

    def is_null(self) -> jax.Array:
        return self.rank < 0

    @staticmethod
    def null(shape=()) -> "GlobalPointer":
        return GlobalPointer(jnp.full(shape, -1, jnp.int32),
                             jnp.full(shape, 0, jnp.int32))


def global_index(ptr: GlobalPointer, local_n: int) -> jax.Array:
    """Flatten (rank, offset) to a global element index."""
    return ptr.rank * jnp.int32(local_n) + ptr.offset


def from_global_index(idx: jax.Array, local_n: int) -> GlobalPointer:
    """Split a global element index into (rank, offset) for block layout."""
    idx = idx.astype(jnp.int32)
    return GlobalPointer(idx // jnp.int32(local_n), idx % jnp.int32(local_n))
