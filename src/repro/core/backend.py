"""BCL backends, JAX edition.

The paper's BCL Core runs over four communication backends (MPI one-sided,
OpenSHMEM, GASNet-EX, UPC++), each implementing a small primitive set:
init / barrier / read / write / CAS / broadcast / reduce.  Container code
is written once against that primitive set.

The JAX port keeps the exact same structure with three backends that are
*lowering strategies* rather than wire protocols:

  SerialBackend   nprocs == 1, collectives are identities.  The reference
                  semantics; used by oracles, single-device tests, and any
                  container running on an unsharded axis.

  SpmdBackend     per-device code inside ``jax.shard_map`` over a named
                  mesh axis.  Collectives lower to real ICI collectives
                  (all-to-all / all-gather / psum / ppermute).  This is the
                  production path.

  GspmdBackend    global-array semantics: the same primitive set expressed
                  as shape transforms + sharding constraints, letting the
                  XLA SPMD partitioner choose the collective schedule.
                  (Used by the model stack, where the compiler's schedule
                  is usually the right one.)

Container code takes a ``Backend`` and never mentions the lowering —
exactly the paper's "pick whichever backend is most optimized for your
system" portability story.
"""

from __future__ import annotations

import abc
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Backend(abc.ABC):
    """Primitive set every BCL backend must implement (paper section 8)."""

    #: mesh axis name(s) this backend communicates over ("" for serial)
    axis: str | tuple[str, ...]

    @abc.abstractmethod
    def nprocs(self) -> int:
        """Static number of ranks on the communication axis."""

    @abc.abstractmethod
    def rank(self) -> jax.Array:
        """Traced index of the calling rank (i32 scalar)."""

    @abc.abstractmethod
    def all_to_all(self, x: jax.Array,
                   groups: Sequence[Sequence[int]] | None = None) -> jax.Array:
        """Tiled all-to-all over axis 0.

        ``x`` has shape (nprocs * C, ...): rows [d*C:(d+1)*C] are sent to
        rank d; the result's rows [s*C:(s+1)*C] were received from rank s.
        Identity when nprocs == 1.

        ``groups`` restricts the collective to a *sub-axis*: a static
        partition of [0, nprocs) into equal-size groups (e.g. the rows or
        columns of a Pr x Pc virtual factorization of the rank axis —
        DESIGN.md section 1.7).  Then ``x`` has shape (G * C, ...) with G
        the group size: block j goes to the j-th member of my group, and
        the result's block j came from that member.  This is the paper's
        "hierarchical team" primitive (DASH-style) expressed over one
        flat communication axis.
        """

    @abc.abstractmethod
    def all_gather(self, x: jax.Array) -> jax.Array:
        """Gather ``x`` from every rank, stacked on a new leading axis."""

    @abc.abstractmethod
    def psum(self, x: jax.Array) -> jax.Array:
        """Sum-reduce across ranks (broadcast result)."""

    @abc.abstractmethod
    def pmax(self, x: jax.Array) -> jax.Array:
        """Max-reduce across ranks (broadcast result)."""

    @abc.abstractmethod
    def ppermute(self, x: jax.Array, perm: Sequence[tuple[int, int]]) -> jax.Array:
        """Point-to-point permutation (the collective closest to RDMA put)."""

    def barrier(self) -> None:
        """Memory fence + barrier.

        SPMD program order already sequences collectives, so this is a
        semantic no-op kept for program structure (and cost accounting).
        """
        return None

    def broadcast(self, x: jax.Array, root: int = 0) -> jax.Array:
        """Broadcast ``x`` from ``root`` to all ranks."""
        if self.nprocs() == 1:
            return x
        return self.all_gather(x)[root]

    # -- derived helpers -------------------------------------------------

    def exclusive_rank_offsets(self, count: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Prefix-sum slot reservation: the TPU analogue of fetch-and-add.

        Every rank contributes ``count`` items to a shared sequence.  RDMA
        BCL reserves slots with an atomic fetch-and-add on the owner;
        here the reservation is an exclusive prefix sum over ranks —
        associative, contention-free, and deterministic.

        Returns ``(my_offset, total)``.
        """
        counts = self.all_gather(count)          # (nprocs,)
        csum = jnp.cumsum(counts)
        my = self.rank()
        my_offset = jnp.where(my == 0, 0, csum[jnp.maximum(my - 1, 0)])
        return my_offset.astype(jnp.int32), csum[-1].astype(jnp.int32)


class SerialBackend(Backend):
    """Single-rank backend: the reference semantics."""

    axis = ""

    def nprocs(self) -> int:
        return 1

    def rank(self) -> jax.Array:
        return jnp.int32(0)

    def all_to_all(self, x: jax.Array, groups=None) -> jax.Array:
        return x

    def all_gather(self, x: jax.Array) -> jax.Array:
        return x[None]

    def psum(self, x: jax.Array) -> jax.Array:
        return x

    def pmax(self, x: jax.Array) -> jax.Array:
        return x

    def ppermute(self, x, perm):
        return x


class SpmdBackend(Backend):
    """Per-device backend for code running inside ``jax.shard_map``.

    ``axis`` may be a single mesh axis name or a tuple of names; a tuple
    communicates over the flattened product axis (used when a container is
    sharded over the whole mesh, e.g. ``("data", "model")``).
    """

    def __init__(self, axis: str | tuple[str, ...], axis_size: int | None = None):
        self.axis = axis
        # axis size must be static; read it from the ambient mesh if not given.
        if axis_size is None:
            from repro.compat import axis_size as _axis_size
            axis_size = _axis_size(axis)
        self._nprocs = int(axis_size)

    def nprocs(self) -> int:
        return self._nprocs

    def rank(self) -> jax.Array:
        return jax.lax.axis_index(self.axis).astype(jnp.int32)

    def all_to_all(self, x: jax.Array, groups=None) -> jax.Array:
        if self._nprocs == 1:
            return x
        if groups is not None:
            groups = [list(g) for g in groups]
            if all(len(g) == 1 for g in groups):
                return x          # single-member groups: identity
            return jax.lax.all_to_all(x, self.axis, split_axis=0,
                                      concat_axis=0, tiled=True,
                                      axis_index_groups=groups)
        return jax.lax.all_to_all(x, self.axis, split_axis=0, concat_axis=0,
                                  tiled=True)

    def all_gather(self, x: jax.Array) -> jax.Array:
        if self._nprocs == 1:
            return x[None]
        return jax.lax.all_gather(x, self.axis)

    def psum(self, x: jax.Array) -> jax.Array:
        return jax.lax.psum(x, self.axis)

    def pmax(self, x: jax.Array) -> jax.Array:
        return jax.lax.pmax(x, self.axis)

    def ppermute(self, x, perm):
        return jax.lax.ppermute(x, self.axis, perm)


def get_backend(axis: str | tuple[str, ...] | None = None,
                axis_size: int | None = None) -> Backend:
    """Backend factory: serial when ``axis`` is None, SPMD otherwise."""
    if axis is None or axis == "":
        return SerialBackend()
    return SpmdBackend(axis, axis_size=axis_size)


def spec_for(backend: Backend, *rest: str | None) -> P:
    """PartitionSpec that shards axis 0 over the backend's comm axis."""
    if isinstance(backend, SerialBackend):
        return P(*((None,) + rest))
    return P(*((backend.axis,) + rest))
