"""BCL Core: the cross-platform internal DSL, adapted to JAX SPMD.

The paper's BCL Core provides global pointers, remote put/get, remote
atomics, and barriers over four communication backends (MPI, OpenSHMEM,
GASNet-EX, UPC++).  On TPU there is no RDMA and no remote atomic; the
core instead provides the same *semantics* over three JAX lowering
backends (serial / spmd / gspmd), with:

  * remote get/put      -> owner-routed batched transfers (all_to_all)
  * fetch-and-add       -> prefix-sum slot reservation (associative scan)
  * CAS / fetch-and-or  -> owner-computes deterministic resolution
  * barrier/fence       -> SPMD program order (explicit token when needed)

See DESIGN.md section 2 for the full adaptation table.
"""

from repro.core.backend import Backend, SerialBackend, SpmdBackend, get_backend
from repro.core.promises import ConProm, Promise
from repro.core.pointers import GlobalPointer
from repro.core.exchange import (ExchangeOverflowError, ExchangePlan,
                                 PendingPlan, PendingResult, RouteResult,
                                 carry_mask, reply, route, suggest_rounds)
from repro.core.transport import (DenseTransport, HierarchicalTransport,
                                  Transport, make_transport)
from repro.core.faults import FaultInjectingTransport, FaultSpec
from repro.core import costs

__all__ = [
    "Backend",
    "SerialBackend",
    "SpmdBackend",
    "get_backend",
    "ConProm",
    "Promise",
    "GlobalPointer",
    "ExchangePlan",
    "ExchangeOverflowError",
    "PendingPlan",
    "PendingResult",
    "carry_mask",
    "route",
    "reply",
    "RouteResult",
    "suggest_rounds",
    "Transport",
    "DenseTransport",
    "HierarchicalTransport",
    "make_transport",
    "FaultSpec",
    "FaultInjectingTransport",
    "costs",
]
