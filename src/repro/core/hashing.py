"""Vectorized integer hashing for BCL containers.

The containers hash 64-bit keys represented as pairs of u32 lanes (JAX
x64 stays disabled — TPU-realistic).  We use the xxHash/murmur-style
avalanche finalizer, which is cheap on the VPU (shifts, xors, mults) and
passes the usual avalanche tests.  ``k`` independent hashes (Bloom filter)
come from the standard double-hashing construction h1 + i*h2 [Kirsch &
Mitzenmacher], matching the paper's "k hash functions" at 2 hashes of cost.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_U32 = jnp.uint32

# murmur3 fmix32 constants
_C1 = jnp.uint32(0x85EBCA6B)
_C2 = jnp.uint32(0xC2B2AE35)
# golden-ratio stream-mixing constants
_PHI = jnp.uint32(0x9E3779B9)


def fmix32(h: jax.Array) -> jax.Array:
    """murmur3 finalizer: full-avalanche mix of a u32 lane."""
    h = h.astype(_U32)
    h = h ^ (h >> 16)
    h = h * _C1
    h = h ^ (h >> 13)
    h = h * _C2
    h = h ^ (h >> 16)
    return h


def hash_u32(x: jax.Array, seed: int = 0) -> jax.Array:
    """Hash u32 lanes with a seed (vectorized)."""
    h = x.astype(_U32) ^ (jnp.uint32(seed) * _PHI + jnp.uint32(1))
    return fmix32(h)


def hash_lanes(lanes: jax.Array, seed: int = 0) -> jax.Array:
    """Hash a (N, L) u32 lane matrix to one u32 per row.

    Horner-style stream mix over lanes followed by the avalanche
    finalizer.  ``L`` is a static trace-time constant, so the loop
    unrolls into straight-line VPU code.
    """
    if lanes.ndim == 1:
        lanes = lanes[:, None]
    n, num_lanes = lanes.shape
    h = jnp.full((n,), jnp.uint32(seed) * _PHI + jnp.uint32(num_lanes), _U32)
    for i in range(num_lanes):
        h = (h ^ fmix32(lanes[:, i].astype(_U32))) * _C1 + jnp.uint32(i + 1)
    return fmix32(h)


def double_hash(lanes: jax.Array, k: int, modulo: int) -> jax.Array:
    """k hash values per row in [0, modulo) via double hashing.

    Returns (N, k) u32.  ``h2`` is forced odd so that for power-of-two
    ``modulo`` the probe sequence visits distinct slots.
    """
    h1 = hash_lanes(lanes, seed=1)
    h2 = hash_lanes(lanes, seed=2) | jnp.uint32(1)
    i = jnp.arange(k, dtype=_U32)[None, :]
    hk = h1[:, None] + i * h2[:, None]
    return (hk % jnp.uint32(modulo)).astype(jnp.uint32)
