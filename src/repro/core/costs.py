"""Operation-cost accounting mirroring paper Tables 2, 3 and 4.

The paper expresses the best-case cost of each data-structure operation in
terms of

  R  remote reads           W  remote writes
  A  remote atomic ops      B  global barriers
  l  local memory ops       n  elements involved

On TPU the *mechanism* differs (owner-computes collectives instead of
RDMA/AMOs) but the cost model is preserved: every container method reports
the cost of the schedule it actually lowered, in the paper's own units,
plus the TPU-side observables (number of collectives launched and bytes
moved).  Tests assert the paper's exact cost formulas; benchmarks report
bytes and collective counts next to wall time.

Costs are trace-time (static) values: they depend only on shapes and
promises, never on traced data, so accounting lives outside jit.
"""

from __future__ import annotations

import dataclasses
from contextlib import contextmanager
from typing import Iterator


@dataclasses.dataclass
class Cost:
    """Cost of one data-structure operation in the paper's units."""

    A: int = 0          # remote atomic ops (owner-RMW rounds here)
    R: int = 0          # remote reads (elements)
    W: int = 0          # remote writes (elements)
    B: int = 0          # barriers
    local: int = 0      # local ops (elements)
    collectives: int = 0  # TPU observable: collectives launched
    bytes_moved: int = 0  # TPU observable: bytes through collectives
    rounds: int = 0       # TPU observable: all-to-all round trips on the
    #                       critical path (the latency term of the paper's
    #                       aggregation argument, section 4.2)
    bytes_out: int = 0    # bytes in the request direction (requester->owner)
    bytes_in: int = 0     # bytes in the reply direction (owner->requester)
    hops: int = 0         # TPU observable: physical exchange stages on the
    #                       critical path — 1 per dense all-to-all launch,
    #                       2 per hierarchical (two-stage) launch, so a
    #                       cost log shows which transport moved the bytes
    #                       (DESIGN.md section 1.7)
    lost_bytes: int = 0   # wire bytes admitted toward destinations known
    #                       to be dead at commit time (degraded commits,
    #                       DESIGN.md section 1.8); static upper bound
    unreachable: int = 0  # dead destination ranks masked at admission
    overlap_launches: int = 0  # collective launches issued split-phase
    #                       (commit_async start) whose completion was
    #                       deferred to finish(); counted once, at wait
    #                       time, alongside the launch's normal
    #                       collectives/hops/bytes (DESIGN.md section 1.9)

    def __add__(self, other: "Cost") -> "Cost":
        return Cost(
            self.A + other.A,
            self.R + other.R,
            self.W + other.W,
            self.B + other.B,
            self.local + other.local,
            self.collectives + other.collectives,
            self.bytes_moved + other.bytes_moved,
            self.rounds + other.rounds,
            self.bytes_out + other.bytes_out,
            self.bytes_in + other.bytes_in,
            self.hops + other.hops,
            self.lost_bytes + other.lost_bytes,
            self.unreachable + other.unreachable,
            self.overlap_launches + other.overlap_launches,
        )

    def formula(self) -> str:
        """Render in the paper's notation, e.g. ``2A + nW``."""
        parts = []
        for val, sym in ((self.A, "A"), (self.R, "R"), (self.W, "W"),
                         (self.B, "B"), (self.local, "l")):
            if val == 1:
                parts.append(sym)
            elif val > 1:
                parts.append(f"{val}{sym}")
        return " + ".join(parts) if parts else "0"


@dataclasses.dataclass
class CostLog:
    """Accumulates per-operation costs; installed via :func:`recording`."""

    entries: list = dataclasses.field(default_factory=list)

    def record(self, op: str, cost: Cost) -> None:
        self.entries.append((op, cost))

    def total(self) -> Cost:
        tot = Cost()
        for _, c in self.entries:
            tot = tot + c
        return tot

    def by_op(self, op: str) -> Cost:
        tot = Cost()
        for name, c in self.entries:
            if name == op:
                tot = tot + c
        return tot


_ACTIVE: list[CostLog] = []


def record(op: str, cost: Cost) -> None:
    """Record a cost against the innermost active log (no-op otherwise)."""
    if _ACTIVE:
        _ACTIVE[-1].record(op, cost)


@contextmanager
def recording() -> Iterator[CostLog]:
    """Context manager: collect costs of all container ops issued inside."""
    log = CostLog()
    _ACTIVE.append(log)
    try:
        yield log
    finally:
        _ACTIVE.pop()
