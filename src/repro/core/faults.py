"""Deterministic fault injection for the exchange stack (DESIGN.md §1.8).

BCL's portability story assumes the communication substrate delivers
every word; real fabrics do not.  This module lets tests and benchmarks
subject the *unmodified* exchange engine to the three failure classes
that matter for distributed containers:

  kill      a rank goes silent: every word it would have contributed to
            a collective arrives as zero on every peer (and stays zero
            for all later launches) — the SPMD analogue of a node loss.

  drop      one (launch, src, dst) wire segment is lost in flight: the
            destination block of ``src``'s send buffer is zeroed for
            exactly that collective launch.

  corrupt   one word of one (launch, src, dst) segment is bit-flipped
            in flight (XOR with a seed-derived mask at a seed-derived
            word index).

Faults are **seeded and trace-time deterministic**: a :class:`FaultSpec`
names launches by their index in program order (the ``n``-th
``all_to_all`` issued through the wrapped transport), sources and
destinations by rank, and derives corrupted word positions from the
seed by integer hashing — no wall-clock randomness, so a faulty program
is jit-stable, reproducible, and resumable.

:class:`FaultInjectingTransport` wraps ANY :class:`Transport` (dense or
hierarchical): it forwards ``request``/``reply`` to the inner transport
but hands it a :class:`_FaultyBackend` whose ``all_to_all`` mutates the
send buffer before the real collective.  The inner transport's wire
format, cost attribution, and slot bookkeeping are untouched — faults
happen strictly "on the wire", which is exactly where the integrity
machinery (checksum lane, ``lost`` accounting, ack-driven carry) must
catch them.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.backend import Backend
from repro.core.transport import Transport

_U32 = jnp.uint32

#: Knuth multiplicative constants for the word/bit position hash.
_H1 = 2654435761
_H2 = 1013904223


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded, deterministic description of injected wire faults.

    ``launch`` indices count ``all_to_all`` calls issued through the
    wrapping transport, in program order, starting at 0 (a dense
    request round is one launch; a hierarchical request round is two;
    replies follow).  ``src``/``dst`` are block indices of that launch's
    send buffer — global ranks for a full-axis collective, group-local
    positions for a grouped (hierarchical sub-axis) collective.
    """

    seed: int = 0
    #: ranks whose sends are zeroed from ``kill_from_launch`` onwards
    kill_ranks: tuple[int, ...] = ()
    kill_from_launch: int = 0
    #: (launch, src, dst) wire segments dropped whole
    drop: tuple[tuple[int, int, int], ...] = ()
    #: (launch, src, dst) wire segments with one bit-flipped word
    corrupt: tuple[tuple[int, int, int], ...] = ()

    def word_and_mask(self, launch: int, src: int, dst: int,
                      block_words: int) -> tuple[int, int]:
        """Seed-derived (word index, XOR mask) for a corrupt fault."""
        h = (self.seed * _H1 + launch * _H2 + src * 97 + dst * 31)
        wi = h % max(block_words, 1)
        bit = (h // max(block_words, 1)) % 32
        return wi, 1 << bit


class _FaultyBackend(Backend):
    """Backend proxy that mutates ``all_to_all`` sends per a FaultSpec.

    Every other primitive forwards untouched: faults model the data
    fabric, not the control collectives (psum/all_gather) that carry
    the engine's own bookkeeping.
    """

    def __init__(self, inner: Backend, spec: FaultSpec,
                 launch_counter: list[int]):
        self._inner = inner
        self._spec = spec
        self._launch = launch_counter
        self.axis = inner.axis

    # -- forwarded primitives -------------------------------------------
    def nprocs(self) -> int:
        return self._inner.nprocs()

    def rank(self) -> jax.Array:
        return self._inner.rank()

    def all_gather(self, x: jax.Array) -> jax.Array:
        return self._inner.all_gather(x)

    def psum(self, x: jax.Array) -> jax.Array:
        return self._inner.psum(x)

    def pmax(self, x: jax.Array) -> jax.Array:
        return self._inner.pmax(x)

    def ppermute(self, x, perm):
        return self._inner.ppermute(x, perm)

    def barrier(self) -> None:
        return self._inner.barrier()

    # -- the faulty wire ------------------------------------------------
    def all_to_all(self, x: jax.Array,
                   groups: Sequence[Sequence[int]] | None = None
                   ) -> jax.Array:
        launch = self._launch[0]
        self._launch[0] = launch + 1
        x = self._mutate(x, groups, launch)
        return self._inner.all_to_all(x, groups)

    def _mutate(self, x: jax.Array, groups, launch: int) -> jax.Array:
        spec = self._spec
        nblocks = (len(groups[0]) if groups is not None
                   else self._inner.nprocs())
        if nblocks < 1 or x.shape[0] % nblocks:
            return x          # degenerate layout: nothing to target
        rank = self._inner.rank()

        # kill: this rank's whole send zeroes out, permanently
        if spec.kill_ranks and launch >= spec.kill_from_launch:
            dead = jnp.zeros((), bool)
            for k in spec.kill_ranks:
                dead = dead | (rank == k)
            x = jnp.where(dead, jnp.zeros_like(x), x)

        drops = [(s, d) for (l, s, d) in spec.drop if l == launch]
        flips = [(s, d) for (l, s, d) in spec.corrupt if l == launch]
        if not drops and not flips:
            return x

        shape = x.shape
        blocks = x.reshape(nblocks, -1)
        block_words = blocks.shape[1]
        for src, dst in drops:
            if not 0 <= dst < nblocks:
                continue
            hit = blocks.at[dst].set(jnp.zeros_like(blocks[dst]))
            blocks = jnp.where(rank == src, hit, blocks)
        for src, dst in flips:
            if not 0 <= dst < nblocks:
                continue
            wi, mask = spec.word_and_mask(launch, src, dst, block_words)
            flipped = blocks[dst, wi] ^ jnp.asarray(mask, blocks.dtype)
            hit = blocks.at[dst, wi].set(flipped)
            blocks = jnp.where(rank == src, hit, blocks)
        return blocks.reshape(shape)


class FaultInjectingTransport(Transport):
    """Wrap any transport so its collectives traverse a faulty fabric.

    The launch counter is trace-time state shared between request and
    reply phases; it counts ``all_to_all`` calls since construction (or
    the last :meth:`reset`), so a :class:`FaultSpec`'s launch indices
    address a specific collective of a specific jitted program — build
    one wrapper per program (or ``reset()`` between traces) to keep the
    numbering deterministic.
    """

    def __init__(self, inner: Transport, spec: FaultSpec):
        self.inner = inner
        self.spec = spec
        self.name = inner.name
        self._launch = [0]

    def reset(self) -> None:
        """Restart launch numbering (call between independent traces)."""
        self._launch[0] = 0

    @property
    def launches(self) -> int:
        """Collective launches traced through this wrapper so far."""
        return self._launch[0]

    def _wrap(self, backend: Backend) -> Backend:
        return _FaultyBackend(backend, self.spec, self._launch)

    def request(self, backend: Backend, args) -> tuple[list, Any, Any]:
        return self.inner.request(self._wrap(backend), args)

    def request_start(self, backend: Backend, args):
        # split-phase launches count through the SAME shared counter, so
        # a spec's launch indices address collectives in the overlapped
        # program order (all starts, then the waits)
        return self.inner.request_start(self._wrap(backend), args)

    def request_wait(self, backend: Backend, handle):
        return self.inner.request_wait(self._wrap(backend), handle)

    def reply(self, backend: Backend, ctx, staged):
        return self.inner.reply(self._wrap(backend), ctx, staged)
