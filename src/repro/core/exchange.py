"""The many-to-many exchange engine — the heart of the TPU port.

Paper section 4.2 identifies "asynchronous many-to-many redistribution"
as the parallel pattern behind queues, buffered hash-table insertion, and
the ISx bucket sort.  On RDMA hardware BCL realizes it as: buffer locally
per destination -> fetch-and-add reserves remote slots -> RDMA put.

On TPU the same pattern is one fused collective program:

  1. bin items by destination rank          (histogram + per-tile prefix +
                                             slot scatter — a Pallas
                                             kernel, no argsort)
  2. reserve slots                          (exclusive prefix sums — the
                                             associative, contention-free
                                             analogue of fetch-and-add)
  3. pad each destination bucket to a
     static capacity C                      (SPMD shapes are static)
  4. one tiled all-to-all moves everything  (latency-bound -> bandwidth-
                                             bound, which is exactly the
                                             HashMapBuffer insight)
  5. unmask on the owner

``route`` is that program.  Every container op with a remote component
compiles down to one or two ``route`` calls, mirroring the paper's claim
that each data-structure op is "a small number of one-sided operations".

Wire format (DESIGN.md section 1): payloads are u32 lane matrices (see
object_container.py); ``route`` appends exactly ONE metadata lane —
bit 31 is the valid flag and the low 31 bits are the item's position in
the sender's batch — so an L-lane payload costs L+1 u32 lanes on the
wire.  Replies cost L lanes and zero metadata: the owner's receive
layout is the exact image of the requester's send layout under the
all-to-all, so writing replies into the rows they arrived in and running
one more all-to-all is an *inverse permutation* that lands every reply
back in the requester's original send slot.  The requester resolves
slots to batch positions from purely local state (``send_item``); no
binning, no argsort, no scatter, and no src_pos lane in the reply
direction.

Shapes and capacities are static; overflow beyond C is dropped and
*counted* (the analogue of a failed/retried insertion), so callers can
assert zero drops or size capacities adaptively.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.kernels import ops as kops

_U32 = jnp.uint32
_I32 = jnp.int32

# metadata lane: bit 31 = valid, bits 0..30 = src_pos
_VALID_BIT = jnp.uint32(1 << 31)
_POS_MASK = jnp.uint32((1 << 31) - 1)


class RouteResult(NamedTuple):
    """Owner-side view of a routed batch (+ requester-local slot map).

    payload   (P*C, L) u32 — rows [s*C:(s+1)*C] arrived from rank s
    valid     (P*C,) bool  — which rows hold real items
    src_rank  (P*C,) i32   — originating rank (derived from slot position)
    src_pos   (P*C,) i32   — item's index in the sender's original batch
    dropped   () i32       — items dropped for capacity overflow (global)
    capacity  int          — static per-(src,dst) capacity C
    send_item (P*C,) i32   — requester-local: original batch index this
                             rank placed in each of its own send slots
                             (sentinel N when the slot was empty)
    send_occ  (P*C,) bool  — requester-local send-slot occupancy; the
                             reply path's ``answered`` comes from here,
                             not from the wire
    """

    payload: jax.Array
    valid: jax.Array
    src_rank: jax.Array
    src_pos: jax.Array
    dropped: jax.Array
    capacity: int
    send_item: jax.Array
    send_occ: jax.Array


def route(backend: Backend,
          payload: jax.Array,
          dest: jax.Array,
          capacity: int,
          valid: jax.Array | None = None,
          op_name: str = "route",
          impl: str = "auto") -> RouteResult:
    """Send each row of ``payload`` to rank ``dest[i]``; return owner view.

    payload: (N, L) u32 (or (N,) — treated as one lane)
    dest:    (N,) i32 destination ranks in [0, nprocs)
    capacity: static per-(src,dst) slot count C
    valid:   (N,) bool mask (default all valid)
    impl:    kernel dispatch for send-buffer construction (kops.bin_offsets)
    """
    if payload.ndim == 1:
        payload = payload[:, None]
    payload = payload.astype(_U32)
    n, lanes = payload.shape
    nprocs = backend.nprocs()
    cap = int(capacity)

    if valid is None:
        valid = jnp.ones((n,), bool)
    dest = dest.astype(_I32)

    # send-buffer construction: no argsort — each item computes its slot
    # directly from (histogram -> per-tile prefix -> within-tile rank)
    counts, offsets = kops.bin_offsets(dest, nprocs, valid, impl=impl)
    in_cap = offsets < cap
    slot = jnp.where(valid & in_cap, dest * cap + offsets,
                     nprocs * cap).astype(_I32)   # drop sentinel

    # lanes layout: [payload | meta] with meta = VALID_BIT | src_pos
    meta = jnp.where(valid, _VALID_BIT | jnp.arange(n, dtype=_U32), 0)
    body = jnp.concatenate([payload, meta[:, None]], axis=1)
    send = jnp.zeros((nprocs * cap, lanes + 1), _U32)
    send = send.at[slot].set(body, mode="drop")

    recv = backend.all_to_all(send)

    out_payload = recv[:, :lanes]
    meta_r = recv[:, lanes]
    out_valid = (meta_r & _VALID_BIT) != 0
    out_src_pos = (meta_r & _POS_MASK).astype(_I32)
    src_rank = jnp.repeat(jnp.arange(nprocs, dtype=_I32), cap)

    # requester-local inverse slot map: which item sits in each send slot
    send_item = jnp.full((nprocs * cap,), n, _I32).at[slot].set(
        jnp.arange(n, dtype=_I32), mode="drop")
    send_occ = jnp.zeros((nprocs * cap,), bool).at[slot].set(
        jnp.ones((n,), bool), mode="drop")

    over = jnp.maximum(counts - cap, 0).sum()
    dropped = backend.psum(over).astype(_I32)

    # route records only the TPU observables; the paper-units cost (R/W/A)
    # is accounted by the calling container op.
    wire_bytes = nprocs * cap * (lanes + 1) * 4
    costs.record(op_name, costs.Cost(
        collectives=1, rounds=1, bytes_moved=wire_bytes,
        bytes_out=wire_bytes))

    return RouteResult(out_payload, out_valid, src_rank, out_src_pos,
                       dropped, cap, send_item, send_occ)


def reply(backend: Backend,
          req: RouteResult,
          reply_payload: jax.Array,
          orig_n: int,
          op_name: str = "reply") -> tuple[jax.Array, jax.Array]:
    """Route per-request replies back to the requesters.

    ``reply_payload`` is (P*C, L) aligned with ``req.payload`` rows.
    Returns ``(replies, answered)`` where ``replies`` is (orig_n, L)
    aligned with the *original* request batch and ``answered`` marks rows
    that received a reply.

    This is a single inverse all-to-all: the owner's row s*C+j arrived
    from rank s's send slot d*C+j, and the tiled all-to-all maps row
    s*C+j straight back there — so replies written in arrival order need
    no binning, no metadata lanes, and no second slot reservation.  The
    requester resolves slots to batch positions with its local
    ``send_item`` map and knows ``answered`` from its own ``send_occ``.
    """
    if reply_payload.ndim == 1:
        reply_payload = reply_payload[:, None]
    lanes = reply_payload.shape[1]

    send = jnp.where(req.valid[:, None], reply_payload.astype(_U32), 0)
    back = backend.all_to_all(send)

    # back[k] answers the item this rank placed in send slot k of the
    # original route call
    item = jnp.where(req.send_occ, req.send_item, orig_n)  # drop sentinel
    out = jnp.zeros((orig_n, lanes), _U32).at[item].set(back, mode="drop")
    answered = jnp.zeros((orig_n,), bool).at[item].set(
        req.send_occ, mode="drop")

    wire_bytes = send.shape[0] * lanes * 4
    costs.record(op_name, costs.Cost(
        collectives=1, rounds=1, bytes_moved=wire_bytes,
        bytes_in=wire_bytes))
    return out, answered


def exchange_capacity(n_per_rank: int, nprocs: int, slack: float = 1.25) -> int:
    """Heuristic static capacity for roughly-uniform traffic.

    Uniform traffic puts ~n/P items in each (src,dst) bucket; ``slack``
    absorbs skew.  Irregular apps (MoE dispatch!) pass explicit
    capacities derived from their own load model instead.
    """
    if nprocs == 1:
        return n_per_rank
    base = (n_per_rank + nprocs - 1) // nprocs
    return max(1, int(base * slack) + 1)
