"""The many-to-many exchange engine — the heart of the TPU port.

Paper section 4.2 identifies "asynchronous many-to-many redistribution"
as the parallel pattern behind queues, buffered hash-table insertion, and
the ISx bucket sort.  On RDMA hardware BCL realizes it as: buffer locally
per destination -> fetch-and-add reserves remote slots -> RDMA put.

On TPU the same pattern is one fused collective program:

  1. bin items by destination rank          (histogram + per-tile prefix +
                                             slot scatter — a Pallas
                                             kernel, no argsort)
  2. reserve slots                          (exclusive prefix sums — the
                                             associative, contention-free
                                             analogue of fetch-and-add)
  3. pad each destination bucket to a
     static capacity C                      (SPMD shapes are static)
  4. one tiled all-to-all moves everything  (latency-bound -> bandwidth-
                                             bound, which is exactly the
                                             HashMapBuffer insight)
  5. unmask on the owner

Scheduling is two-phase (DESIGN.md section 1.5): callers register typed
*flows* on an :class:`ExchangePlan` (``plan.add(payload, dest, capacity,
reply_lanes, op_name)``), and ``plan.commit(backend)`` concatenates all
same-round flows lane-wise into ONE binning pass and ONE tiled
all-to-all, demultiplexing per-flow owner views; replies from every flow
share one inverse all-to-all (``plan.finish``).  This is the paper's
concurrency-promise story made operational: a promise names which ops
may run concurrently, and concurrent ops are exactly the ops whose
flows may share a collective round.  ``Promise.FINE`` on the plan
forces the sequential one-op-per-round schedule — the oracle every
fused path is tested against.

``route``/``reply`` remain as thin single-flow wrappers, so a container
op that has nothing to fuse with still compiles to the same program it
always did.

Wire format (DESIGN.md section 1): payloads are u32 lane matrices (see
object_container.py), and the fused wire is *ragged*: per destination
rank, the request buffer is a flat u32 word vector in which each flow
owns one contiguous segment of exactly ``C_f * (L_f + 1)`` words — rows
of flow f are ``L_f + 1`` words wide, the last word being the flow's
metadata lane (bit 31 the valid flag, low 31 bits the item's position
in its flow's batch).  No flow pays another flow's width: a plan's
request bytes equal the SUM of its flows' single-flow ``route()``
bytes, which is what makes fusion unconditionally profitable.  Reply
segments are likewise exactly ``R_f`` words per row and zero metadata:
the owner's receive layout is the exact image of the requesters' send
layout under the all-to-all, so writing replies into segment-order
rows and running one more all-to-all is an *inverse permutation* that
lands every reply back in the requester's original send slot.  The
requester resolves slots to batch positions from purely local state
captured at commit time; no binning, no argsort, and no src_pos lane
in the reply direction.

The *physical* movement behind commit/finish is pluggable (DESIGN.md
section 1.7): the plan computes the logical exchange — the ONE binning
pass, admission, ragged layout, send maps — and hands movement to a
:class:`repro.core.transport.Transport`.  ``DenseTransport`` (the
default) is the one-shot tiled all-to-all described above;
``HierarchicalTransport`` factors the rank axis ``P = Pr x Pc`` and
moves everything in two sqrt(P)-peer stages with a relay re-binning
hop, bit-identical to dense whenever its stage capacities admit the
dense-admitted traffic.  Containers thread a ``transport=`` knob;
``None`` keeps the dense program byte-for-byte.

Shapes and capacities are static; what happens beyond a flow's capacity
is governed by the plan's ``overflow`` policy (DESIGN.md section 1.6).
RDMA BCL retries a failed fetch-and-add; the static-shape analogue is
*carryover retry rounds*: ``commit(max_rounds=R)`` ships, in round
``r``, exactly the items whose within-(dest, flow)-bucket rank from the
SINGLE binning pass falls in ``[r*C_f, (r+1)*C_f)`` — the retry rounds
are pure extra all-to-alls whose masks are derived from the offsets
already computed, with no second binning pass.  Owner views concatenate
the rounds to an effective capacity ``R*C_f`` (row ``s*(R*C_f) + o``
holds rank-``o`` arrivals from rank ``s`` — bit-identical to a single
round at capacity ``R*C_f``), the reply stays ONE inverse all-to-all
(just ``R`` times wider), and ``dropped`` counts only items whose rank
is ``>= R*C_f``.  Residual overflow is then dropped-and-counted
(``overflow="drop"``), raised on eagerly (``"raise-in-test"``), or
handed back to the caller as a re-injection mask
(``"carry"``/:meth:`CommittedPlan.leftover` — the HashMapBuffer flush
path re-stages leftovers exactly like the paper's failed-insert
re-insertion loop).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.promises import Promise, fine_grained, validate
from repro.core.transport import (DENSE, FlowWire, RequestArgs, Transport,
                                  _DenseCtx, make_transport)
from repro.kernels import ops as kops

_U32 = jnp.uint32
_I32 = jnp.int32

# metadata lane: bit 31 = valid, bits 0..30 = src_pos
_VALID_BIT = jnp.uint32(1 << 31)
_POS_MASK = jnp.uint32((1 << 31) - 1)

#: salt added to every wire checksum word so an intact-but-empty window
#: (stored = SALT + 0) is distinguishable from a zeroed/lost segment
#: (stored = 0, checksum row's own meta lane also zeroed)
_CK_SALT = jnp.uint32(0x9E3779B9)

#: legal ``overflow=`` policies (DESIGN.md section 1.6)
OVERFLOW_POLICIES = ("drop", "raise-in-test", "carry")


class ExchangeOverflowError(RuntimeError):
    """Raised by ``overflow="raise-in-test"`` when a flow drops items.

    Only raised when drop counts are concrete (eager execution — the
    test/debug regime the policy is named for); under ``jit`` tracing
    the counts are tracers and the policy degrades to ``"drop"``.
    """


class RouteResult(NamedTuple):
    """Owner-side view of a routed flow (+ requester-local slot map).

    payload   (P*C, L) u32 — rows [s*C:(s+1)*C] arrived from rank s
    valid     (P*C,) bool  — which rows hold real items
    src_rank  (P*C,) i32   — originating rank (derived from slot position)
    src_pos   (P*C,) i32   — item's index in the sender's original batch
    dropped   () i32       — items dropped for capacity overflow (global)
    capacity  int          — static EFFECTIVE per-(src,dst) capacity: the
                             flow's declared C times the plan's
                             ``max_rounds`` (retry rounds concatenate)
    send_item (P*C,) i32   — requester-local: original batch index this
                             rank placed in each of its own send slots,
                             in flow-local coordinates (sentinel N when
                             the slot was empty); identical whether the
                             flow was routed eagerly or as a segment of
                             a fused plan
    send_occ  (P*C,) bool  — requester-local send-slot occupancy; the
                             reply path's ``answered`` comes from here,
                             not from the wire
    lost      () i32       — items shipped but NOT surviving arrival
                             (global): wire windows whose integrity
                             check failed, plus anything a faulty or
                             under-provisioned transport lost in
                             flight.  Always 0 unless the plan was
                             committed with ``integrity=True``
                             (DESIGN.md section 1.8); such items are
                             healed by the caller's ack-driven carry
                             path, never silently consumed
    """

    payload: jax.Array
    valid: jax.Array
    src_rank: jax.Array
    src_pos: jax.Array
    dropped: jax.Array
    capacity: int
    send_item: jax.Array
    send_occ: jax.Array
    lost: jax.Array | int = 0


@dataclasses.dataclass
class _Flow:
    """One registered flow of an ExchangePlan (trace-time record)."""

    payload: jax.Array        # (N, L) u32
    dest: jax.Array           # (N,) i32
    capacity: int             # per-(src,dst) slot count C_f
    valid: jax.Array          # (N,) bool
    op_name: str
    reply_lanes: int          # 0 = fire-and-forget (no reply expected)
    max_rounds: int | None = None   # per-flow override; None = plan-wide

    @property
    def n(self) -> int:
        return self.payload.shape[0]

    @property
    def lanes(self) -> int:
        return self.payload.shape[1]


def _flow_rounds(f: _Flow, plan_rounds: int) -> int:
    """Effective retry rounds for one flow.

    The flow-level ``max_rounds`` (if set) overrides the plan-wide
    knob, and the result is clamped to ``ceil(N_f / C_f)``: no
    (dest, flow) bucket can ever hold more than the flow's N items, so
    rounds past that bound could never ship anything new — an
    exact-capacity flow (queue.pop's unit requests, MoE's stats flow)
    stays at ONE launch no matter what the plan requests, instead of
    paying R-fold wire for nothing.
    """
    r = plan_rounds if f.max_rounds is None else f.max_rounds
    return max(1, min(int(r), -(-f.n // f.capacity)))


class ExchangePlan:
    """Two-phase scheduler fusing concurrent container ops' collectives.

    Usage::

        plan = ExchangePlan(name="hashmap.find_insert")
        h_f = plan.add(find_body, owners_f, cap, reply_lanes=Lv + 1,
                       op_name="hashmap.find")
        h_i = plan.add(ins_body, owners_i, cap, reply_lanes=1,
                       op_name="hashmap.insert")
        c = plan.commit(backend)          # ONE all-to-all for all flows
        ... owner-side work on c.view(h_f), c.view(h_i) ...
        c.set_reply(h_f, find_replies)
        c.set_reply(h_i, ok_bits)
        outs = c.finish(backend)          # ONE inverse all-to-all
        find_out, find_answered = outs[h_f]

    Cost attribution (DESIGN.md section 1.5): each flow is charged the
    EXACT bytes of its own ragged wire segment — ``P * C_f * (L_f+1) * 4``
    out, ``P * C_f * R_f * 4`` back, identical to a single-flow
    ``route``/``reply`` — under its ``op_name``; the single physical
    collective and its round are charged once, under ``name`` (default:
    the first flow's op).

    A plan constructed with ``promise=Promise.FINE`` lowers to the
    sequential one-op-per-round schedule instead (one ``route`` and one
    ``reply`` per flow) — the semantic oracle for the fused schedule.
    """

    def __init__(self, promise: Promise = Promise.NONE,
                 name: str | None = None):
        validate(promise)
        self.promise = promise
        self.name = name
        self._flows: list[_Flow] = []
        self._committed = False

    def add(self, payload: jax.Array, dest: jax.Array, capacity: int,
            reply_lanes: int = 0, valid: jax.Array | None = None,
            op_name: str = "flow", max_rounds: int | None = None) -> int:
        """Register a flow; returns its handle (index into the plan).

        Shape/capacity mistakes are caught HERE, named after the flow's
        ``op_name`` — not three layers down as an opaque concatenate or
        reshape error inside the fused lowering.  ``max_rounds``
        overrides the plan-wide retry-round knob for THIS flow (e.g. an
        exactly-sized flow declares 1 so it never rides retry launches);
        either way the effective count clamps to ``ceil(N / capacity)``.
        """
        if self._committed:
            raise ValueError(
                "add() after commit(): the round's flows are already on "
                "the wire; build a new ExchangePlan for the next round")
        if payload.ndim not in (1, 2):
            raise ValueError(
                f"flow '{op_name}': payload must be (N,) or (N, L) u32 "
                f"lanes, got ndim={payload.ndim}")
        if payload.ndim == 1:
            payload = payload[:, None]
        payload = payload.astype(_U32)
        n = payload.shape[0]
        if dest.ndim != 1 or dest.shape[0] != n:
            raise ValueError(
                f"flow '{op_name}': dest must be ({n},) to match the "
                f"payload's {n} rows, got shape {tuple(dest.shape)}")
        if int(capacity) <= 0:
            raise ValueError(
                f"flow '{op_name}': capacity must be a positive static "
                f"per-(src,dst) slot count, got {capacity}")
        if int(reply_lanes) < 0:
            raise ValueError(
                f"flow '{op_name}': reply_lanes must be >= 0, "
                f"got {reply_lanes}")
        if valid is None:
            valid = jnp.ones((n,), bool)
        elif valid.ndim != 1 or valid.shape[0] != n:
            raise ValueError(
                f"flow '{op_name}': valid must be ({n},) bool to match "
                f"the payload's {n} rows, got shape {tuple(valid.shape)}")
        if max_rounds is not None and int(max_rounds) < 1:
            raise ValueError(
                f"flow '{op_name}': max_rounds must be >= 1, "
                f"got {max_rounds}")
        self._flows.append(_Flow(payload, dest.astype(_I32), int(capacity),
                                 valid, op_name, int(reply_lanes),
                                 None if max_rounds is None
                                 else int(max_rounds)))
        return len(self._flows) - 1

    def commit(self, backend: Backend, impl: str = "auto",
               max_rounds: int = 1,
               overflow: str = "drop",
               transport: Transport | str | None = None,
               dead_ranks: tuple[int, ...] | None = None,
               integrity: bool = False) -> "CommittedPlan":
        """Issue the request round: one fused all-to-all for all flows.

        ``max_rounds=R`` adds R-1 carryover retry rounds: retry round r
        re-ships the items whose within-bucket rank from the single
        binning pass falls in ``[r*C_f, (r+1)*C_f)``, so owner views see
        an effective capacity of ``R*C_f`` per flow and only rank
        ``>= R*C_f`` counts as dropped.  ``overflow`` picks the residual
        policy: ``"drop"`` (count only), ``"raise-in-test"`` (raise
        :class:`ExchangeOverflowError` when counts are concrete), or
        ``"carry"`` (leftovers stay available via
        :meth:`CommittedPlan.leftover` for caller re-injection).
        ``transport`` picks the physical collective layer (DESIGN.md
        section 1.7): ``None``/``"dense"`` is the one-shot tiled
        all-to-all, ``"hier"`` the two-stage Pr x Pc exchange; a
        :class:`~repro.core.transport.Transport` instance passes
        through.  The logical semantics — admission, owner layout,
        drops, send maps — are transport-independent.

        Degraded operation (DESIGN.md section 1.8): ``dead_ranks`` is a
        static tuple of ranks known to be down; traffic addressed to
        them is masked at admission and handed back as carry-compatible
        leftovers (:meth:`CommittedPlan.unreachable`) instead of being
        shipped into the void, with ``unreachable``/``lost_bytes``
        observables recorded in :mod:`repro.core.costs`.
        ``integrity=True`` appends a synthetic checksum flow to the
        wire (one u32 word per (dest, round, flow) window, riding the
        same launches); windows whose checksum fails verification on
        arrival are invalidated wholesale and surfaced as the per-flow
        ``lost`` count on the views, so corruption feeds the caller's
        ack/carry retry path instead of poisoning owner state.  Both
        default off, leaving the wire byte-identical to a plain commit.
        """
        dead, transport = self._precommit(backend, max_rounds, overflow,
                                          dead_ranks, transport)
        if fine_grained(self.promise):
            return self._commit_fine(backend, impl, int(max_rounds),
                                     overflow, transport, dead, integrity)
        st = self._stage_fused(backend, impl, int(max_rounds), overflow,
                               transport, dead, integrity)
        segments, extra_drop, tctx = transport.request(backend, st.args)
        return self._finalize_fused(backend, st, segments, extra_drop,
                                    tctx, transport)

    def commit_async(self, backend: Backend, impl: str = "auto",
                     max_rounds: int = 1,
                     overflow: str = "drop",
                     transport: Transport | str | None = None,
                     dead_ranks: tuple[int, ...] | None = None,
                     integrity: bool = False) -> "PendingPlan":
        """Split-phase :meth:`commit`: start the wire, defer completion.

        Issues the request's collectives through the transport's
        ``request_start`` and returns a :class:`PendingPlan`; the caller
        traces independent compute in the window before calling
        ``finish()``, which completes the transport wait and yields the
        same :class:`CommittedPlan` a synchronous commit would have —
        bit-identical views, drops, and send maps (DESIGN.md §1.9).
        Retry rounds are double-buffered for free: every round's launch
        is issued at start, so round ``r+1``'s all-to-all is already in
        flight while round ``r``'s arrivals are processed at the wait.

        Cost attribution: the launches record their normal
        collectives/hops/bytes exactly once, at the wait (where the
        owner segments materialize); the start additionally records
        ``overlap_launches`` — the count of collectives whose completion
        was deferred — under the plan op, so logs show HOW MUCH of the
        wire ran split-phase without double-charging any hop.

        The ``Promise.FINE`` oracle stays sequential: under a FINE
        promise the plan commits eagerly (no overlap window, no
        ``overlap_launches``) and the returned PendingPlan is already
        complete — ``finish()`` just unwraps it.
        """
        dead, transport = self._precommit(backend, max_rounds, overflow,
                                          dead_ranks, transport)
        if fine_grained(self.promise):
            return PendingPlan(self, committed=self._commit_fine(
                backend, impl, int(max_rounds), overflow, transport,
                dead, integrity))
        st = self._stage_fused(backend, impl, int(max_rounds), overflow,
                               transport, dead, integrity)
        handle = transport.request_start(backend, st.args)
        return PendingPlan(self, staged=st, handle=handle,
                           transport=transport)

    def _precommit(self, backend: Backend, max_rounds, overflow,
                   dead_ranks, transport):
        """Shared commit/commit_async validation + one-shot latch."""
        if not self._flows:
            raise ValueError("commit() on an empty ExchangePlan")
        if self._committed:
            # a silent second commit would launch a duplicate collective
            # and double-record every cost pin
            raise ValueError("ExchangePlan already committed")
        if int(max_rounds) < 1:
            raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
        if overflow not in OVERFLOW_POLICIES:
            raise ValueError(
                f"overflow must be one of {OVERFLOW_POLICIES}, "
                f"got {overflow!r}")
        dead = tuple(sorted({int(d) for d in (dead_ranks or ())}))
        for d in dead:
            if not 0 <= d < backend.nprocs():
                raise ValueError(
                    f"dead_ranks names rank {d}, outside the "
                    f"{backend.nprocs()}-rank axis")
        self._committed = True
        return dead, make_transport(transport)

    def _commit_fine(self, backend: Backend, impl: str, max_rounds: int,
                     overflow: str, transport: Transport,
                     dead: tuple[int, ...],
                     integrity: bool) -> "CommittedPlan":
        # sequential oracle: one single-flow plan per flow, in
        # registration order; the sub-plans carry the replies so the
        # oracle exercises the SAME transport end to end
        subs = []
        for f in self._flows:
            p = ExchangePlan(name=f.op_name)
            p.add(f.payload, f.dest, f.capacity,
                  reply_lanes=f.reply_lanes, valid=f.valid,
                  op_name=f.op_name)
            subs.append(p.commit(
                backend, impl=impl,
                max_rounds=_flow_rounds(f, max_rounds),
                overflow=overflow, transport=transport,
                dead_ranks=dead, integrity=integrity))
        return CommittedPlan(self, [c.view(0) for c in subs],
                             sequential=True, subplans=subs,
                             dead_ranks=dead)

    # -- fused lowering ---------------------------------------------------

    def _stage_fused(self, backend: Backend, impl: str,
                     max_rounds: int = 1,
                     overflow: str = "drop",
                     transport: Transport = DENSE,
                     dead_ranks: tuple[int, ...] = (),
                     integrity: bool = False) -> "_StagedCommit":
        """Everything that happens BEFORE the wire moves: the one binning
        pass, admission, wire bodies, send maps, and the RequestArgs the
        transport ships.  Shared verbatim by the synchronous commit and
        commit_async, which is what makes the two bit-identical."""
        flows = self._flows
        nprocs = backend.nprocs()
        nflows = len(flows)
        rounds = int(max_rounds)   # validated by commit(), the sole entry
        caps = [f.capacity for f in flows]
        # per-flow effective retry rounds: flow override else plan-wide,
        # clamped to ceil(N_f/C_f) — exactly-sized flows never pay for
        # retry launches their buckets cannot use
        rounds_f = [_flow_rounds(f, rounds) for f in flows]
        # ragged wire: flow f's rows are exactly L_f + 1 words (payload
        # lanes + its own metadata lane) — no cross-flow padding
        roww = [f.lanes + 1 for f in flows]

        dest_all = jnp.concatenate([f.dest for f in flows])
        valid_all = jnp.concatenate([f.valid for f in flows])
        flow_id = jnp.concatenate([
            jnp.full((f.n,), fi, _I32) for fi, f in enumerate(flows)])

        # degraded commit (DESIGN.md section 1.8): traffic toward dead
        # ranks is masked BEFORE admission, so such items never take a
        # send slot — they keep their flow-level validity and surface as
        # carry-compatible leftovers / unreachable() rows instead of
        # shipping into the void (or counting as capacity drops)
        if dead_ranks:
            alive = jnp.ones_like(valid_all)
            for d in dead_ranks:
                alive = alive & (dest_all != d)
            valid_all = valid_all & alive

        # ONE binning pass for every flow AND every retry round:
        # composite (dest, flow) buckets.  Retry round r ships exactly
        # the items with within-bucket rank in [r*C_f, (r+1)*C_f) — a
        # pure mask over these same offsets, never a second pass.  The
        # "exchange.bin" entry is how tests pin that invariant (per-hop
        # re-binning passes inside a transport record their own).
        costs.record("exchange.bin",
                     costs.Cost(local=int(dest_all.shape[0])))
        counts, offsets = kops.multi_bin_offsets(
            dest_all, flow_id, nprocs, nflows, valid_all, impl=impl)
        caps_arr = jnp.asarray(caps, _I32)
        rounds_arr = jnp.asarray(rounds_f, _I32)
        eff_arr = caps_arr * rounds_arr                # effective R_f*C_f
        ok = valid_all & (offsets < eff_arr[flow_id])

        # wire bodies and requester-local slot maps are built ONCE and
        # are TRANSPORT-INDEPENDENT: admission comes from the one
        # binning pass, so every transport ships the same items to the
        # same dense owner slots
        bodies = []
        send_items, send_occs = [], []
        row0 = 0
        for fi, f in enumerate(flows):
            meta = jnp.where(f.valid,
                             _VALID_BIT | jnp.arange(f.n, dtype=_U32), 0)
            bodies.append(jnp.concatenate([f.payload, meta[:, None]],
                                          axis=1))

            # requester-local inverse slot maps in FLOW-local coordinates
            # (d*(R*C_f) + within-bucket rank): identical to the eager
            # layout at capacity R*C_f, so the reply path — fused segment
            # slice or standalone ``reply()`` — resolves slots the same
            # way either way
            cap_e = rounds_f[fi] * f.capacity
            okf = ok[row0:row0 + f.n]
            sl_f = jnp.where(okf,
                             f.dest * cap_e + offsets[row0:row0 + f.n],
                             nprocs * cap_e).astype(_I32)
            # 1-lane in-kernel scatters (kops.place_rows): commit traces
            # zero standalone XLA scatter ops (DESIGN.md section 1.10);
            # values are < 2**31 so the u32 round trip is exact
            send_items.append(kops.place_rows(
                jnp.full((nprocs * cap_e,), f.n, _U32), sl_f,
                jnp.arange(f.n, dtype=_U32)[:, None],
                impl=impl).astype(_I32))
            send_occs.append(kops.place_rows(
                jnp.zeros((nprocs * cap_e,), _U32), sl_f,
                jnp.ones((f.n, 1), _U32), impl=impl) != 0)
            row0 += f.n

        # physical movement: the transport owns the launches, the wire
        # words, and their cost attribution (DESIGN.md section 1.7)
        plan_op = self.name or flows[0].op_name
        specs = [FlowWire(caps[fi], rounds_f[fi], roww[fi],
                          flows[fi].reply_lanes, flows[fi].n,
                          flows[fi].op_name)
                 for fi in range(nflows)]

        if dead_ranks:
            # static degraded-commit observables: how many destinations
            # were masked and the worst-case wire bytes their buckets
            # would have carried (per requesting rank)
            lb = sum(len(dead_ranks) * rounds_f[fi] * caps[fi]
                     * roww[fi] * 4 for fi in range(nflows))
            costs.record(plan_op, costs.Cost(unreachable=len(dead_ranks),
                                             lost_bytes=lb))

        send_dest, send_flow = dest_all, flow_id
        send_off, send_valid = offsets, valid_all
        ck_rmax = 0
        if integrity:
            # synthetic checksum flow (DESIGN.md section 1.8): ONE u32
            # checksum word (+ meta lane) certifying each (dest, round,
            # flow) wire window, riding the SAME launches as the data.
            # Row d*R*F + r*F + f has the analytic within-bucket rank
            # r*F + f at capacity F, so the flow needs no second binning
            # pass; the stored word is SALT + sum of the window's row
            # hashes (u32 wraparound), which the owner recomputes from
            # the arrival segment.
            ck_rmax = max(rounds_f)
            ck_vals = []
            row0 = 0
            for fi, f in enumerate(flows):
                h = kops.mix_rows(bodies[fi], impl=impl)
                rf, cf = rounds_f[fi], caps[fi]
                okf = ok[row0:row0 + f.n]
                seg = jnp.where(
                    okf, f.dest * rf + offsets[row0:row0 + f.n] // cf,
                    nprocs * rf).astype(_I32)
                sums = jax.ops.segment_sum(
                    h, seg, num_segments=nprocs * rf + 1)[:-1] \
                    .reshape(nprocs, rf).astype(_U32)
                if rf < ck_rmax:
                    sums = jnp.pad(sums, ((0, 0), (0, ck_rmax - rf)))
                ck_vals.append(sums)
                row0 += f.n
            ck_lane = (_CK_SALT + jnp.stack(ck_vals, axis=2)).reshape(-1)
            n_ck = nprocs * ck_rmax * nflows
            ck_meta = _VALID_BIT | jnp.arange(n_ck, dtype=_U32)
            bodies.append(jnp.stack([ck_lane, ck_meta], axis=1))
            specs.append(FlowWire(nflows, ck_rmax, 2, 0, n_ck,
                                  "exchange.integrity"))
            ar = jnp.arange(n_ck, dtype=_I32)
            send_dest = jnp.concatenate(
                [dest_all, ar // (ck_rmax * nflows)])
            send_flow = jnp.concatenate(
                [flow_id, jnp.full((n_ck,), nflows, _I32)])
            send_off = jnp.concatenate([offsets, ar % (ck_rmax * nflows)])
            send_valid = jnp.concatenate(
                [valid_all, jnp.ones((n_ck,), bool)])

        return _StagedCommit(
            args=RequestArgs(specs, bodies, send_dest, send_flow,
                             send_off, send_valid, plan_op, impl),
            rounds_f=rounds_f, counts=counts, eff_arr=eff_arr, ok=ok,
            send_items=send_items, send_occs=send_occs,
            overflow=overflow, dead_ranks=dead_ranks,
            integrity=integrity, ck_rmax=ck_rmax, impl=impl)

    def _finalize_fused(self, backend: Backend, st: "_StagedCommit",
                        segments, extra_drop, tctx,
                        transport: Transport) -> "CommittedPlan":
        """Everything that happens AFTER the wire lands: integrity
        verification, overflow accounting, owner views."""
        flows = self._flows
        nprocs = backend.nprocs()
        nflows = len(flows)
        rounds_f, ok, integrity = st.rounds_f, st.ok, st.integrity
        caps = [f.capacity for f in flows]
        impl, ck_rmax = st.impl, st.ck_rmax

        # one psum covers every flow's overflow accounting; only rank
        # >= R_f*C_f is a drop — earlier overflow was carried to a retry.
        # A transport with explicitly undersized stage capacities may
        # drop admitted items too; those counts arrive psum'ed.
        over = jnp.maximum(st.counts - st.eff_arr[None, :], 0).sum(0)  # (F,)
        lost = None
        good_by_flow: list[jax.Array] = []
        if integrity:
            # owner-side verification: recompute each (src, round)
            # window's hash sum from the arrival segment and compare to
            # the stored checksum word.  A failed window (corrupt word,
            # zeroed segment, transport loss) invalidates ALL its
            # arrivals — corrupted items re-enter via the caller's
            # ack/carry retry path instead of being consumed.  The lost
            # count is global sent-minus-survived, folded into the same
            # psum as the overflow counts.
            ck_seg = segments[nflows]
            ck_ok3 = ((ck_seg[:, 1] & _VALID_BIT) != 0) \
                .reshape(nprocs, ck_rmax, nflows)
            ck_val3 = ck_seg[:, 0].reshape(nprocs, ck_rmax, nflows)
            sent, surv = [], []
            row0 = 0
            for fi, f in enumerate(flows):
                rf, cf = rounds_f[fi], caps[fi]
                comp = kops.mix_rows(segments[fi], impl=impl) \
                    .reshape(nprocs, rf, cf).sum(axis=2, dtype=_U32)
                good = (ck_ok3[:, :rf, fi]
                        & (ck_val3[:, :rf, fi] == _CK_SALT + comp))
                good_rows = jnp.repeat(good.reshape(-1), cf)
                good_by_flow.append(good_rows)
                sent.append(ok[row0:row0 + f.n].sum().astype(_I32))
                meta_f = segments[fi][:, f.lanes]
                alive = ((meta_f & _VALID_BIT) != 0) & good_rows
                surv.append(alive.sum().astype(_I32))
                row0 += f.n
            red = backend.psum(jnp.concatenate(
                [over, jnp.stack(sent), jnp.stack(surv)])).astype(_I32)
            dropped = red[:nflows]
            lost = jnp.maximum(red[nflows:2 * nflows]
                               - red[2 * nflows:], 0)
        else:
            dropped = backend.psum(over).astype(_I32)
        if extra_drop is not None:
            dropped = dropped + extra_drop[:nflows]

        views = []
        for fi, f in enumerate(flows):
            cap_e = rounds_f[fi] * f.capacity
            segment = segments[fi]
            pay = segment[:, :f.lanes]
            meta_r = segment[:, f.lanes]
            out_valid = (meta_r & _VALID_BIT) != 0
            if integrity:
                out_valid = out_valid & good_by_flow[fi]
            out_src_pos = (meta_r & _POS_MASK).astype(_I32)
            src_rank = jnp.repeat(jnp.arange(nprocs, dtype=_I32), cap_e)
            views.append(RouteResult(pay, out_valid, src_rank, out_src_pos,
                                     dropped[fi], cap_e,
                                     st.send_items[fi], st.send_occs[fi],
                                     lost[fi] if lost is not None
                                     else jnp.int32(0)))

        if st.overflow == "raise-in-test":
            _raise_on_drops(flows, dropped)

        return CommittedPlan(self, views, sequential=False,
                             transport=transport, tctx=tctx,
                             dead_ranks=st.dead_ranks)


@dataclasses.dataclass
class _StagedCommit:
    """Pre-wire state of a fused commit (shared by sync + async paths).

    ``args`` is what the transport ships; the rest is what
    ``_finalize_fused`` needs once the owner segments land.
    """

    args: RequestArgs
    rounds_f: list[int]
    counts: jax.Array
    eff_arr: jax.Array
    ok: jax.Array
    send_items: list[jax.Array]
    send_occs: list[jax.Array]
    overflow: str
    dead_ranks: tuple[int, ...]
    integrity: bool
    ck_rmax: int
    impl: str


class CommittedPlan:
    """Request round issued; owner-side views available, replies pending."""

    def __init__(self, plan: ExchangePlan, views: list[RouteResult],
                 sequential: bool, transport: Transport | None = None,
                 tctx=None, subplans: list["CommittedPlan"] | None = None,
                 dead_ranks: tuple[int, ...] = ()):
        self._plan = plan
        self._views = views
        self._sequential = sequential
        self._transport = transport        # physical layer (fused path)
        self._tctx = tctx                  # transport's reply context
        self._subplans = subplans or []    # FINE: one sub-plan per flow
        self._dead_ranks = tuple(dead_ranks or ())
        self._replies: dict[int, jax.Array] = {}
        self._finished = False

    def view(self, handle: int) -> RouteResult:
        """Owner-side view of one flow (same layout as eager ``route``)."""
        return self._views[handle]

    def reply_lanes(self, handle: int) -> int:
        """Reply words per row one flow declared at ``add`` (0 = none)."""
        return self._plan._flows[handle].reply_lanes

    def leftover(self, handle: int) -> tuple[jax.Array, jax.Array]:
        """Requester-side overflow carry for one flow.

        Returns ``(payload, mask)`` in the flow's ORIGINAL batch
        coordinates: ``mask[i]`` is True iff item i was valid but never
        shipped (its within-bucket rank fell beyond every round's
        capacity window).  The ``overflow="carry"`` contract: the caller
        re-injects exactly these rows next cycle — the static-shape
        analogue of re-inserting a failed fetch-and-add, which
        ``hashmap_buffer.flush`` uses to make spills lossless.  Derived
        from purely local state (the commit-time send maps), so it costs
        zero collectives and works on fused and FINE schedules alike.
        """
        f = self._plan._flows[handle]
        return f.payload, carry_mask(self._views[handle], f.valid)

    def unreachable(self, handle: int) -> tuple[jax.Array, jax.Array]:
        """Rows addressed to a dead rank (``commit(dead_ranks=...)``).

        Returns ``(payload, mask)`` in the flow's ORIGINAL batch
        coordinates, exactly like :meth:`leftover` — and every
        unreachable row is also IN that leftover mask, since masking at
        admission means it never took a send slot.  This narrower view
        lets recovery code separate "re-inject verbatim next cycle"
        (capacity overflow) from "re-route after the mesh heals" (the
        owner is gone; after ``elastic.plan_remesh`` re-homes the key
        space, these rows are re-inserted against the new owner map).
        Purely local state; zero collectives.
        """
        f = self._plan._flows[handle]
        mask = jnp.zeros((f.n,), bool)
        for d in self._dead_ranks:
            mask = mask | (f.dest == d)
        return f.payload, f.valid & mask

    def set_reply(self, handle: int, rows: jax.Array) -> None:
        """Stage per-request replies for one flow.

        ``rows`` is (P*C_f, reply_lanes) aligned with ``view(handle)``
        rows; lane count must match the flow's declared ``reply_lanes``.
        """
        f = self._plan._flows[handle]
        if rows.ndim == 1:
            rows = rows[:, None]
        if f.reply_lanes == 0:
            raise ValueError(
                f"flow {handle} ({f.op_name}) declared reply_lanes=0")
        if rows.shape[1] != f.reply_lanes:
            raise ValueError(
                f"flow {handle} ({f.op_name}) declared reply_lanes="
                f"{f.reply_lanes}, got {rows.shape[1]}")
        self._replies[handle] = rows.astype(_U32)

    def finish(self, backend: Backend) -> dict[int, tuple[jax.Array, jax.Array]]:
        """Issue the reply round: one fused inverse all-to-all.

        Returns ``{handle: (replies (N_f, reply_lanes), answered (N_f,))}``
        for every flow with ``reply_lanes > 0``; replies land aligned
        with each flow's *original* request batch.
        """
        if self._finished:
            # callers must cache the returned dict; a second finish would
            # launch a duplicate collective and double-record costs
            raise ValueError("CommittedPlan already finished")
        flows = self._plan._flows
        replying = [fi for fi, f in enumerate(flows) if f.reply_lanes > 0]
        for fi in replying:
            if fi not in self._replies:
                raise ValueError(
                    f"finish() before set_reply() for flow {fi} "
                    f"({flows[fi].op_name})")
        self._finished = True
        if not replying:
            return {}

        if self._sequential:
            # FINE oracle: each flow's reply is its own sub-plan finish,
            # through the same transport as its request
            outs = {}
            for fi in replying:
                sub = self._subplans[fi]
                sub.set_reply(0, self._replies[fi])
                outs[fi] = sub.finish(backend)[0]
            return outs

        # owner replies in arrival order, masked to real arrivals; the
        # transport lands them back in the requesters' send slots
        staged = {fi: jnp.where(self._views[fi].valid[:, None],
                                self._replies[fi], 0)
                  for fi in replying}
        slots = self._transport.reply(backend, self._tctx, staged)

        outs = {}
        for fi in replying:
            f = flows[fi]
            view = self._views[fi]
            seg = slots[fi]
            item = jnp.where(view.send_occ, view.send_item, f.n)
            out = jnp.zeros((f.n, f.reply_lanes), _U32).at[item].set(
                seg, mode="drop")
            answered = jnp.zeros((f.n,), bool).at[item].set(
                view.send_occ, mode="drop")
            outs[fi] = (out, answered)
        return outs


class PendingPlan:
    """Future returned by :meth:`ExchangePlan.commit_async`.

    The request's collectives are already in flight (traced into the
    program) when this object exists; ``finish(backend)`` completes the
    transport wait and returns the :class:`CommittedPlan` — bit-identical
    to what the synchronous commit would have produced.  Everything the
    caller traces between the two calls sits in the overlap window.
    """

    def __init__(self, plan: ExchangePlan,
                 committed: CommittedPlan | None = None,
                 staged: _StagedCommit | None = None,
                 handle=None, transport: Transport | None = None):
        self._plan = plan
        self._committed = committed        # FINE oracle: already complete
        self._staged = staged
        self._handle = handle
        self._transport = transport
        self._done = False

    def finish(self, backend: Backend) -> CommittedPlan:
        """Complete the wire; one-shot (a second wait would duplicate
        the transport's completion collectives and cost records)."""
        if self._done:
            raise ValueError("PendingPlan already finished")
        self._done = True
        if self._committed is not None:
            return self._committed
        st = self._staged
        # the deferred launches' collectives/hops/bytes record exactly
        # once, inside request_wait; the start's only extra observable
        # is HOW MANY launches ran split-phase
        costs.record(st.args.plan_op,
                     costs.Cost(overlap_launches=self._handle.launched))
        segments, extra_drop, tctx = self._transport.request_wait(
            backend, self._handle)
        return self._plan._finalize_fused(backend, st, segments,
                                          extra_drop, tctx,
                                          self._transport)


class PendingResult:
    """Future for a container op issued split-phase (``async_=True``).

    Wraps the op's completion closure: the exchange wire is in flight,
    and ``finish()`` runs the owner-side work + reply round, returning
    exactly what the synchronous op would have returned.  One-shot.
    """

    def __init__(self, complete):
        self._complete = complete
        self._done = False

    def finish(self):
        if self._done:
            raise ValueError("PendingResult already finished")
        self._done = True
        out, self._complete = self._complete, None
        return out()


def carry_mask(req: RouteResult, valid: jax.Array) -> jax.Array:
    """Items of the ORIGINAL batch that were valid but never shipped.

    Requester-local: recovered from the route's commit-time send maps
    (an item shipped iff it owns a send slot), so it needs no extra
    collective.  ``route(..., capacity=C, max_rounds=R)`` marks exactly
    the items with within-bucket rank >= R*C — the rows an
    ``overflow="carry"`` caller re-injects next cycle.
    """
    n = valid.shape[0]
    shipped = jnp.zeros((n,), bool).at[
        jnp.where(req.send_occ, req.send_item, n)].set(
        jnp.ones_like(req.send_occ), mode="drop")
    return valid & ~shipped


def _raise_on_drops(flows: list[_Flow], dropped: jax.Array) -> None:
    """``overflow="raise-in-test"``: raise on any concrete drop count."""
    if isinstance(dropped, jax.core.Tracer):
        return          # traced: counts unknowable here; policy degrades
    for fi, f in enumerate(flows):
        if int(dropped[fi]) > 0:
            raise ExchangeOverflowError(
                f"flow '{f.op_name}' dropped {int(dropped[fi])} item(s) "
                f"for capacity overflow (capacity={f.capacity}); raise "
                f"capacity or max_rounds, or use overflow='carry'")


def route(backend: Backend,
          payload: jax.Array,
          dest: jax.Array,
          capacity: int,
          valid: jax.Array | None = None,
          op_name: str = "route",
          impl: str = "auto",
          max_rounds: int = 1,
          overflow: str = "drop",
          transport: Transport | str | None = None,
          dead_ranks: tuple[int, ...] | None = None,
          integrity: bool = False) -> RouteResult:
    """Send each row of ``payload`` to rank ``dest[i]``; return owner view.

    Thin eager wrapper: a single-flow :class:`ExchangePlan`, committed
    immediately.  Wire format, costs, and owner-view layout are exactly
    the fused engine's single-flow case.

    payload: (N, L) u32 (or (N,) — treated as one lane)
    dest:    (N,) i32 destination ranks in [0, nprocs)
    capacity: static per-(src,dst) slot count C
    valid:   (N,) bool mask (default all valid)
    impl:    kernel dispatch for send-buffer construction
             (kops.multi_bin_offsets)
    max_rounds: carryover retry rounds R — the result is bit-identical
             to a single round at capacity R*C (only the cost accounting
             differs: R all-to-all launches off ONE binning pass).
             Clamped to ceil(N/C), past which a round can't ship
             anything new
    overflow: residual policy beyond rank R*C — "drop" | "raise-in-test"
             | "carry" (pair with :func:`carry_mask` on the result)
    transport: physical collective layer ("dense" default; see
             DESIGN.md section 1.7).  Flows needing a reply through a
             non-dense transport should use an :class:`ExchangePlan`
             with ``reply_lanes`` declared — the standalone
             :func:`reply` is the dense inverse all-to-all only.
    dead_ranks / integrity: degraded-operation knobs, forwarded to
             :meth:`ExchangePlan.commit` (DESIGN.md section 1.8).
    """
    plan = ExchangePlan(name=op_name)
    h = plan.add(payload, dest, capacity, valid=valid, op_name=op_name)
    return plan.commit(backend, impl=impl, max_rounds=max_rounds,
                       overflow=overflow, transport=transport,
                       dead_ranks=dead_ranks, integrity=integrity).view(h)


def reply(backend: Backend,
          req: RouteResult,
          reply_payload: jax.Array,
          orig_n: int,
          op_name: str = "reply",
          transport: Transport | str | None = None
          ) -> tuple[jax.Array, jax.Array]:
    """Route per-request replies back to the requesters (single flow).

    ``reply_payload`` is (P*C, L) aligned with ``req.payload`` rows.
    Returns ``(replies, answered)`` where ``replies`` is (orig_n, L)
    aligned with the *original* request batch and ``answered`` marks rows
    that received a reply.

    This is a single inverse all-to-all: the owner's row s*C+j arrived
    from rank s's send slot d*C+j, and the tiled all-to-all maps row
    s*C+j straight back there — so replies written in arrival order need
    no binning, no metadata lanes, and no second slot reservation.  The
    requester resolves slots to batch positions with its local
    ``send_item`` map and knows ``answered`` from its own ``send_occ``.
    Flows of a multi-flow plan should reply through
    ``CommittedPlan.finish`` instead, which fuses every flow's replies
    into ONE such inverse permutation (calling ``reply`` on a fused view
    is semantically correct — the slot maps are flow-local — but launches
    an unfused collective per flow).

    ``transport`` must name the transport the request moved over, and
    only the dense inverse permutation is expressible from a bare
    :class:`RouteResult` — a view routed over a multi-hop transport
    carries per-launch relay state that only the committed plan holds,
    so a non-dense transport raises here and the caller must reply
    through ``finish`` (declare ``reply_lanes`` on the flow).
    """
    tr = make_transport(transport)
    if tr.name != "dense":
        raise ValueError(
            f"reply({op_name!r}): the standalone reply is the dense "
            f"inverse permutation; a flow routed over transport "
            f"{tr.name!r} must declare reply_lanes and reply through "
            f"CommittedPlan.finish, which holds the transport's inverse "
            f"hop state")
    if reply_payload.ndim == 1:
        reply_payload = reply_payload[:, None]
    lanes = reply_payload.shape[1]

    # ride the transport's inverse permutation (one single-flow wire):
    # bit-identical to the pre-transport direct all-to-all, and keeps
    # every physical collective inside core/transport.py
    spec = FlowWire(req.capacity, 1, lanes + 1, lanes, orig_n, op_name)
    staged = {0: jnp.where(req.valid[:, None],
                           reply_payload.astype(_U32), 0)}
    back = tr.reply(backend, _DenseCtx([spec], op_name, "auto"), staged)[0]

    # back[k] answers the item this rank placed in send slot k of the
    # original route call
    item = jnp.where(req.send_occ, req.send_item, orig_n)  # drop sentinel
    out = jnp.zeros((orig_n, lanes), _U32).at[item].set(back, mode="drop")
    answered = jnp.zeros((orig_n,), bool).at[item].set(
        req.send_occ, mode="drop")
    return out, answered


def suggest_rounds(loads, capacity: int, slack: float = 1.0,
                   limit: int = 16) -> int:
    """Heuristic ``max_rounds`` from an observed load trajectory.

    The retry-round analogue of :func:`exchange_capacity` (ROADMAP's
    adaptive-rounds item): given the per-step observed PEAK
    (dest, flow)-bucket loads of recent batches — e.g. ``max
    bucket count`` or ``max expert_load`` readings — pick the smallest
    R whose effective capacity ``R * capacity`` covers the hottest
    bucket seen, times ``slack``.  ``loads`` is a scalar or any
    iterable of scalars (ints, numpy, or concrete jax scalars); the
    result clamps to ``[1, limit]`` so a pathological trajectory cannot
    demand unbounded launches.  Callers with no trajectory yet pass the
    uniform expectation and get 1.
    """
    if capacity <= 0:
        raise ValueError(f"capacity must be positive, got {capacity}")
    try:
        peak = max((int(x) for x in loads), default=0)
    except TypeError:
        peak = int(loads)
    need = -(-int(peak * slack) // int(capacity)) if peak > 0 else 1
    return max(1, min(int(limit), need))


def exchange_capacity(n_per_rank: int, nprocs: int, slack: float = 1.25) -> int:
    """Heuristic static capacity for roughly-uniform traffic.

    Uniform traffic puts ~n/P items in each (src,dst) bucket; ``slack``
    absorbs skew.  Irregular apps (MoE dispatch!) pass explicit
    capacities derived from their own load model instead.
    """
    if nprocs == 1:
        return n_per_rank
    base = (n_per_rank + nprocs - 1) // nprocs
    return max(1, int(base * slack) + 1)
