"""The many-to-many exchange engine — the heart of the TPU port.

Paper section 4.2 identifies "asynchronous many-to-many redistribution"
as the parallel pattern behind queues, buffered hash-table insertion, and
the ISx bucket sort.  On RDMA hardware BCL realizes it as: buffer locally
per destination -> fetch-and-add reserves remote slots -> RDMA put.

On TPU the same pattern is one fused collective program:

  1. bin items by destination rank          (histogram + stable sort)
  2. reserve slots                          (exclusive prefix sums — the
                                             associative, contention-free
                                             analogue of fetch-and-add)
  3. pad each destination bucket to a
     static capacity C                      (SPMD shapes are static)
  4. one tiled all-to-all moves everything  (latency-bound -> bandwidth-
                                             bound, which is exactly the
                                             HashMapBuffer insight)
  5. unmask on the owner

``route`` is that program.  Every container op with a remote component
compiles down to one or two ``route`` calls, mirroring the paper's claim
that each data-structure op is "a small number of one-sided operations".

All payloads are u32 lane matrices (see object_container.py).  Shapes and
capacities are static; overflow beyond C is dropped and *counted* (the
analogue of a failed/retried insertion), so callers can assert zero drops
or size capacities adaptively.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend

_U32 = jnp.uint32
_I32 = jnp.int32


class RouteResult(NamedTuple):
    """Owner-side view of a routed batch.

    payload   (P*C, L) u32 — rows [s*C:(s+1)*C] arrived from rank s
    valid     (P*C,) bool  — which rows hold real items
    src_rank  (P*C,) i32   — originating rank (derived from slot position)
    src_pos   (P*C,) i32   — item's index in the sender's original batch
    dropped   () i32       — items dropped for capacity overflow (global)
    capacity  int          — static per-(src,dst) capacity C
    """

    payload: jax.Array
    valid: jax.Array
    src_rank: jax.Array
    src_pos: jax.Array
    dropped: jax.Array
    capacity: int


def _bin_by_dest(dest: jax.Array, valid: jax.Array, nprocs: int):
    """Stable binning: per-dest counts, sort order, position-within-dest."""
    n = dest.shape[0]
    dest_ = jnp.where(valid, dest.astype(_I32), nprocs)  # invalid -> bucket P
    counts_full = jnp.zeros((nprocs + 1,), _I32).at[dest_].add(1)
    start = jnp.concatenate([jnp.zeros((1,), _I32),
                             jnp.cumsum(counts_full)[:-1].astype(_I32)])
    order = jnp.argsort(dest_, stable=True)
    sorted_dest = dest_[order]
    pos = jnp.arange(n, dtype=_I32) - start[sorted_dest]
    return counts_full[:nprocs], order, sorted_dest, pos


def route(backend: Backend,
          payload: jax.Array,
          dest: jax.Array,
          capacity: int,
          valid: jax.Array | None = None,
          op_name: str = "route") -> RouteResult:
    """Send each row of ``payload`` to rank ``dest[i]``; return owner view.

    payload: (N, L) u32 (or (N,) — treated as one lane)
    dest:    (N,) i32 destination ranks in [0, nprocs)
    capacity: static per-(src,dst) slot count C
    valid:   (N,) bool mask (default all valid)
    """
    if payload.ndim == 1:
        payload = payload[:, None]
    payload = payload.astype(_U32)
    n, lanes = payload.shape
    nprocs = backend.nprocs()
    cap = int(capacity)

    if valid is None:
        valid = jnp.ones((n,), bool)

    counts, order, sorted_dest, pos = _bin_by_dest(dest, valid, nprocs)

    # drop sentinel: one past the end of the send buffer
    in_cap = pos < cap
    slot = jnp.where((sorted_dest < nprocs) & in_cap,
                     sorted_dest * cap + pos,
                     nprocs * cap).astype(_I32)

    # lanes layout: [payload | src_pos | valid]
    src_pos_lane = order.astype(_U32)[:, None]
    valid_lane = jnp.ones((n, 1), _U32)
    body = jnp.concatenate([payload[order], src_pos_lane, valid_lane], axis=1)

    send = jnp.zeros((nprocs * cap, lanes + 2), _U32)
    send = send.at[slot].set(body, mode="drop")

    recv = backend.all_to_all(send)

    out_payload = recv[:, :lanes]
    out_src_pos = recv[:, lanes].astype(_I32)
    out_valid = recv[:, lanes + 1] == 1
    src_rank = jnp.repeat(jnp.arange(nprocs, dtype=_I32), cap)

    over = jnp.maximum(counts - cap, 0).sum()
    dropped = backend.psum(over).astype(_I32)

    # route records only the TPU observables; the paper-units cost (R/W/A)
    # is accounted by the calling container op.
    costs.record(op_name, costs.Cost(
        collectives=1, bytes_moved=nprocs * cap * (lanes + 2) * 4))

    return RouteResult(out_payload, out_valid, src_rank, out_src_pos,
                       dropped, cap)


def reply(backend: Backend,
          req: RouteResult,
          reply_payload: jax.Array,
          orig_n: int,
          op_name: str = "reply") -> tuple[jax.Array, jax.Array]:
    """Route per-request replies back to the requesters.

    ``reply_payload`` is (P*C, L) aligned with ``req.payload`` rows.
    Returns ``(replies, answered)`` where ``replies`` is (orig_n, L)
    aligned with the *original* request batch and ``answered`` marks rows
    that received a reply.
    """
    if reply_payload.ndim == 1:
        reply_payload = reply_payload[:, None]
    lanes = reply_payload.shape[1]

    body = jnp.concatenate(
        [reply_payload.astype(_U32), req.src_pos.astype(_U32)[:, None]], axis=1)
    back = route(backend, body, dest=req.src_rank, capacity=req.capacity,
                 valid=req.valid, op_name=op_name)

    out = jnp.zeros((orig_n, lanes), _U32)
    answered = jnp.zeros((orig_n,), bool)
    pos = jnp.where(back.valid, back.payload[:, lanes].astype(_I32), orig_n)
    out = out.at[pos].set(back.payload[:, :lanes], mode="drop")
    answered = answered.at[pos].set(back.valid, mode="drop")
    return out, answered


def exchange_capacity(n_per_rank: int, nprocs: int, slack: float = 1.25) -> int:
    """Heuristic static capacity for roughly-uniform traffic.

    Uniform traffic puts ~n/P items in each (src,dst) bucket; ``slack``
    absorbs skew.  Irregular apps (MoE dispatch!) pass explicit
    capacities derived from their own load model instead.
    """
    if nprocs == 1:
        return n_per_rank
    base = (n_per_rank + nprocs - 1) // nprocs
    return max(1, int(base * slack) + 1)
