"""The many-to-many exchange engine — the heart of the TPU port.

Paper section 4.2 identifies "asynchronous many-to-many redistribution"
as the parallel pattern behind queues, buffered hash-table insertion, and
the ISx bucket sort.  On RDMA hardware BCL realizes it as: buffer locally
per destination -> fetch-and-add reserves remote slots -> RDMA put.

On TPU the same pattern is one fused collective program:

  1. bin items by destination rank          (histogram + per-tile prefix +
                                             slot scatter — a Pallas
                                             kernel, no argsort)
  2. reserve slots                          (exclusive prefix sums — the
                                             associative, contention-free
                                             analogue of fetch-and-add)
  3. pad each destination bucket to a
     static capacity C                      (SPMD shapes are static)
  4. one tiled all-to-all moves everything  (latency-bound -> bandwidth-
                                             bound, which is exactly the
                                             HashMapBuffer insight)
  5. unmask on the owner

Scheduling is two-phase (DESIGN.md section 1.5): callers register typed
*flows* on an :class:`ExchangePlan` (``plan.add(payload, dest, capacity,
reply_lanes, op_name)``), and ``plan.commit(backend)`` concatenates all
same-round flows lane-wise into ONE binning pass and ONE tiled
all-to-all, demultiplexing per-flow owner views; replies from every flow
share one inverse all-to-all (``plan.finish``).  This is the paper's
concurrency-promise story made operational: a promise names which ops
may run concurrently, and concurrent ops are exactly the ops whose
flows may share a collective round.  ``Promise.FINE`` on the plan
forces the sequential one-op-per-round schedule — the oracle every
fused path is tested against.

``route``/``reply`` remain as thin single-flow wrappers, so a container
op that has nothing to fuse with still compiles to the same program it
always did.

Wire format (DESIGN.md section 1): payloads are u32 lane matrices (see
object_container.py).  A plan's request buffer has, per destination
rank, one contiguous *segment per flow* of that flow's static capacity;
rows are ``max(flow lanes) + 1`` u32 lanes wide, the last lane being the
single shared metadata lane — bit 31 is the valid flag and the low 31
bits are the item's position in its flow's batch.  Replies cost
``max(reply lanes)`` lanes and zero metadata: the owner's receive
layout is the exact image of the requesters' send layout under the
all-to-all, so writing replies into segment-order rows and running one
more all-to-all is an *inverse permutation* that lands every reply back
in the requester's original send slot.  The requester resolves slots to
batch positions from purely local state captured at commit time; no
binning, no argsort, and no src_pos lane in the reply direction.

Shapes and capacities are static; overflow beyond a flow's capacity is
dropped and *counted* per flow (the analogue of a failed/retried
insertion), so callers can assert zero drops or size capacities
adaptively.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import costs
from repro.core.backend import Backend
from repro.core.promises import Promise, fine_grained, validate
from repro.kernels import ops as kops

_U32 = jnp.uint32
_I32 = jnp.int32

# metadata lane: bit 31 = valid, bits 0..30 = src_pos
_VALID_BIT = jnp.uint32(1 << 31)
_POS_MASK = jnp.uint32((1 << 31) - 1)


class RouteResult(NamedTuple):
    """Owner-side view of a routed flow (+ requester-local slot map).

    payload   (P*C, L) u32 — rows [s*C:(s+1)*C] arrived from rank s
    valid     (P*C,) bool  — which rows hold real items
    src_rank  (P*C,) i32   — originating rank (derived from slot position)
    src_pos   (P*C,) i32   — item's index in the sender's original batch
    dropped   () i32       — items dropped for capacity overflow (global)
    capacity  int          — static per-(src,dst) capacity C
    send_item (P*C,) i32   — requester-local: original batch index this
                             rank placed in each of its own send slots,
                             in flow-local coordinates (sentinel N when
                             the slot was empty); identical whether the
                             flow was routed eagerly or as a segment of
                             a fused plan
    send_occ  (P*C,) bool  — requester-local send-slot occupancy; the
                             reply path's ``answered`` comes from here,
                             not from the wire
    """

    payload: jax.Array
    valid: jax.Array
    src_rank: jax.Array
    src_pos: jax.Array
    dropped: jax.Array
    capacity: int
    send_item: jax.Array
    send_occ: jax.Array


@dataclasses.dataclass
class _Flow:
    """One registered flow of an ExchangePlan (trace-time record)."""

    payload: jax.Array        # (N, L) u32
    dest: jax.Array           # (N,) i32
    capacity: int             # per-(src,dst) slot count C_f
    valid: jax.Array          # (N,) bool
    op_name: str
    reply_lanes: int          # 0 = fire-and-forget (no reply expected)

    @property
    def n(self) -> int:
        return self.payload.shape[0]

    @property
    def lanes(self) -> int:
        return self.payload.shape[1]


class ExchangePlan:
    """Two-phase scheduler fusing concurrent container ops' collectives.

    Usage::

        plan = ExchangePlan(name="hashmap.find_insert")
        h_f = plan.add(find_body, owners_f, cap, reply_lanes=Lv + 1,
                       op_name="hashmap.find")
        h_i = plan.add(ins_body, owners_i, cap, reply_lanes=1,
                       op_name="hashmap.insert")
        c = plan.commit(backend)          # ONE all-to-all for all flows
        ... owner-side work on c.view(h_f), c.view(h_i) ...
        c.set_reply(h_f, find_replies)
        c.set_reply(h_i, ok_bits)
        outs = c.finish(backend)          # ONE inverse all-to-all
        find_out, find_answered = outs[h_f]

    Cost attribution (DESIGN.md section 1.5): each flow is charged the
    bytes of its own wire segment (its capacity x the fused lane width)
    under its ``op_name``; the single physical collective and its round
    are charged once, under ``name`` (default: the first flow's op).

    A plan constructed with ``promise=Promise.FINE`` lowers to the
    sequential one-op-per-round schedule instead (one ``route`` and one
    ``reply`` per flow) — the semantic oracle for the fused schedule.
    """

    def __init__(self, promise: Promise = Promise.NONE,
                 name: str | None = None):
        validate(promise)
        self.promise = promise
        self.name = name
        self._flows: list[_Flow] = []
        self._committed = False

    def add(self, payload: jax.Array, dest: jax.Array, capacity: int,
            reply_lanes: int = 0, valid: jax.Array | None = None,
            op_name: str = "flow") -> int:
        """Register a flow; returns its handle (index into the plan)."""
        if self._committed:
            raise ValueError(
                "add() after commit(): the round's flows are already on "
                "the wire; build a new ExchangePlan for the next round")
        if payload.ndim == 1:
            payload = payload[:, None]
        payload = payload.astype(_U32)
        n = payload.shape[0]
        if valid is None:
            valid = jnp.ones((n,), bool)
        self._flows.append(_Flow(payload, dest.astype(_I32), int(capacity),
                                 valid, op_name, int(reply_lanes)))
        return len(self._flows) - 1

    def commit(self, backend: Backend, impl: str = "auto") -> "CommittedPlan":
        """Issue the request round: one fused all-to-all for all flows."""
        if not self._flows:
            raise ValueError("commit() on an empty ExchangePlan")
        if self._committed:
            # a silent second commit would launch a duplicate collective
            # and double-record every cost pin
            raise ValueError("ExchangePlan already committed")
        self._committed = True
        if fine_grained(self.promise):
            views = [route(backend, f.payload, f.dest, f.capacity,
                           valid=f.valid, op_name=f.op_name, impl=impl)
                     for f in self._flows]
            return CommittedPlan(self, views, sequential=True)
        return self._commit_fused(backend, impl)

    # -- fused lowering ---------------------------------------------------

    def _commit_fused(self, backend: Backend, impl: str) -> "CommittedPlan":
        flows = self._flows
        nprocs = backend.nprocs()
        nflows = len(flows)
        caps = [f.capacity for f in flows]
        seg = [0]
        for c in caps:
            seg.append(seg[-1] + c)
        ctot = seg[-1]
        wl = max(f.lanes for f in flows) + 1          # + shared meta lane

        dest_all = jnp.concatenate([f.dest for f in flows])
        valid_all = jnp.concatenate([f.valid for f in flows])
        flow_id = jnp.concatenate([
            jnp.full((f.n,), fi, _I32) for fi, f in enumerate(flows)])

        # ONE binning pass for every flow: composite (dest, flow) buckets
        counts, offsets = kops.multi_bin_offsets(
            dest_all, flow_id, nprocs, nflows, valid_all, impl=impl)
        caps_arr = jnp.asarray(caps, _I32)
        seg_arr = jnp.asarray(seg[:-1], _I32)
        in_cap = offsets < caps_arr[flow_id]
        ok = valid_all & in_cap
        slot = jnp.where(ok, dest_all * ctot + seg_arr[flow_id] + offsets,
                         nprocs * ctot).astype(_I32)   # drop sentinel

        # reply layout: only replying flows get a segment (compact wire)
        replying = [fi for fi, f in enumerate(flows) if f.reply_lanes > 0]
        seg_r = {}
        ctot_r = 0
        for fi in replying:
            seg_r[fi] = ctot_r
            ctot_r += caps[fi]

        send = jnp.zeros((nprocs * ctot, wl), _U32)
        send_items, send_occs = [], []
        row0 = 0
        for fi, f in enumerate(flows):
            sl = slot[row0:row0 + f.n]
            meta = jnp.where(f.valid,
                             _VALID_BIT | jnp.arange(f.n, dtype=_U32), 0)
            body = f.payload
            if f.lanes < wl - 1:
                body = jnp.concatenate(
                    [body, jnp.zeros((f.n, wl - 1 - f.lanes), _U32)], axis=1)
            body = jnp.concatenate([body, meta[:, None]], axis=1)
            send = send.at[sl].set(body, mode="drop")

            # requester-local inverse slot maps in FLOW-local coordinates
            # (d*C_f + within-bucket rank): identical to the eager layout,
            # so the reply path — fused segment slice or standalone
            # ``reply()`` — resolves slots the same way either way
            okf = ok[row0:row0 + f.n]
            sl_f = jnp.where(okf,
                             f.dest * f.capacity + offsets[row0:row0 + f.n],
                             nprocs * f.capacity).astype(_I32)
            send_items.append(jnp.full((nprocs * f.capacity,), f.n, _I32)
                              .at[sl_f].set(jnp.arange(f.n, dtype=_I32),
                                            mode="drop"))
            send_occs.append(jnp.zeros((nprocs * f.capacity,), bool)
                             .at[sl_f].set(jnp.ones((f.n,), bool),
                                           mode="drop"))
            row0 += f.n

        recv = backend.all_to_all(send)

        # one psum covers every flow's overflow accounting
        over = jnp.maximum(counts - caps_arr[None, :], 0).sum(0)   # (F,)
        dropped = backend.psum(over).astype(_I32)

        r3 = recv.reshape(nprocs, ctot, wl)
        views = []
        for fi, f in enumerate(flows):
            segment = r3[:, seg[fi]:seg[fi] + f.capacity, :]
            pay = segment[..., :f.lanes].reshape(nprocs * f.capacity, f.lanes)
            meta_r = segment[..., wl - 1].reshape(-1)
            out_valid = (meta_r & _VALID_BIT) != 0
            out_src_pos = (meta_r & _POS_MASK).astype(_I32)
            src_rank = jnp.repeat(jnp.arange(nprocs, dtype=_I32), f.capacity)
            views.append(RouteResult(pay, out_valid, src_rank, out_src_pos,
                                     dropped[fi], f.capacity,
                                     send_items[fi], send_occs[fi]))

        # cost attribution: per-flow wire-segment share; the physical
        # collective and its round once, under the plan's op name
        plan_op = self.name or flows[0].op_name
        for f in flows:
            fb = nprocs * f.capacity * wl * 4
            costs.record(f.op_name, costs.Cost(
                bytes_moved=fb, bytes_out=fb))
        costs.record(plan_op, costs.Cost(collectives=1, rounds=1))

        return CommittedPlan(self, views, sequential=False, ctot_r=ctot_r,
                             seg_r=seg_r)


class CommittedPlan:
    """Request round issued; owner-side views available, replies pending."""

    def __init__(self, plan: ExchangePlan, views: list[RouteResult],
                 sequential: bool, ctot_r: int = 0,
                 seg_r: dict | None = None):
        self._plan = plan
        self._views = views
        self._sequential = sequential
        self._ctot_r = ctot_r
        self._seg_r = seg_r or {}
        self._replies: dict[int, jax.Array] = {}
        self._finished = False

    def view(self, handle: int) -> RouteResult:
        """Owner-side view of one flow (same layout as eager ``route``)."""
        return self._views[handle]

    def set_reply(self, handle: int, rows: jax.Array) -> None:
        """Stage per-request replies for one flow.

        ``rows`` is (P*C_f, reply_lanes) aligned with ``view(handle)``
        rows; lane count must match the flow's declared ``reply_lanes``.
        """
        f = self._plan._flows[handle]
        if rows.ndim == 1:
            rows = rows[:, None]
        if f.reply_lanes == 0:
            raise ValueError(
                f"flow {handle} ({f.op_name}) declared reply_lanes=0")
        if rows.shape[1] != f.reply_lanes:
            raise ValueError(
                f"flow {handle} ({f.op_name}) declared reply_lanes="
                f"{f.reply_lanes}, got {rows.shape[1]}")
        self._replies[handle] = rows.astype(_U32)

    def finish(self, backend: Backend) -> dict[int, tuple[jax.Array, jax.Array]]:
        """Issue the reply round: one fused inverse all-to-all.

        Returns ``{handle: (replies (N_f, reply_lanes), answered (N_f,))}``
        for every flow with ``reply_lanes > 0``; replies land aligned
        with each flow's *original* request batch.
        """
        if self._finished:
            # callers must cache the returned dict; a second finish would
            # launch a duplicate collective and double-record costs
            raise ValueError("CommittedPlan already finished")
        flows = self._plan._flows
        replying = [fi for fi, f in enumerate(flows) if f.reply_lanes > 0]
        for fi in replying:
            if fi not in self._replies:
                raise ValueError(
                    f"finish() before set_reply() for flow {fi} "
                    f"({flows[fi].op_name})")
        self._finished = True
        if not replying:
            return {}

        if self._sequential:
            outs = {}
            for fi in replying:
                f = flows[fi]
                outs[fi] = reply(backend, self._views[fi], self._replies[fi],
                                 f.n, op_name=f.op_name)
            return outs

        nprocs = backend.nprocs()
        ctot_r = self._ctot_r
        wr = max(flows[fi].reply_lanes for fi in replying)
        send = jnp.zeros((nprocs * ctot_r, wr), _U32)
        for fi in replying:
            f = flows[fi]
            view = self._views[fi]
            rows = jnp.where(view.valid[:, None], self._replies[fi], 0)
            # owner arrival row s*C_f + j  ->  reply row s*ctot_r + seg + j
            ar = jnp.arange(nprocs * f.capacity, dtype=_I32)
            idx = (ar // f.capacity) * ctot_r + self._seg_r[fi] \
                + (ar % f.capacity)
            send = send.at[idx, :f.reply_lanes].set(rows)

        back = backend.all_to_all(send)

        # the inverse all-to-all lands flow f's replies in its own
        # segment of each source block; slicing the segment recovers the
        # flow-local slot layout, so the view's send maps resolve it
        back3 = back.reshape(nprocs, ctot_r, wr)
        outs = {}
        for fi in replying:
            f = flows[fi]
            view = self._views[fi]
            seg = back3[:, self._seg_r[fi]:self._seg_r[fi] + f.capacity, :]
            seg = seg.reshape(nprocs * f.capacity, wr)
            item = jnp.where(view.send_occ, view.send_item, f.n)
            out = jnp.zeros((f.n, wr), _U32).at[item].set(seg, mode="drop")
            answered = jnp.zeros((f.n,), bool).at[item].set(
                view.send_occ, mode="drop")
            outs[fi] = (out[:, :f.reply_lanes], answered)

        plan_op = self._plan.name or flows[0].op_name
        for fi in replying:
            fb = nprocs * flows[fi].capacity * wr * 4
            costs.record(flows[fi].op_name, costs.Cost(
                bytes_moved=fb, bytes_in=fb))
        costs.record(plan_op, costs.Cost(collectives=1, rounds=1))
        return outs


def route(backend: Backend,
          payload: jax.Array,
          dest: jax.Array,
          capacity: int,
          valid: jax.Array | None = None,
          op_name: str = "route",
          impl: str = "auto") -> RouteResult:
    """Send each row of ``payload`` to rank ``dest[i]``; return owner view.

    Thin eager wrapper: a single-flow :class:`ExchangePlan`, committed
    immediately.  Wire format, costs, and owner-view layout are exactly
    the fused engine's single-flow case.

    payload: (N, L) u32 (or (N,) — treated as one lane)
    dest:    (N,) i32 destination ranks in [0, nprocs)
    capacity: static per-(src,dst) slot count C
    valid:   (N,) bool mask (default all valid)
    impl:    kernel dispatch for send-buffer construction
             (kops.multi_bin_offsets)
    """
    plan = ExchangePlan(name=op_name)
    h = plan.add(payload, dest, capacity, valid=valid, op_name=op_name)
    return plan._commit_fused(backend, impl).view(h)


def reply(backend: Backend,
          req: RouteResult,
          reply_payload: jax.Array,
          orig_n: int,
          op_name: str = "reply") -> tuple[jax.Array, jax.Array]:
    """Route per-request replies back to the requesters (single flow).

    ``reply_payload`` is (P*C, L) aligned with ``req.payload`` rows.
    Returns ``(replies, answered)`` where ``replies`` is (orig_n, L)
    aligned with the *original* request batch and ``answered`` marks rows
    that received a reply.

    This is a single inverse all-to-all: the owner's row s*C+j arrived
    from rank s's send slot d*C+j, and the tiled all-to-all maps row
    s*C+j straight back there — so replies written in arrival order need
    no binning, no metadata lanes, and no second slot reservation.  The
    requester resolves slots to batch positions with its local
    ``send_item`` map and knows ``answered`` from its own ``send_occ``.
    Flows of a multi-flow plan should reply through
    ``CommittedPlan.finish`` instead, which fuses every flow's replies
    into ONE such inverse permutation (calling ``reply`` on a fused view
    is semantically correct — the slot maps are flow-local — but launches
    an unfused collective per flow).
    """
    if reply_payload.ndim == 1:
        reply_payload = reply_payload[:, None]
    lanes = reply_payload.shape[1]

    send = jnp.where(req.valid[:, None], reply_payload.astype(_U32), 0)
    back = backend.all_to_all(send)

    # back[k] answers the item this rank placed in send slot k of the
    # original route call
    item = jnp.where(req.send_occ, req.send_item, orig_n)  # drop sentinel
    out = jnp.zeros((orig_n, lanes), _U32).at[item].set(back, mode="drop")
    answered = jnp.zeros((orig_n,), bool).at[item].set(
        req.send_occ, mode="drop")

    wire_bytes = send.shape[0] * lanes * 4
    costs.record(op_name, costs.Cost(
        collectives=1, rounds=1, bytes_moved=wire_bytes,
        bytes_in=wire_bytes))
    return out, answered


def exchange_capacity(n_per_rank: int, nprocs: int, slack: float = 1.25) -> int:
    """Heuristic static capacity for roughly-uniform traffic.

    Uniform traffic puts ~n/P items in each (src,dst) bucket; ``slack``
    absorbs skew.  Irregular apps (MoE dispatch!) pass explicit
    capacities derived from their own load model instead.
    """
    if nprocs == 1:
        return n_per_rank
    base = (n_per_rank + nprocs - 1) // nprocs
    return max(1, int(base * slack) + 1)
