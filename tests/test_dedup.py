"""Data-pipeline dedup on the containers (the k-mer pipeline re-skinned)."""

import numpy as np

from repro.core import costs, get_backend
from repro.data.dedup import Deduper, DedupSpec


def test_exact_duplicates_flagged(rng):
    d = Deduper(get_backend(None), DedupSpec(ngram=4, dup_threshold=0.5))
    docs = rng.integers(0, 1000, (4, 64)).astype(np.int32)
    frac1, dup1 = d.observe(docs)
    assert not dup1.any()                      # first sighting: fresh
    frac2, dup2 = d.observe(docs.copy())       # resubmitted verbatim
    assert dup2.all()
    assert (frac2 > 0.95).all()


def test_fresh_docs_pass(rng):
    d = Deduper(get_backend(None), DedupSpec(ngram=4))
    a = rng.integers(0, 10000, (4, 64)).astype(np.int32)
    b = rng.integers(10000, 20000, (4, 64)).astype(np.int32)
    d.observe(a)
    frac, dup = d.observe(b)
    assert not dup.any()
    assert (frac < 0.1).all()


def test_partial_overlap_measured(rng):
    d = Deduper(get_backend(None), DedupSpec(ngram=4, dup_threshold=0.4))
    base = rng.integers(0, 1000, (1, 64)).astype(np.int32)
    d.observe(base)
    half = base.copy()
    half[0, 32:] = rng.integers(2000, 3000, 32)
    frac, dup = d.observe(half)
    assert 0.25 < frac[0] < 0.75


def test_observe_and_probe_fused_pair(rng):
    """The contamination-check path: bloom insert + find share one plan
    (2 collectives), and the probe sees this batch's insertions."""
    d = Deduper(get_backend(None), DedupSpec(ngram=4))
    train = rng.integers(0, 1000, (2, 64)).astype(np.int32)
    with costs.recording() as log:
        frac, dup, probe_frac = d.observe_and_probe(train, train.copy())
    # the fused bloom pair is exactly one round trip
    assert log.by_op("bloom.insert_find").collectives == 2
    assert not dup.any()                    # first sighting: fresh
    assert (probe_frac > 0.95).all()        # probe sees the fresh inserts

    # fresh probe docs stay unseen; previously observed docs stay seen
    nxt = rng.integers(2000, 3000, (2, 64)).astype(np.int32)
    fresh = rng.integers(5000, 9000, (2, 64)).astype(np.int32)
    _, _, pf = d.observe_and_probe(nxt, fresh)
    assert (pf < 0.1).all()
    _, _, pf2 = d.observe_and_probe(
        rng.integers(3000, 4000, (2, 64)).astype(np.int32), train)
    assert (pf2 > 0.95).all()


def test_counts_accumulate(rng):
    d = Deduper(get_backend(None), DedupSpec(ngram=4))
    doc = rng.integers(0, 500, (1, 32)).astype(np.int32)
    for _ in range(3):
        d.observe(doc)
    counts = d.count_of(doc)
    # seen 3 times: bloom ate the 1st, table counted the next 2 (+1 base)
    assert (counts >= 3).all()


def test_retry_rounds_same_results_fraction_of_wire(rng):
    """max_rounds=R sizes each launch at ceil(m/R) wire rows: identical
    dedup verdicts, with extra launches buying an R-fold narrower
    per-round footprint (rounds x capacity still covers the batch)."""
    docs = rng.integers(0, 1000, (4, 64)).astype(np.int32)
    again = docs.copy()
    outs = []
    byts = []
    for r in (1, 4):
        d = Deduper(get_backend(None),
                    DedupSpec(ngram=4, dup_threshold=0.5, max_rounds=r))
        with costs.recording() as log:
            frac1, dup1 = d.observe(docs)
            frac2, dup2 = d.observe(again)
        outs.append((frac1, dup1, frac2, dup2))
        byts.append(log.by_op("bloom.insert").bytes_out)
    for a, b in zip(outs[0], outs[1]):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # base-round wire share shrinks ~R-fold (retry share is separate)
    assert byts[1] * 3 < byts[0]
