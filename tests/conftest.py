"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 real device;
multi-device coverage runs in subprocesses (test_multidevice.py)."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def mesh11():
    from repro.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))
