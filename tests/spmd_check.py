"""Multi-device correctness battery; run under 8 fake CPU devices.

Invoked by test_multidevice.py as a subprocess (the parent test process
keeps its 1-device world).  Exits non-zero on any failure.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS
from jax.sharding import PartitionSpec as P

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.compat import make_mesh, shard_map

from repro.core import ConProm, Promise, get_backend, route
from repro.containers import bloom as bl
from repro.containers import hashmap as hm
from repro.containers import queue as q


def check(name, ok):
    print(f"{'PASS' if ok else 'FAIL'} {name}")
    if not ok:
        sys.exit(1)


def main():
    assert len(jax.devices()) == 8
    mesh = make_mesh((8,), ("bcl",))
    np.random.seed(0)
    PROCS, NLOC = 8, 64

    # ---- hashmap across devices vs dict oracle ----
    def build_and_query(keys, vals, queries):
        bk = get_backend("bcl")
        spec, st = hm.hashmap_create(bk, 8192, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        st, ok = hm.insert(bk, spec, st, keys, vals, capacity=NLOC)
        st, v, found = hm.find(bk, spec, st, queries, capacity=NLOC)
        return ok, v, found

    keys = jnp.asarray(np.random.permutation(1 << 20)[:PROCS * NLOC],
                       jnp.uint32)
    vals = keys * 7 + 1
    queries = jnp.concatenate([keys[:PROCS * NLOC // 2],
                               keys[:PROCS * NLOC // 2] + (1 << 21)])
    f = jax.jit(shard_map(build_and_query, mesh=mesh,
                              in_specs=(P("bcl"),) * 3,
                              out_specs=(P("bcl"),) * 3))
    ok, v, found = f(keys, vals, queries)
    nf, nv, nq = map(np.asarray, (found, v, queries))
    present = nq < (1 << 21)
    check("hashmap.insert_all", bool(np.asarray(ok).all()))
    check("hashmap.find_present", bool(nf[present].all()))
    check("hashmap.find_absent", not bool(nf[~present].any()))
    check("hashmap.values", bool((nv[present] == nq[present] * 7 + 1).all()))

    # ---- ISx-style queue exchange preserves the multiset ----
    def isx(values, dest):
        bk = get_backend("bcl")
        spec, st = q.queue_create(bk, 512, SDS((), jnp.uint32))
        st, _, dropped = q.push(bk, spec, st, values, dest, capacity=128)
        rows, got = q.local_drain(spec, st)
        return rows, got, dropped[None]

    vals2 = jnp.asarray(np.random.randint(0, 1 << 20, PROCS * 100),
                        jnp.uint32)
    dest2 = (vals2 // ((1 << 20) // 8)).astype(jnp.int32).clip(0, 7)
    g = jax.jit(shard_map(isx, mesh=mesh, in_specs=(P("bcl"),) * 2,
                              out_specs=(P("bcl"),) * 3))
    rows, got, dropped = g(vals2, dest2)
    rec = np.asarray(rows)[np.asarray(got)]
    check("queue.multiset",
          sorted(rec.tolist()) == sorted(np.asarray(vals2).tolist()))
    check("queue.nodrop", int(np.asarray(dropped).sum()) == 0)
    # destination correctness: each received value belongs to its rank
    rows2 = np.asarray(rows).reshape(8, -1)
    got2 = np.asarray(got).reshape(8, -1)
    ok_dest = all(
        (rows2[r][got2[r]] // ((1 << 20) // 8)).clip(0, 7).astype(int)
        .tolist() == [r] * got2[r].sum() for r in range(8))
    check("queue.destinations", ok_dest)

    # ---- fused plans == Promise.FINE oracle on 8 ranks, random data ----
    def fused_or_fine(fine):
        extra = Promise.FINE if fine else Promise.NONE

        def body(keys, vals, fk, ik, iv, qv, qd):
            bk = get_backend("bcl")
            spec, st = hm.hashmap_create(bk, 8192, SDS((), jnp.uint32),
                                         SDS((), jnp.uint32), block_size=16)
            st, _ = hm.insert(bk, spec, st, keys, vals, capacity=NLOC)
            st, v, f, ok = hm.find_insert(
                bk, spec, st, fk, ik, iv, capacity=NLOC,
                promise=ConProm.HashMap.find_insert | extra)
            qspec, qst = q.queue_create(bk, 512, SDS((), jnp.uint32),
                                        circular=True)
            # every rank pops its right neighbor's ring
            nbr = (jax.lax.axis_index("bcl") + 1) % PROCS
            qst, pushed, dropped, out, got = q.push_pop(
                bk, qspec, qst, qv, qd, 32, 24, nbr,
                promise=ConProm.CircularQueue.push_pop | extra)
            return (v, f, ok, out, got, pushed[None], dropped[None],
                    st.status)

        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("bcl"),) * 7,
                                 out_specs=(P("bcl"),) * 8))

    rngf = np.random.default_rng(42)
    base = jnp.asarray(rngf.permutation(1 << 20)[:PROCS * NLOC], jnp.uint32)
    fi_args = (base, base * 5 + 2,
               jnp.asarray(np.where(rngf.random(PROCS * NLOC) < 0.5,
                                    np.asarray(base),
                                    np.asarray(base) + (1 << 21)),
                           jnp.uint32),
               jnp.asarray(rngf.permutation(1 << 20)[:PROCS * NLOC]
                           + (1 << 21), jnp.uint32),
               jnp.asarray(rngf.integers(0, 1 << 30, PROCS * NLOC),
                           jnp.uint32),
               jnp.asarray(rngf.integers(0, 1 << 30, PROCS * 64), jnp.uint32),
               jnp.asarray(rngf.integers(0, PROCS, PROCS * 64), jnp.int32))
    got_fused = fused_or_fine(False)(*fi_args)
    got_fine = fused_or_fine(True)(*fi_args)
    check("plan.fused_equals_fine_8rank",
          all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(got_fused, got_fine)))

    # ---- ragged mixed-width plan == FINE on 8 ranks, random dests ----
    # a 1-lane flow and a 3-lane flow with different reply widths share
    # one plan under carryover retries: the ragged wire (per-flow word
    # segments, DESIGN.md section 1.5) must be bit-identical to the
    # sequential oracle on views, replies, and drop counts.
    from repro.core import ExchangePlan

    def ragged_or_fine(fine):
        extra = Promise.FINE if fine else Promise.NONE

        def body(p1, p3, d1, d3):
            bk = get_backend("bcl")
            plan = ExchangePlan(promise=extra, name="ragged")
            h1 = plan.add(p1, d1, 8, reply_lanes=1, op_name="narrow")
            h3 = plan.add(p3, d3, 8, reply_lanes=2, op_name="wide")
            c = plan.commit(bk, max_rounds=2)
            c.set_reply(h1, c.view(h1).payload[:, 0] * 3 + 1)
            c.set_reply(h3, c.view(h3).payload[:, :2] + 5)
            outs = c.finish(bk)
            v1, v3 = c.view(h1), c.view(h3)
            return (outs[h1][0], outs[h1][1], outs[h3][0], outs[h3][1],
                    v1.payload, v1.valid, v3.payload, v3.valid,
                    v1.dropped[None], v3.dropped[None])

        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("bcl"),) * 4,
                                 out_specs=(P("bcl"),) * 10))

    rr = np.random.default_rng(23)
    rg_args = (jnp.asarray(rr.integers(0, 1 << 30, PROCS * 96), jnp.uint32),
               jnp.asarray(rr.integers(0, 1 << 30, (PROCS * 48, 3)),
                           jnp.uint32),
               jnp.asarray(rr.integers(0, PROCS, PROCS * 96), jnp.int32),
               jnp.asarray(rr.integers(0, PROCS, PROCS * 48), jnp.int32))
    got_rf = ragged_or_fine(False)(*rg_args)
    got_rs = ragged_or_fine(True)(*rg_args)
    check("plan.ragged_equals_fine_8rank",
          all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(got_rf, got_rs)))

    # ---- split-phase commit_async == sync commit on 8 ranks ----
    # the same mixed-width plan issued split-phase (DESIGN.md section
    # 1.9): commit_async starts the wire, finish() completes it — views,
    # replies, answered masks, and drop counts must be bit-identical to
    # the one-shot commit above, on both physical transports (the
    # hierarchical one overlaps its two hops across retry rounds).
    def ragged_async(transport):
        def body(p1, p3, d1, d3):
            bk = get_backend("bcl")
            plan = ExchangePlan(name="ragged")
            h1 = plan.add(p1, d1, 8, reply_lanes=1, op_name="narrow")
            h3 = plan.add(p3, d3, 8, reply_lanes=2, op_name="wide")
            c = plan.commit_async(bk, max_rounds=2,
                                  transport=transport).finish(bk)
            c.set_reply(h1, c.view(h1).payload[:, 0] * 3 + 1)
            c.set_reply(h3, c.view(h3).payload[:, :2] + 5)
            outs = c.finish(bk)
            v1, v3 = c.view(h1), c.view(h3)
            return (outs[h1][0], outs[h1][1], outs[h3][0], outs[h3][1],
                    v1.payload, v1.valid, v3.payload, v3.valid,
                    v1.dropped[None], v3.dropped[None])

        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("bcl"),) * 4,
                                 out_specs=(P("bcl"),) * 10))

    for tag, tr_a in (("plan.async_equals_sync_8rank", None),
                      ("plan.async_equals_sync_8rank_hier", "hier")):
        got_ra = ragged_async(tr_a)(*rg_args)
        check(tag, all(np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(got_ra, got_rf)))

    # ---- zipf-skewed destinations: retry rounds make push lossless ----
    # mean-load capacity (n_loc / P) with zipf destination draws: the
    # hot rank overflows every (src, hot) bucket; carryover retries
    # recover exactly the overflow, with no second binning pass.
    n_loc = 128
    zw = 1.0 / (np.arange(1, PROCS + 1) ** 1.3)
    zdest = np.random.default_rng(13).choice(
        PROCS, size=PROCS * n_loc, p=zw / zw.sum())
    zvals = jnp.asarray(np.arange(PROCS * n_loc), jnp.uint32)
    zdest = jnp.asarray(zdest, jnp.int32)
    mean_cap = n_loc // PROCS

    def zpush(rounds):
        def body(values, dest):
            bk = get_backend("bcl")
            spec, st = q.queue_create(bk, 4 * n_loc, SDS((), jnp.uint32))
            st, _, dropped = q.push(bk, spec, st, values, dest,
                                    capacity=mean_cap, max_rounds=rounds)
            rows, got = q.local_drain(spec, st)
            return rows, got, dropped[None]

        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("bcl"),) * 2,
                                 out_specs=(P("bcl"),) * 3))(zvals, zdest)

    _, _, zdrop1 = zpush(1)
    zrows, zgot, zdrop8 = zpush(8)
    rec = np.asarray(zrows)[np.asarray(zgot)]
    check("exchange.zipf_drop_mode_loses", int(np.asarray(zdrop1).sum()) > 0)
    check("exchange.zipf_retry_lossless",
          int(np.asarray(zdrop8).sum()) == 0 and
          sorted(rec.tolist()) == sorted(np.asarray(zvals).tolist()))

    # ---- hierarchical transport == dense on a 2-D factorization ----
    # the full container battery (hashmap find/insert/find_insert, queue
    # push/pop/push_pop, bloom insert_find, a raw retry plan) over the
    # two-stage Pr x Pc exchange must be bit-identical to the dense
    # one-shot all-to-all (DESIGN.md section 1.7)
    from repro.core import HierarchicalTransport, costs as _costs

    def transport_battery(transport):
        def body(keys, vals, fk, ik, iv, qv, qd, p3, d3):
            bk = get_backend("bcl")
            spec, st = hm.hashmap_create(bk, 8192, SDS((), jnp.uint32),
                                         SDS((), jnp.uint32), block_size=16)
            st, ins_ok = hm.insert(bk, spec, st, keys, vals, capacity=NLOC,
                                   transport=transport)
            st, fv, ff = hm.find(bk, spec, st, fk, capacity=NLOC,
                                 transport=transport)
            st, v, f, ok = hm.find_insert(
                bk, spec, st, fk, ik, iv, capacity=NLOC,
                promise=ConProm.HashMap.find_insert, transport=transport)
            qspec, qst = q.queue_create(bk, 512, SDS((), jnp.uint32),
                                        circular=True)
            nbr = (jax.lax.axis_index("bcl") + 1) % PROCS
            qst, pushed, dropped, out, got = q.push_pop(
                bk, qspec, qst, qv, qd, 32, 24, nbr,
                promise=ConProm.CircularQueue.push_pop,
                transport=transport)
            qst, pv, pg = q.pop(bk, qspec, qst, 8, nbr,
                                transport=transport)
            bspec, bst = bl.bloom_create(bk, 1 << 14, SDS((), jnp.uint32),
                                         k=4)
            bst, already, present = bl.insert_find(
                bk, bspec, bst, qv, fk, 64, NLOC, transport=transport)
            # raw plan with carryover retry rounds (max_rounds > 1)
            plan = ExchangePlan(name="retry3")
            h3 = plan.add(p3, d3, 8, reply_lanes=2, op_name="retry3")
            c = plan.commit(bk, max_rounds=3, transport=transport)
            c.set_reply(h3, c.view(h3).payload[:, :2] + 9)
            o3 = c.finish(bk)[h3]
            v3 = c.view(h3)
            return (ins_ok, fv, ff, v, f, ok, pushed[None], dropped[None],
                    out, got, pv, pg, already, present, st.status,
                    bst.words, o3[0], o3[1], v3.payload, v3.valid,
                    v3.dropped[None])

        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("bcl"),) * 9,
                                 out_specs=(P("bcl"),) * 21))

    tb_args = fi_args + (rg_args[1], rg_args[3])
    got_dense = transport_battery(None)(*tb_args)
    for pr, pc in ((2, 4), (4, 2)):
        got_hier = transport_battery(HierarchicalTransport(pr, pc))(*tb_args)
        check(f"exchange.hier_equals_dense_8rank_{pr}x{pc}",
              all(np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(got_dense, got_hier)))

    # ---- one-kernel wire == scatter oracle on 8 ranks (§1.10) ----
    # the SAME container battery run with impl="jnp" (the declared
    # fallback wire, object_container.scatter_rows) and impl="pallas"
    # (the fused slot+pack kernel that builds the wire in one pass):
    # dense and two-stage transports, one-shot and split-phase commits,
    # integrity checksums on, carryover retry rounds — every output must
    # be bit-identical, raw table state included.
    def wire_battery(impl, transport, split):
        def body(keys, vals, fk, ik, iv, qv, qd, p3, d3):
            bk = get_backend("bcl")
            spec, st = hm.hashmap_create(bk, 8192, SDS((), jnp.uint32),
                                         SDS((), jnp.uint32), block_size=16,
                                         impl=impl)
            st, ins_ok = hm.insert(bk, spec, st, keys, vals, capacity=NLOC,
                                   max_rounds=2, transport=transport,
                                   integrity=True)
            st, fv, ff = hm.find(bk, spec, st, fk, capacity=NLOC,
                                 transport=transport, integrity=True)
            fi = hm.find_insert(
                bk, spec, st, fk, ik, iv, capacity=NLOC,
                promise=ConProm.HashMap.find_insert, transport=transport,
                integrity=True, async_=split)
            st, v, f, ok = fi.finish() if split else fi
            qspec, qst = q.queue_create(bk, 512, SDS((), jnp.uint32),
                                        circular=True)
            nbr = (jax.lax.axis_index("bcl") + 1) % PROCS
            pp = q.push_pop(bk, qspec, qst, qv, qd, 32, 24, nbr,
                            promise=ConProm.CircularQueue.push_pop,
                            transport=transport, integrity=True,
                            impl=impl, async_=split)
            qst, pushed, dropped, out, got = pp.finish() if split else pp
            # raw plan with carryover retries, integrity on
            plan = ExchangePlan(name="wire3")
            h3 = plan.add(p3, d3, 8, reply_lanes=2, op_name="wire3")
            if split:
                c = plan.commit_async(bk, impl=impl, max_rounds=3,
                                      transport=transport,
                                      integrity=True).finish(bk)
            else:
                c = plan.commit(bk, impl=impl, max_rounds=3,
                                transport=transport, integrity=True)
            c.set_reply(h3, c.view(h3).payload[:, :2] + 9)
            o3 = c.finish(bk)[h3]
            v3 = c.view(h3)
            return (ins_ok, fv, ff, v, f, ok, pushed[None], dropped[None],
                    out, got, st.tkeys, st.tvals, st.status,
                    o3[0], o3[1], v3.payload, v3.valid, v3.dropped[None])

        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("bcl"),) * 9,
                                 out_specs=(P("bcl"),) * 18))

    for tag, tr_w, split in (("dense_sync", None, False),
                             ("dense_async", None, True),
                             ("hier_sync", HierarchicalTransport(2, 4),
                              False),
                             ("hier_async", HierarchicalTransport(2, 4),
                              True)):
        got_sc = wire_battery("jnp", tr_w, split)(*tb_args)
        got_fu = wire_battery("pallas", tr_w, split)(*tb_args)
        check(f"wire.fused_equals_scatter_8rank_{tag}",
              all(np.array_equal(np.asarray(a), np.asarray(b))
                  for a, b in zip(got_sc, got_fu)))

    # faults arm: the same seeded corruption (integrity checksums on)
    # must produce bit-identical arrivals AND loss accounting on both
    # wires — fusion may not move bytes across checksum windows
    from repro.core import (FaultInjectingTransport as _FIT,
                            FaultSpec as _FSpec,
                            make_transport as _mk_tr)

    def wire_fault(impl):
        ftr = _FIT(_mk_tr("dense"), _FSpec(seed=7, corrupt=((0, 2, 5),)))

        def body(pay, dst):
            bk = get_backend("bcl")
            res = route(bk, pay, dst, capacity=64, op_name="wf", impl=impl,
                        transport=ftr, integrity=True)
            return res.payload, res.valid, res.lost[None], res.dropped[None]

        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("bcl"),) * 2,
                                 out_specs=(P("bcl"),) * 4))

    wf_rng = np.random.default_rng(9)
    wf_pay = jnp.asarray(wf_rng.integers(0, 1 << 30, (PROCS * 64, 2)),
                         jnp.uint32)
    wf_dst = jnp.asarray(wf_rng.integers(0, PROCS, PROCS * 64), jnp.int32)
    got_wj = wire_fault("jnp")(wf_pay, wf_dst)
    got_wp = wire_fault("pallas")(wf_pay, wf_dst)
    check("wire.fused_equals_scatter_faults",
          all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(got_wj, got_wp))
          and int(np.asarray(got_wj[2]).sum()) > 0)

    # ---- per-hop byte attribution + the sparse-destination wire pin ----
    # every rank sends all n items to ONE rank ((r+1) % 8): per-stage
    # loads are 8, so explicit stage caps (8, 8) are lossless while the
    # dense wire must pad EVERY (src, dst) pair to the hottest bucket.
    # 4-lane rows: hier = Pc*c1*(L+2) + Pr*c2*(L+2) = 48*6 words/rank,
    # dense = P*C*(L+1) = 64*5 — the two-stage wire is strictly below.
    n_sp, lanes_sp = 8, 4
    sp_pay = jnp.asarray(
        np.random.default_rng(5).integers(0, 1 << 19,
                                          (PROCS * n_sp, lanes_sp)),
        jnp.uint32)

    def sparse_push(transport):
        def body(pay):
            bk = get_backend("bcl")
            dest = jnp.full((n_sp,), (jax.lax.axis_index("bcl") + 1)
                            % PROCS, jnp.int32)
            res = route(bk, pay, dest, capacity=n_sp, op_name="sp",
                        transport=transport)
            return res.payload, res.valid, res.dropped[None]

        with _costs.recording() as log:
            out = jax.jit(shard_map(body, mesh=mesh,
                                    in_specs=(P("bcl"),),
                                    out_specs=(P("bcl"),) * 3))(sp_pay)
        return out, log

    hier_sp = HierarchicalTransport(2, 4, stage_caps={"sp": (8, 8)})
    (dp, dv, dd), dlog = sparse_push(None)
    (hp, hv, hd), hlog = sparse_push(hier_sp)
    check("exchange.hier_sparse_results_equal",
          np.array_equal(np.asarray(dp), np.asarray(hp))
          and np.array_equal(np.asarray(dv), np.asarray(hv))
          and int(np.asarray(hd).sum()) == 0)
    dense_words = PROCS * n_sp * (lanes_sp + 1)
    hier_words = (4 * 8 + 2 * 8) * (lanes_sp + 2)
    c_d, c_h = dlog.by_op("sp"), hlog.by_op("sp")
    c_rel = hlog.by_op("sp.relay")
    check("exchange.hier_hop_bytes_exact",
          c_h.bytes_out == 4 * 8 * (lanes_sp + 2) * 4
          and c_rel.bytes_out == 2 * 8 * (lanes_sp + 2) * 4
          and c_h.hops == 2 and c_h.collectives == 2
          and c_d.hops == 1 and c_d.collectives == 1)
    check("exchange.hier_sparse_wire_below_dense",
          c_h.bytes_out + c_rel.bytes_out < c_d.bytes_out
          and hier_words < dense_words
          and c_h.bytes_out + c_rel.bytes_out == hier_words * 4
          and c_d.bytes_out == dense_words * 4)

    # ---- bloom: distributed atomicity of duplicate insertion ----
    def bloomdup(items):
        bk = get_backend("bcl")
        spec, st = bl.bloom_create(bk, 1 << 16, SDS((), jnp.uint32), k=4)
        st, already = bl.insert(bk, spec, st, items, capacity=64)
        return already

    dup = jnp.full((PROCS * 16,), 777, jnp.uint32)
    fb = jax.jit(shard_map(bloomdup, mesh=mesh, in_specs=(P("bcl"),),
                               out_specs=P("bcl")))
    already = np.asarray(fb(dup))
    check("bloom.dup_atomicity", int((~already).sum()) == 1)

    # ---- SPMD == serial semantics (portability across backends) ----
    def serial_hashmap(keys, vals, queries):
        bk = get_backend(None)
        spec, st = hm.hashmap_create(bk, 8192, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        st, _ = hm.insert(bk, spec, st, keys, vals, capacity=len(keys))
        st, v, found = hm.find(bk, spec, st, queries, capacity=len(queries))
        return v, found

    vs, fs = serial_hashmap(keys, vals, queries)
    check("portability.same_found",
          np.array_equal(np.asarray(fs), nf))
    check("portability.same_values",
          np.array_equal(np.asarray(vs)[np.asarray(fs)], nv[nf]))

    # ---- mini production-style dry-run on a (2,4) mesh ----
    from repro.configs import get_config, reduced
    from repro.configs.shapes import ShapeSpec, input_specs
    from repro.launch.steps import (batch_shardings, make_train_step,
                                    train_shardings)
    mesh2 = make_mesh((2, 4), ("data", "model"))
    for arch in ("qwen3-4b", "arctic-480b"):
        cfg = reduced(get_config(arch), n_heads=4, n_kv_heads=4,
                      d_model=64, vocab=512)
        shape = ShapeSpec("t", 64, 4, "train")
        specs = input_specs(cfg, shape)
        pshape, oshape, psh, osh = train_shardings(cfg, mesh2)
        bsh = batch_shardings(cfg, mesh2, specs)
        step = make_train_step(cfg, mesh2)
        compiled = jax.jit(step, in_shardings=(psh, osh, bsh),
                           out_shardings=(psh, osh, None)).lower(
            pshape, oshape, specs).compile()
        check(f"mini_dryrun.{arch}",
              compiled.memory_analysis() is not None)

    # ---- MoE exchange dispatch == dense-expert reference ----
    def moe_equiv():
        import dataclasses
        from repro.models import moe as moe_mod
        from repro.models.sharding import Axes
        cfg = reduced(get_config("arctic-480b"), d_model=32, vocab=256)
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2,
                                         expert_d_ff=16),
            moe_capacity_slack=8.0)
        rng = jax.random.PRNGKey(0)
        params = moe_mod.moe_init(rng, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
        axes8 = Axes.from_mesh(mesh2)
        y_spmd, _, st_spmd = moe_mod.moe_apply(params, x, cfg, mesh2, axes8)

        mesh1 = make_mesh((1, 1), ("data", "model"))
        axes1 = Axes.from_mesh(mesh1)
        y_ser, _, st_ser = moe_mod.moe_apply(params, x, cfg, mesh1, axes1)
        cfg_dd = dataclasses.replace(cfg, moe_dedup_dispatch=True)
        y_dd, _, st_dd = moe_mod.moe_apply(params, x, cfg_dd, mesh2, axes8)
        n_assign = x.shape[0] * x.shape[1] * cfg.moe.top_k
        # the fused stats flow reports true global served counts: with
        # ample capacity every assignment is served, on every schedule
        loads_ok = all(
            float(st["expert_load"].sum()) == n_assign
            for st in (st_spmd, st_ser, st_dd))
        loads_eq = np.array_equal(np.asarray(st_spmd["expert_load"]),
                                  np.asarray(st_ser["expert_load"]))
        return (np.allclose(np.asarray(y_spmd), np.asarray(y_ser),
                            atol=1e-4),
                np.allclose(np.asarray(y_dd), np.asarray(y_ser),
                            atol=1e-4),
                loads_ok and loads_eq)

    eq_std, eq_dd, eq_load = moe_equiv()
    check("moe.spmd_equals_serial", eq_std)
    check("moe.dedup_dispatch_parity", eq_dd)
    check("moe.stats_flow_load", eq_load)

    # ---- GPipe pipeline: 4 stages over a 'stage' axis == sequential ----
    from repro.parallel import gpipe
    smesh = make_mesh((4,), ("stage",))
    ws = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8)) * 0.4
    xmb = jax.random.normal(jax.random.PRNGKey(1), (6, 2, 8))

    def stage(params, xx):
        return jnp.tanh(xx @ params)

    out = gpipe(stage, ws, xmb, smesh, axis="stage")
    expect = xmb
    for i in range(4):
        expect = jnp.tanh(expect @ ws[i])
    check("gpipe.4stage_sequential_parity",
          bool(np.allclose(np.asarray(out), np.asarray(expect),
                           atol=1e-5)))

    # ---- ISx weak scaling shape: per-rank keys constant, 8 ranks ----
    def isx_weak(values):
        bk = get_backend("bcl")
        spec, st = q.queue_create(bk, 2048, SDS((), jnp.uint32))
        dest = (values // ((1 << 20) // 8)).astype(jnp.int32).clip(0, 7)
        st, _, dropped = q.push(bk, spec, st, values, dest, capacity=512)
        rows, got = q.local_drain(spec, st)
        return jnp.sort(jnp.where(got, rows, jnp.uint32(0xFFFFFFFF))), \
            got.sum()[None]

    keys8 = jnp.asarray(np.random.randint(0, 1 << 20, 8 * 1024), jnp.uint32)
    fw = jax.jit(shard_map(isx_weak, mesh=mesh, in_specs=(P("bcl"),),
                               out_specs=(P("bcl"), P("bcl"))))
    srted, counts = fw(keys8)
    merged = np.asarray(srted).reshape(8, -1)
    cnts = np.asarray(counts)
    glob = np.concatenate([merged[r][: cnts[r]] for r in range(8)])
    check("isx.weak_scaling_sorted",
          np.array_equal(np.sort(np.asarray(keys8)), np.sort(glob)) and
          all(np.all(np.diff(merged[r][: cnts[r]]) >= 0) for r in range(8)))

    # ---- elastic checkpoint: save on (2,4), restore onto (4,2) ----
    import tempfile
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from jax.sharding import NamedSharding
    mesh_a = make_mesh((2, 4), ("data", "model"))
    mesh_b = make_mesh((4, 2), ("data", "model"))
    w = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    w_a = jax.device_put(w, NamedSharding(mesh_a, P("data", "model")))
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, 3, {"w": w_a})
        got, step = restore_checkpoint(
            td, None, {"w": jnp.zeros((8, 8))},
            shardings={"w": NamedSharding(mesh_b, P("data", "model"))})
    ok_val = np.array_equal(np.asarray(got["w"]), np.asarray(w))
    ok_shard = got["w"].sharding.mesh.shape["data"] == 4
    check("elastic.reshard_on_restore", ok_val and ok_shard and step == 3)

    # ---- chaos battery: kill / corrupt / checkpoint-recover (§1.8) ----
    # A rank dies mid-run.  The battery drives the full recovery story:
    # phase A builds containers and checkpoints their exported state;
    # phase B keeps running against the dead rank (degraded commit +
    # fault-injected wire + integrity checksums) and pins EXACTLY which
    # inserts ack; the FT control plane detects the silence, plans the
    # remesh, and does not re-fail anyone on the next tick; recovery
    # restores every shard from the checkpoint (the survivors re-inject
    # the dead rank's shard) and replays the killed batch — the final
    # container state is bit-identical to a run where nothing died.
    from repro.core import FaultInjectingTransport, FaultSpec, make_transport
    from repro.core.hashing import hash_lanes
    from repro.containers.hashmap import (export_state as hm_export,
                                          restore_state as hm_restore)
    from repro.containers.queue import (export_state as q_export,
                                        restore_state as q_restore)
    from repro.runtime.elastic import plan_remesh
    from repro.runtime.ft import FaultToleranceManager

    KILLED = 3
    crng = np.random.default_rng(3)
    cperm = crng.permutation(1 << 20)
    b1k = jnp.asarray(cperm[:PROCS * NLOC], jnp.uint32)
    b2k = jnp.asarray(cperm[PROCS * NLOC:2 * PROCS * NLOC], jnp.uint32)
    b1v, b2v = b1k * 11 + 3, b2k * 11 + 3
    qv1 = jnp.asarray(crng.integers(0, 1 << 30, PROCS * 48), jnp.uint32)
    qd1 = jnp.asarray(crng.integers(0, PROCS, PROCS * 48), jnp.int32)
    qv2 = jnp.asarray(crng.integers(0, 1 << 30, PROCS * 48), jnp.uint32)
    qd2 = jnp.asarray(crng.integers(0, PROCS, PROCS * 48), jnp.int32)

    def hm_fresh(bk):
        return hm.hashmap_create(bk, 8192, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=16)

    def phase_a(k1, v1, qv, qd):
        bk = get_backend("bcl")
        spec, st = hm_fresh(bk)
        st, ok = hm.insert(bk, spec, st, k1, v1, capacity=NLOC)
        qspec, qst = q.queue_create(bk, 512, SDS((), jnp.uint32))
        qst, _, qdrop = q.push(bk, qspec, qst, qv, qd, capacity=96)
        ex, qex = hm_export(spec, st), q_export(qspec, qst)
        return (ok, qdrop[None], ex["tkeys"], ex["tvals"], ex["status"],
                qex["data"], qex["head"], qex["tail"], qex["tail_ready"],
                qex["head_ready"])

    a = jax.jit(shard_map(phase_a, mesh=mesh, in_specs=(P("bcl"),) * 4,
                          out_specs=(P("bcl"),) * 10))(b1k, b1v, qv1, qd1)
    check("chaos.phase_a_clean", bool(np.asarray(a[0]).all())
          and int(np.asarray(a[1]).sum()) == 0)
    ck_tree = {"hm": {"tkeys": a[2], "tvals": a[3], "status": a[4]},
               "q": {"data": a[5], "head": a[6], "tail": a[7],
                     "tail_ready": a[8], "head_ready": a[9]}}

    # phase B: rank KILLED dies.  Its memory is gone, its wire sends
    # arrive as zeros (FaultSpec kill), and the plan is committed
    # degraded (dead_ranks).  The integrity checksums turn the zeroed
    # segments into invalid arrivals instead of silent garbage, so the
    # ack mask is EXACT: an insert succeeded iff neither its source nor
    # its attempt-0 owner is the dead rank.
    ktr = FaultInjectingTransport(make_transport("dense"),
                                  FaultSpec(seed=11, kill_ranks=(KILLED,)))

    def phase_b(tk, tv, stt, k2, v2):
        bk = get_backend("bcl")
        spec, _ = hm_fresh(bk)
        dead = jax.lax.axis_index("bcl") == KILLED
        st = hm.HashMapState(
            jnp.where(dead, jnp.zeros_like(tk), tk),
            jnp.where(dead, jnp.zeros_like(tv), tv),
            jnp.where(dead, jnp.zeros_like(stt), stt))
        st, ok2 = hm.insert(bk, spec, st, k2, v2, capacity=NLOC,
                            attempts=1, transport=ktr,
                            dead_ranks=(KILLED,), integrity=True)
        return ok2

    ok2 = jax.jit(shard_map(phase_b, mesh=mesh, in_specs=(P("bcl"),) * 5,
                            out_specs=P("bcl")))(a[2], a[3], a[4], b2k, b2v)
    g0 = np.asarray(hash_lanes(b2k[:, None], seed=1)) % 512
    owner0 = g0 // 64                       # 512 blocks, 64 per rank
    src = np.repeat(np.arange(PROCS), NLOC)
    expect_ok = (src != KILLED) & (owner0 != KILLED)
    check("chaos.kill_acks_exact",
          np.array_equal(np.asarray(ok2), expect_ok)
          and int((~expect_ok).sum()) > 0)

    # the FT control plane sees the silence, plans recovery, and the
    # promoted world is stable on the next tick
    ftm = FaultToleranceManager(n_nodes=PROCS, heartbeat_interval=1.0,
                                timeout_beats=2)
    for nd in range(PROCS):
        ftm.heartbeat(nd, 0.0)
    for nd in range(PROCS):
        if nd != KILLED:
            ftm.heartbeat(nd, 2.5)
    dec = ftm.tick(2.5, last_ckpt_step=1)
    check("chaos.ft_detects_kill",
          dec.action == "restart" and dec.failed_nodes == [KILLED]
          and dec.restart_step == 1)
    rplan = plan_remesh(("data", "model"), (PROCS, 1), PROCS - 1)
    check("chaos.remesh_plan",
          rplan.new_shape == (PROCS - 1, 1) and rplan.dropped_devices == 0
          and abs(rplan.batch_per_shard_scale - PROCS / (PROCS - 1)) < 1e-9)
    for nd in range(PROCS):
        if nd != KILLED:
            ftm.heartbeat(nd, 3.0)
    check("chaos.no_refail_next_tick",
          ftm.tick(3.1, last_ckpt_step=1).action == "none")

    # recovery: restore every shard from the checkpoint (survivors
    # re-inject the dead rank's shard via restore_state), replay the
    # killed batch, and compare against the fault-free reference
    with tempfile.TemporaryDirectory() as td:
        save_checkpoint(td, dec.restart_step, ck_tree)
        like = jax.tree_util.tree_map(jnp.zeros_like, ck_tree)
        got_ck, rstep = restore_checkpoint(td, None, like)
    check("chaos.ckpt_roundtrip", rstep == dec.restart_step)

    def recover(tk, tv, stt, qdata, qh, qt, qtr_, qhr, k2, v2, qv, qd):
        bk = get_backend("bcl")
        spec, _ = hm_fresh(bk)
        st = hm_restore(spec, {"tkeys": tk, "tvals": tv, "status": stt})
        qspec, _ = q.queue_create(bk, 512, SDS((), jnp.uint32))
        qst = q_restore(qspec, {"data": qdata, "head": qh, "tail": qt,
                                "tail_ready": qtr_, "head_ready": qhr})
        st, ok = hm.insert(bk, spec, st, k2, v2, capacity=NLOC)
        qst, _, qdrop = q.push(bk, qspec, qst, qv, qd, capacity=96)
        ex, qex = hm_export(spec, st), q_export(qspec, qst)
        return (ok, qdrop[None], ex["tkeys"], ex["tvals"], ex["status"],
                qex["data"], qex["head"], qex["tail"])

    def reference(k1, v1, k2, v2, qva, qda, qvb, qdb):
        bk = get_backend("bcl")
        spec, st = hm_fresh(bk)
        st, _ = hm.insert(bk, spec, st, k1, v1, capacity=NLOC)
        st, _ = hm.insert(bk, spec, st, k2, v2, capacity=NLOC)
        qspec, qst = q.queue_create(bk, 512, SDS((), jnp.uint32))
        qst, _, _ = q.push(bk, qspec, qst, qva, qda, capacity=96)
        qst, _, _ = q.push(bk, qspec, qst, qvb, qdb, capacity=96)
        ex, qex = hm_export(spec, st), q_export(qspec, qst)
        return (ex["tkeys"], ex["tvals"], ex["status"],
                qex["data"], qex["head"], qex["tail"])

    rec = jax.jit(shard_map(recover, mesh=mesh, in_specs=(P("bcl"),) * 12,
                            out_specs=(P("bcl"),) * 8))(
        jnp.asarray(got_ck["hm"]["tkeys"]), jnp.asarray(got_ck["hm"]["tvals"]),
        jnp.asarray(got_ck["hm"]["status"]), jnp.asarray(got_ck["q"]["data"]),
        jnp.asarray(got_ck["q"]["head"]), jnp.asarray(got_ck["q"]["tail"]),
        jnp.asarray(got_ck["q"]["tail_ready"]),
        jnp.asarray(got_ck["q"]["head_ready"]),
        b2k, b2v, qv2, qd2)
    ref = jax.jit(shard_map(reference, mesh=mesh, in_specs=(P("bcl"),) * 8,
                            out_specs=(P("bcl"),) * 6))(
        b1k, b1v, b2k, b2v, qv1, qd1, qv2, qd2)
    check("chaos.recovery_replay_clean", bool(np.asarray(rec[0]).all())
          and int(np.asarray(rec[1]).sum()) == 0)
    check("chaos.recovered_bit_identical",
          all(np.array_equal(np.asarray(x), np.asarray(y))
              for x, y in zip(rec[2:], ref)))

    # ---- corruption: integrity + carry heals, no-retry loses loudly ----
    cspec = FaultSpec(seed=7, corrupt=((0, 2, 5),))
    lrng = np.random.default_rng(3)
    lv = jnp.asarray(lrng.integers(0, 1 << 30, PROCS * 64), jnp.uint32)
    ld = jnp.asarray(lrng.integers(0, PROCS, PROCS * 64), jnp.int32)

    # no-retry arm: the corrupted segment's items are LOST, and the lost
    # counter accounts for every one of them — never silent
    ltr = FaultInjectingTransport(make_transport("dense"), cspec)

    def corrupt_lose(pay, dst):
        bk = get_backend("bcl")
        res = route(bk, pay, dst, capacity=64, op_name="lose",
                    transport=ltr, integrity=True)
        return (res.valid.sum()[None], res.lost[None], res.dropped[None])

    arr, lost, drp = jax.jit(shard_map(
        corrupt_lose, mesh=mesh, in_specs=(P("bcl"),) * 2,
        out_specs=(P("bcl"),) * 3))(lv[:, None], ld)
    n_lost = int(np.asarray(lost)[0])
    check("chaos.corrupt_lost_accounted",
          n_lost > 0 and int(np.asarray(drp).sum()) == 0
          and int(np.asarray(arr).sum()) + n_lost == PROCS * 64)

    # heal arm: same fault under overflow="carry" — the unacked items
    # ride the carry mask into a re-push and NOTHING is lost
    htr = FaultInjectingTransport(make_transport("dense"), cspec)

    def corrupt_heal(vals_, dst):
        bk = get_backend("bcl")
        qspec, qst = q.queue_create(bk, 1024, SDS((), jnp.uint32))
        qst, _, _, carry = q.push(bk, qspec, qst, vals_, dst, capacity=64,
                                  max_rounds=2, overflow="carry",
                                  transport=htr, integrity=True)
        qst, _, _, carry2 = q.push(bk, qspec, qst, vals_, dst, capacity=64,
                                   valid=carry, overflow="carry",
                                   transport=htr, integrity=True)
        rows, got = q.local_drain(qspec, qst)
        return carry.sum()[None], carry2.sum()[None], rows, got

    c1, c2, hrows, hgot = jax.jit(shard_map(
        corrupt_heal, mesh=mesh, in_specs=(P("bcl"),) * 2,
        out_specs=(P("bcl"),) * 4))(lv, ld)
    healed = np.asarray(hrows)[np.asarray(hgot)]
    check("chaos.corrupt_carry_heals",
          int(np.asarray(c1).sum()) > 0 and int(np.asarray(c2).sum()) == 0
          and sorted(healed.tolist()) == sorted(np.asarray(lv).tolist()))

    # ---- faults x split-phase (DESIGN.md sections 1.8 + 1.9) ----
    # the same seeded corruption driven through commit_async/finish:
    # with double-buffered retry rounds the next round's wire is already
    # in flight while the previous round's checksum windows are being
    # verified, and the loss accounting must not change.  A FRESH
    # FaultInjectingTransport re-bases the trace-time launch counter, so
    # the injected faults hit the same launches as the sync arm.
    from repro.core import ExchangePlan as _EP
    astr = FaultInjectingTransport(make_transport("dense"), cspec)

    def corrupt_lose_async(pay, dst):
        bk = get_backend("bcl")
        plan = _EP(name="lose")
        h = plan.add(pay, dst, 64, op_name="lose")
        c = plan.commit_async(bk, transport=astr, integrity=True).finish(bk)
        res = c.view(h)
        return (res.valid.sum()[None], res.lost[None], res.dropped[None])

    arr_a, lost_a, drp_a = jax.jit(shard_map(
        corrupt_lose_async, mesh=mesh, in_specs=(P("bcl"),) * 2,
        out_specs=(P("bcl"),) * 3))(lv[:, None], ld)
    check("chaos.async_corrupt_lost_accounted",
          int(np.asarray(lost_a)[0]) == n_lost
          and np.array_equal(np.asarray(arr_a), np.asarray(arr))
          and int(np.asarray(drp_a).sum()) == 0)

    # heal arm, split-phase: a fused push_pop under overflow="carry" +
    # integrity, issued via commit_async with 2 retry rounds — round 2
    # is committed while round 1's checksums settle.  The async and
    # sync schedules of the SAME program must agree bit-for-bit, the
    # first shot must lose loudly (carry > 0), the re-push must heal
    # (carry2 == 0), and drain + pops must recover the full multiset.
    def heal_pair(split):
        ptr = FaultInjectingTransport(make_transport("dense"), cspec)

        def body(vals_, dst):
            bk = get_backend("bcl")
            qspec, qst = q.queue_create(bk, 1024, SDS((), jnp.uint32),
                                        circular=True)

            def pp(st, valid):
                if split:
                    return q.push_pop(
                        bk, qspec, st, vals_, dst, 64, 8, 0, valid=valid,
                        max_rounds=2, overflow="carry", transport=ptr,
                        integrity=True, async_=True).finish()
                return q.push_pop(
                    bk, qspec, st, vals_, dst, 64, 8, 0, valid=valid,
                    max_rounds=2, overflow="carry", transport=ptr,
                    integrity=True)

            qst, _, _, out1, got1, carry = pp(qst, None)
            qst, _, _, out2, got2, carry2 = pp(qst, carry)
            rows, got = q.local_drain(qspec, qst)
            return (carry.sum()[None], carry2.sum()[None], rows, got,
                    out1, got1, out2, got2)

        return jax.jit(shard_map(body, mesh=mesh, in_specs=(P("bcl"),) * 2,
                                 out_specs=(P("bcl"),) * 8))(lv, ld)

    hp_sync = heal_pair(False)
    hp_async = heal_pair(True)
    recovered = np.concatenate(
        [np.asarray(hp_async[2])[np.asarray(hp_async[3])],
         np.asarray(hp_async[4])[np.asarray(hp_async[5])],
         np.asarray(hp_async[6])[np.asarray(hp_async[7])]])
    check("chaos.async_corrupt_carry_heals",
          all(np.array_equal(np.asarray(a), np.asarray(b))
              for a, b in zip(hp_sync, hp_async))
          and int(np.asarray(hp_async[0]).sum()) > 0
          and int(np.asarray(hp_async[1]).sum()) == 0
          and sorted(recovered.tolist()) == sorted(np.asarray(lv).tolist()))

    print("ALL SPMD CHECKS PASSED")


if __name__ == "__main__":
    main()
