"""Tier-1 smoke run of the exchange-layer microbenchmarks.

Runs micro_hashmap / micro_queue at tiny sizes (benchmarks/run.py
--smoke) so a perf-shaped regression in the exchange engine — extra
collectives, extra wire lanes — fails the suite, not just the nightly
benchmark sweep.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_micro_hashmap_smoke():
    from benchmarks import micro_hashmap
    results = micro_hashmap.run(smoke=True)
    for k in ("hashmap_insert", "hashmap_insert_buffer",
              "hashmap_find_atomic", "hashmap_find", "hashmap_find_2attempt"):
        assert results[k] > 0, k


def test_micro_queue_smoke():
    from benchmarks import micro_queue
    results = micro_queue.run(smoke=True)
    for k in ("cq_push_pushpop", "fq_push", "fq_pop", "fq_local_pop"):
        assert results[k] > 0, k


def test_micro_fused_arms_smoke():
    """The --fused arms run and report both schedules of each pair."""
    from benchmarks import micro_hashmap, micro_queue
    r = micro_hashmap.run(smoke=True, fused=True)
    assert r["hashmap_find_insert_fused"] > 0
    assert r["hashmap_find_insert_fine"] > 0
    r = micro_queue.run(smoke=True, fused=True)
    assert r["cq_push_pop_fused"] > 0
    assert r["cq_push_pop_fine"] > 0


def test_micro_wire_arms_smoke(capsys):
    """The --wire {scatter,fused} arms (DESIGN.md section 1.10): both
    wires run every variant, rows follow the shared CSV schema with the
    hbm_passes column filled, the fused arm reports strictly fewer
    standalone scatter passes than the scatter arm, and the wire choice
    never changes bytes, collectives, or rounds."""
    from benchmarks import micro_hashmap, micro_queue
    from benchmarks.util import HEADER
    ncols = len(HEADER.split(","))
    hcols = HEADER.split(",")
    ip = hcols.index("hbm_passes")
    rs = micro_hashmap.run(smoke=True, wire="scatter")
    rf = micro_hashmap.run(smoke=True, wire="fused")
    rq = micro_queue.run(smoke=True, wire="fused")
    for k in ("hashmap_insert", "hashmap_find"):
        assert rs[k] > 0 and rf[k] > 0, k
    assert rq["fq_push"] > 0
    rows = [ln.split(",") for ln in capsys.readouterr().out.splitlines()
            if "," in ln]
    for cols in rows:
        assert len(cols) == ncols, cols
    by_name = {cols[0]: cols for cols in rows}
    for base in ("hashmap_insert", "hashmap_insert_buffer",
                 "hashmap_find_atomic", "hashmap_find",
                 "hashmap_find_2attempt"):
        s, f = by_name[base + "_scatter"], by_name[base + "_fused"]
        # the structural win: fewer HBM scatter passes when fused
        assert int(f[ip]) < int(s[ip]), base
        # ...at identical collectives / bytes / rounds / hops
        for i in (2, 3, 4, 8):
            assert s[i] == f[i], (base, hcols[i], s[i], f[i])
    for cols in by_name.values():
        if cols[0].endswith(("_scatter", "_fused")):
            assert cols[ip] != "", cols[0]        # column filled


def test_micro_skew_arms_smoke(capsys):
    """The --skew zipf arms run; the drop-mode arm loses items, the
    retry arm loses none, and every CSV row follows the shared schema
    (incl. the retry_rounds and dropped columns)."""
    from benchmarks import micro_hashmap, micro_queue
    from benchmarks.util import HEADER
    ncols = len(HEADER.split(","))
    rq = micro_queue.run(smoke=True, skew="zipf")
    assert rq["fq_push_skew_drop_dropped"] > 0
    assert rq["fq_push_skew_retry_dropped"] == 0
    rh = micro_hashmap.run(smoke=True, skew="zipf")
    assert rh["hashmap_insert_skew_drop_dropped"] > 0
    assert rh["hashmap_insert_skew_retry_dropped"] == 0
    rows = [ln for ln in capsys.readouterr().out.strip().splitlines()
            if "," in ln]
    assert rows, "benchmarks emitted no CSV rows"
    for ln in rows:
        assert len(ln.split(",")) == ncols, ln
    skew_tags = [ln for ln in rows if "_skew_" in ln]
    assert len(skew_tags) == 4
    for ln in skew_tags:
        cols = ln.split(",")
        assert cols[6] != "" and cols[7] != "", ln     # retry_rounds,dropped


def test_app_skew_arms_smoke(capsys):
    """The --skew zipf arms on the APPLICATION benchmarks (isx /
    meraculous / kmer): drop-mode arms lose items, retry arms lose none,
    and every skew row carries the retry_rounds/dropped columns of the
    shared CSV schema."""
    from benchmarks import isx, kmer, meraculous
    from benchmarks.util import HEADER
    ncols = len(HEADER.split(","))
    r = isx.run(smoke=True, skew="zipf")
    assert r["isx_skew_drop_dropped"] > 0
    assert r["isx_skew_retry_dropped"] == 0
    r = kmer.run(smoke=True, skew="zipf")
    assert r["kmer_insert_skew_drop_dropped"] > 0
    assert r["kmer_insert_skew_retry_dropped"] == 0
    r = meraculous.run(smoke=True, skew="zipf")
    assert r["meraculous_build_skew_drop_dropped"] > 0
    assert r["meraculous_build_skew_retry_dropped"] == 0
    rows = [ln for ln in capsys.readouterr().out.strip().splitlines()
            if "," in ln]
    skew_rows = [ln for ln in rows if "_skew_" in ln]
    assert len(skew_rows) == 6
    for ln in skew_rows:
        cols = ln.split(",")
        assert len(cols) == ncols, ln
        assert cols[6] != "" and cols[7] != "", ln     # retry_rounds,dropped


def test_lm_moe_skew_arm_smoke(capsys):
    """The lm_step --skew zipf arm (MoE dispatch under zipf-routed
    tokens): the drop arm loses tokens at uniform expert capacity, the
    suggest_rounds-driven retry arm serves every token, and both rows
    follow the shared CSV schema (retry_rounds + dropped columns)."""
    from benchmarks import lm_step
    from benchmarks.util import HEADER
    ncols = len(HEADER.split(","))
    results = {}
    lm_step._moe_skew_arm(results, smoke=True)
    assert results["lm_moe_skew_drop_dropped"] > 0
    assert results["lm_moe_skew_retry_dropped"] == 0
    rows = [ln for ln in capsys.readouterr().out.strip().splitlines()
            if ln.startswith("lm_moe_skew_")]
    assert len(rows) == 2
    for ln in rows:
        cols = ln.split(",")
        assert len(cols) == ncols, ln
        assert cols[6] != "" and cols[7] != "", ln     # retry_rounds,dropped
    # the retry arm's round count came from the heuristic, not a constant
    retry_row = [ln for ln in rows if "retry" in ln][0]
    assert int(retry_row.split(",")[6]) > 1


def test_micro_async_arms_smoke(capsys):
    """The --async arms (DESIGN.md section 1.9): the split-phase rows
    carry overlap_launches > 0 while every other cost column (including
    collectives/bytes/hops) matches the sync row exactly — the
    charge-once-at-wait attribution rule, checked end to end through
    the CSV schema."""
    from benchmarks import micro_hashmap, micro_queue
    from benchmarks.util import HEADER
    ncols = len(HEADER.split(","))
    rq = micro_queue.run(smoke=True, async_=True)
    assert rq["cq_push_pop_sync"] > 0 and rq["cq_push_pop_async"] > 0
    rh = micro_hashmap.run(smoke=True, async_=True)
    assert rh["hashmap_find_insert_sync"] > 0
    assert rh["hashmap_find_insert_async"] > 0
    rows = [ln for ln in capsys.readouterr().out.strip().splitlines()
            if "," in ln]
    for ln in rows:
        assert len(ln.split(",")) == ncols, ln
    for sync_tag, async_tag in (
            ("cq_push_pop_sync", "cq_push_pop_async"),
            ("hashmap_find_insert_sync", "hashmap_find_insert_async")):
        s = [ln.split(",") for ln in rows
             if ln.startswith(sync_tag + ",")][0]
        a = [ln.split(",") for ln in rows
             if ln.startswith(async_tag + ",")][0]
        # collectives, bytes, rounds, hops, lost, unreachable all equal
        for i in (2, 3, 4, 8, 9, 11):
            assert s[i] == a[i], (sync_tag, i, s[i], a[i])
        assert s[12] == "0", s          # sync arm defers nothing
        assert int(a[12]) > 0, a        # async arm reports its deferrals


def test_lm_moe_async_arm_smoke(capsys):
    """The lm_step --async arm: split-phase MoE dispatch overlaps the
    wire (overlap_launches > 0) with cost totals equal to the sync arm
    (ISSUE acceptance: lm_step --async)."""
    from benchmarks import lm_step
    from benchmarks.util import HEADER
    ncols = len(HEADER.split(","))
    results = {}
    lm_step._moe_async_arm(results, smoke=True)
    assert results["lm_moe_dispatch_async_overlap"] > 0
    assert results["lm_moe_dispatch_sync_overlap"] == 0
    rows = [ln for ln in capsys.readouterr().out.strip().splitlines()
            if ln.startswith("lm_moe_dispatch_")]
    assert len(rows) == 2
    s = [ln.split(",") for ln in rows if "_sync," in ln][0]
    a = [ln.split(",") for ln in rows if "_async," in ln][0]
    assert len(s) == ncols and len(a) == ncols
    for i in (2, 3, 4, 8, 9, 11):
        assert s[i] == a[i], (i, s[i], a[i])
    assert s[12] == "0" and int(a[12]) > 0


def test_micro_faults_arms_smoke(capsys):
    """The --faults arms (DESIGN.md section 1.8): seeded corruption under
    the integrity checksum loses items (never silently), the carry /
    re-send heal recovers every one of them, the degraded-commit probe
    reports its dead rank, and the rows carry the lost_bytes / recovered
    / unreachable columns of the shared CSV schema."""
    from benchmarks import micro_hashmap, micro_queue
    from benchmarks.util import HEADER
    ncols = len(HEADER.split(","))
    micro_queue.run(smoke=True, faults=True)
    micro_hashmap.run(smoke=True, faults=True)
    rows = [ln for ln in capsys.readouterr().out.strip().splitlines()
            if "," in ln]
    for ln in rows:
        assert len(ln.split(",")) == ncols, ln
    fault_rows = [ln for ln in rows if "_faults" in ln.split(",")[0]]
    assert len(fault_rows) == 2
    for ln in fault_rows:
        cols = ln.split(",")
        # lost_bytes, recovered, unreachable: filled, and non-trivial —
        # the injected corruption really invalidated wire bytes, the
        # heal pass really recovered items, the probe really masked a
        # dead rank
        assert int(cols[9]) > 0, ln
        assert int(cols[10]) > 0, ln
        assert int(cols[11]) == 1, ln


def test_micro_transport_arm_smoke(capsys):
    """The --transport hier arm: micro benchmarks run the exchange over
    the two-stage transport, rows are suffixed _hier, and the hops
    column shows the extra stage (2 per launch where dense logs 1)."""
    from benchmarks import micro_queue
    from benchmarks.util import HEADER
    ncols = len(HEADER.split(","))
    r = micro_queue.run(smoke=True, transport="hier")
    for k in ("fq_push", "fq_pop", "fq_local_pop"):
        assert r[k] > 0, k
    rows = [ln for ln in capsys.readouterr().out.strip().splitlines()
            if "," in ln]
    hier_rows = [ln for ln in rows if ln.split(",")[0].endswith("_hier")]
    assert hier_rows, "no _hier rows emitted"
    for ln in hier_rows:
        cols = ln.split(",")
        assert len(cols) == ncols, ln
    fq = [ln.split(",") for ln in hier_rows
          if ln.startswith("fq_push_hier,")][0]
    # 8 waves x 2 hops/launch (collectives == hops for pure requests)
    assert int(fq[8]) == int(fq[2]) and int(fq[8]) == 16


def test_smoke_costs_pin_round_reduction():
    """The benchmark-side cost observables see the fused exchange."""
    from benchmarks.util import trace_costs
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax import ShapeDtypeStruct as SDS
    from repro.core import ConProm, get_backend
    from repro.containers import hashmap as hm

    bk = get_backend(None)
    spec, st = hm.hashmap_create(bk, 1 << 10, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=16)
    keys = jnp.asarray(np.arange(64), jnp.uint32)
    st, _ = hm.insert(bk, spec, st, keys, keys, capacity=64)

    c2 = trace_costs(
        jax.jit(lambda s, k: hm.find(bk, spec, s, k, capacity=64,
                                     promise=ConProm.HashMap.find,
                                     attempts=2)), st, keys)
    c_seq = trace_costs(
        jax.jit(lambda s, k: hm.find(bk, spec, s, k, capacity=64,
                                     promise=ConProm.HashMap.find,
                                     attempts=2, speculative=False)),
        st, keys)
    assert c2.collectives == 2 and c2.rounds == 2
    assert c_seq.collectives == 4 and c_seq.rounds == 4
