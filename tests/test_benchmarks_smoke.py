"""Tier-1 smoke run of the exchange-layer microbenchmarks.

Runs micro_hashmap / micro_queue at tiny sizes (benchmarks/run.py
--smoke) so a perf-shaped regression in the exchange engine — extra
collectives, extra wire lanes — fails the suite, not just the nightly
benchmark sweep.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def test_micro_hashmap_smoke():
    from benchmarks import micro_hashmap
    results = micro_hashmap.run(smoke=True)
    for k in ("hashmap_insert", "hashmap_insert_buffer",
              "hashmap_find_atomic", "hashmap_find", "hashmap_find_2attempt"):
        assert results[k] > 0, k


def test_micro_queue_smoke():
    from benchmarks import micro_queue
    results = micro_queue.run(smoke=True)
    for k in ("cq_push_pushpop", "fq_push", "fq_pop", "fq_local_pop"):
        assert results[k] > 0, k


def test_micro_fused_arms_smoke():
    """The --fused arms run and report both schedules of each pair."""
    from benchmarks import micro_hashmap, micro_queue
    r = micro_hashmap.run(smoke=True, fused=True)
    assert r["hashmap_find_insert_fused"] > 0
    assert r["hashmap_find_insert_fine"] > 0
    r = micro_queue.run(smoke=True, fused=True)
    assert r["cq_push_pop_fused"] > 0
    assert r["cq_push_pop_fine"] > 0


def test_smoke_costs_pin_round_reduction():
    """The benchmark-side cost observables see the fused exchange."""
    from benchmarks.util import trace_costs
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax import ShapeDtypeStruct as SDS
    from repro.core import ConProm, get_backend
    from repro.containers import hashmap as hm

    bk = get_backend(None)
    spec, st = hm.hashmap_create(bk, 1 << 10, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=16)
    keys = jnp.asarray(np.arange(64), jnp.uint32)
    st, _ = hm.insert(bk, spec, st, keys, keys, capacity=64)

    c2 = trace_costs(
        jax.jit(lambda s, k: hm.find(bk, spec, s, k, capacity=64,
                                     promise=ConProm.HashMap.find,
                                     attempts=2)), st, keys)
    c_seq = trace_costs(
        jax.jit(lambda s, k: hm.find(bk, spec, s, k, capacity=64,
                                     promise=ConProm.HashMap.find,
                                     attempts=2, speculative=False)),
        st, keys)
    assert c2.collectives == 2 and c2.rounds == 2
    assert c_seq.collectives == 4 and c_seq.rounds == 4
