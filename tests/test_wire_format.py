"""Wire-format and collective-round assertions for the fused exchange.

Pins the tentpole optimizations quantitatively via ``costs.recording()``:

  * route ships exactly ONE metadata lane (L+1 u32 lanes per item);
  * reply ships ZERO metadata lanes (L u32 lanes per item) — the
    inverse-permutation all-to-all needs no src_pos on the wire;
  * a 2-attempt hashmap find costs 2 collectives (two speculative
    flows on one ExchangePlan), down from 4 for the sequential loop,
    at the SAME wire bytes as the pre-plan hand-fused dual batch;
  * a fused find+insert under ``ConProm.HashMap.find_insert`` costs 2
    collectives per round trip where ``Promise.FINE`` costs 4;
  * a fused push+pop costs 2 collectives where ``Promise.FINE`` costs 3;

and pins the semantics of every fusion against the serial oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from jax import ShapeDtypeStruct as SDS

from repro.core import (ConProm, ExchangePlan, Promise, costs, get_backend,
                        route)
from repro.core.exchange import reply
from repro.containers import hashmap as hm
from repro.containers import queue as q
from repro.kernels import ops as kops
from repro.kernels import ref


# ---------------------------------------------------------------------------
# bytes per item: one metadata lane out, zero lanes back
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lanes", [1, 3])
def test_route_ships_one_metadata_lane(lanes):
    bk = get_backend(None)
    n, cap = 16, 16
    pay = jnp.zeros((n, lanes), jnp.uint32)
    with costs.recording() as log:
        route(bk, pay, jnp.zeros(n, jnp.int32), capacity=cap, op_name="op")
    c = log.by_op("op")
    # P * C * (L + 1) u32 lanes: payload + packed (valid | src_pos) meta
    assert c.bytes_out == 1 * cap * (lanes + 1) * 4
    assert c.bytes_moved == c.bytes_out and c.bytes_in == 0
    assert c.collectives == 1 and c.rounds == 1


@pytest.mark.parametrize("lanes", [1, 3])
def test_reply_ships_zero_metadata_lanes(lanes):
    bk = get_backend(None)
    n, cap = 16, 16
    req = route(bk, jnp.zeros((n, 2), jnp.uint32), jnp.zeros(n, jnp.int32),
                capacity=cap)
    with costs.recording() as log:
        reply(bk, req, jnp.zeros((cap, lanes), jnp.uint32), orig_n=n,
              op_name="op")
    c = log.by_op("op")
    # pure inverse all-to-all: P * C * L u32 lanes, no src_pos, no valid
    assert c.bytes_in == 1 * cap * lanes * 4
    assert c.bytes_moved == c.bytes_in and c.bytes_out == 0
    assert c.collectives == 1 and c.rounds == 1


def test_request_reply_direction_split():
    bk = get_backend(None)
    n = 8
    with costs.recording() as log:
        req = route(bk, jnp.zeros((n, 1), jnp.uint32),
                    jnp.zeros(n, jnp.int32), capacity=n, op_name="op")
        reply(bk, req, req.payload[:, :1], orig_n=n, op_name="op")
    c = log.by_op("op")
    assert c.bytes_out == n * 2 * 4          # 1 payload lane + meta lane
    assert c.bytes_in == n * 1 * 4           # 1 payload lane only
    assert c.bytes_moved == c.bytes_out + c.bytes_in
    assert c.rounds == 2


# ---------------------------------------------------------------------------
# collective rounds: speculative dual-attempt find
# ---------------------------------------------------------------------------

def _loaded_map(nkeys=200, capacity=256, block_size=4):
    """A hash map loaded to ~0.8 so many keys need attempt-1/2 homes."""
    bk = get_backend(None)
    spec, st = hm.hashmap_create(bk, capacity, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=block_size)
    keys = jnp.asarray(np.random.default_rng(7).permutation(1 << 20)[:nkeys],
                       jnp.uint32)
    vals = keys * 3 + 1
    st, ok = hm.insert(bk, spec, st, keys, vals, capacity=nkeys, attempts=3)
    return bk, spec, st, keys, vals, ok


def test_find_two_attempts_two_collectives():
    bk, spec, st, keys, _, _ = _loaded_map()
    with costs.recording() as log:
        hm.find(bk, spec, st, keys, capacity=keys.shape[0], attempts=2)
    c = log.by_op("hashmap.find")
    assert c.collectives == 2 and c.rounds == 2


def test_find_sequential_attempts_four_collectives():
    bk, spec, st, keys, _, _ = _loaded_map()
    with costs.recording() as log:
        hm.find(bk, spec, st, keys, capacity=keys.shape[0], attempts=2,
                speculative=False)
    c = log.by_op("hashmap.find")
    assert c.collectives == 4 and c.rounds == 4


def test_speculative_find_matches_serial_oracle():
    bk, spec, st, keys, vals, ok = _loaded_map()
    n = keys.shape[0]
    # mix of present keys (including attempt-1 residents) and absent keys
    queries = jnp.concatenate([keys, keys + jnp.uint32(1 << 21)])
    _, v_spec, f_spec = hm.find(bk, spec, st, queries, capacity=2 * n)
    _, v_ser, f_ser = hm.find(bk, spec, st, queries, capacity=2 * n,
                              speculative=False)
    assert np.array_equal(np.asarray(f_spec), np.asarray(f_ser))
    assert np.array_equal(np.asarray(v_spec), np.asarray(v_ser))
    # inserted keys found at 2 attempts must carry the inserted value
    fs = np.asarray(f_spec[:n])
    assert fs.sum() > 0
    assert (np.asarray(v_spec[:n])[fs] ==
            (np.asarray(keys) * 3 + 1)[fs]).all()
    # absent keys are never "found"
    assert not np.asarray(f_spec[n:]).any()


def test_speculative_find_atomic_promise():
    bk, spec, st, keys, _, _ = _loaded_map()
    st1, v1, f1 = hm.find(bk, spec, st, keys, capacity=keys.shape[0],
                          promise=ConProm.HashMap.find_insert)
    st2, v2, f2 = hm.find(bk, spec, st, keys, capacity=keys.shape[0],
                          promise=ConProm.HashMap.find_insert,
                          speculative=False)
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
    assert np.array_equal(np.asarray(v1), np.asarray(v2))
    # the read-bit dance is net-zero on the status word either way
    assert np.array_equal(np.asarray(st1.status), np.asarray(st2.status))


def test_speculative_find_two_flow_lane_counts():
    """The two-flow plan ships EXACTLY the bytes of the old hand-fused
    dual batch: request 2C rows x (1 + Lk + meta) lanes, reply 2C rows x
    (Lv + found) lanes — the plan refactor changes the scheduler, not
    the wire."""
    bk, spec, st, keys, _, _ = _loaded_map()
    n = keys.shape[0]
    lk = spec.key_packer.lanes        # 1
    lv = spec.val_packer.lanes        # 1
    with costs.recording() as log:
        hm.find(bk, spec, st, keys, capacity=n, attempts=2)
    c = log.by_op("hashmap.find")
    assert c.bytes_out == 2 * n * (1 + lk + 1) * 4    # two C-row segments
    assert c.bytes_in == 2 * n * (lv + 1) * 4
    assert c.collectives == 2 and c.rounds == 2


# ---------------------------------------------------------------------------
# collective rounds: fused find+insert (the plan/commit acceptance pin)
# ---------------------------------------------------------------------------

def test_find_insert_fused_two_collectives_fine_four():
    """ConProm.HashMap.find_insert fuses both ops into 2 collectives per
    round trip; the Promise.FINE sequential schedule costs exactly 4."""
    bk, spec, st, keys, _, _ = _loaded_map()
    n = keys.shape[0]
    ins = keys + jnp.uint32(1 << 22)
    with costs.recording() as log_f:
        hm.find_insert(bk, spec, st, keys, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert)
    with costs.recording() as log_s:
        hm.find_insert(bk, spec, st, keys, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert | Promise.FINE)
    assert log_f.total().collectives == 2 and log_f.total().rounds == 2
    assert log_s.total().collectives == 4 and log_s.total().rounds == 4


def test_find_insert_fused_matches_fine_oracle():
    bk, spec, st, keys, _, _ = _loaded_map()
    n = keys.shape[0]
    queries = jnp.concatenate([keys[:100], keys[:100] + jnp.uint32(1 << 21)])
    ins = keys + jnp.uint32(1 << 22)
    f = hm.find_insert(bk, spec, st, queries, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert)
    s = hm.find_insert(bk, spec, st, queries, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert | Promise.FINE)
    for got, want in zip(f[1:], s[1:]):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(f[0], s[0]):         # table state, bit-identical
        assert np.array_equal(np.asarray(got), np.asarray(want))
    found = np.asarray(f[2])
    # single-attempt probe: attempt-0 residents found, attempt-1 homes
    # legitimately missed (the op documents attempts=1 semantics)
    assert found[:100].sum() > 50
    assert not found[100:].any()              # absent keys never found
    vals = np.asarray(f[1])[:100]
    keys_np = np.asarray(queries)[:100]
    assert (vals[found[:100]] == (keys_np * 3 + 1)[found[:100]]).all()


# ---------------------------------------------------------------------------
# collective rounds: fused push+pop
# ---------------------------------------------------------------------------

def test_push_pop_fused_two_collectives_fine_three():
    bk = get_backend(None)
    spec, st = q.queue_create(bk, 128, SDS((), jnp.uint32), circular=True)
    vals = jnp.arange(32, dtype=jnp.uint32) + 1
    dest = jnp.zeros(32, jnp.int32)
    with costs.recording() as log_f:
        f = q.push_pop(bk, spec, st, vals, dest, 32, 16, 0)
    with costs.recording() as log_s:
        s = q.push_pop(bk, spec, st, vals, dest, 32, 16, 0,
                       promise=ConProm.CircularQueue.push_pop | Promise.FINE)
    assert log_f.total().collectives == 2 and log_f.total().rounds == 2
    assert log_s.total().collectives == 3 and log_s.total().rounds == 3
    for got, want in zip(f[1:], s[1:]):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(f[0], s[0]):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    # push lands before pop: this round's pushes are poppable
    assert int(f[4].sum()) == 16
    assert np.array_equal(np.asarray(f[3])[np.asarray(f[4])],
                          np.arange(16, dtype=np.uint32) + 1)


# ---------------------------------------------------------------------------
# ragged per-flow wire segments: byte-exact pins (DESIGN.md section 1.5)
# ---------------------------------------------------------------------------

def test_find_insert_ragged_bytes_exact_and_below_rectangular():
    """Mixed-width fused plan: each flow ships exactly C_f*(L_f+1) u32
    request words and C_f*R_f reply words — the analytic formula — and
    the plan total is strictly below the rectangular (max-width padded)
    layout in both directions."""
    bk, spec, st, keys, _, _ = _loaded_map()
    n = keys.shape[0]
    lk, lv = spec.key_packer.lanes, spec.val_packer.lanes       # 1, 1
    ins = keys + jnp.uint32(1 << 22)
    with costs.recording() as log:
        hm.find_insert(bk, spec, st, keys, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert)
    lf, li = 1 + lk, 1 + lk + lv               # payload lanes per flow
    assert log.by_op("hashmap.find").bytes_out == n * (lf + 1) * 4
    assert log.by_op("hashmap.insert").bytes_out == n * (li + 1) * 4
    assert log.by_op("hashmap.find").bytes_in == n * (lv + 1) * 4
    assert log.by_op("hashmap.insert").bytes_in == n * 1 * 4
    tot = log.total()
    assert tot.bytes_out == n * ((lf + 1) + (li + 1)) * 4
    assert tot.bytes_in == n * ((lv + 1) + 1) * 4
    # PR 3 rectangular layout: every flow padded to the widest
    assert tot.bytes_out < 2 * n * (max(lf, li) + 1) * 4
    assert tot.bytes_in < 2 * n * max(lv + 1, 1) * 4


def test_push_pop_ragged_bytes_exact_and_below_rectangular():
    """Wide values make push the wide flow; pop's unit requests and the
    value-width pop replies each ship their own exact widths."""
    bk = get_backend(None)
    lanes = 3                                   # 3-lane values
    spec, st = q.queue_create(bk, 128, lanes, circular=True)
    nv, npop = 32, 16
    vals = jnp.arange(nv * lanes, dtype=jnp.uint32).reshape(nv, lanes)
    with costs.recording() as log:
        q.push_pop(bk, spec, st, vals, jnp.zeros(nv, jnp.int32), nv,
                   npop, 0)
    assert log.by_op("queue.push").bytes_out == nv * (lanes + 1) * 4
    assert log.by_op("queue.pop").bytes_out == npop * (1 + 1) * 4
    assert log.by_op("queue.push").bytes_in == 0     # fire-and-forget
    assert log.by_op("queue.pop").bytes_in == npop * (lanes + 1 + 0) * 4
    # rectangular: pop's unit requests would pay the push flow's width
    assert log.total().bytes_out < (nv + npop) * (lanes + 1) * 4


def test_bloom_insert_find_ragged_bytes_exact():
    """Same-width flows: the ragged formula reduces to the rectangular
    one — sum_f C_f*(L_f+1) words out, C_f*1 words back."""
    bk = get_backend(None)
    from repro.containers import bloom as bl
    spec, st = bl.bloom_create(bk, 1 << 12, SDS((), jnp.uint32), k=4)
    ins = jnp.arange(24, dtype=jnp.uint32) + 1
    qry = jnp.arange(16, dtype=jnp.uint32) + 5
    with costs.recording() as log:
        bl.insert_find(bk, spec, st, ins, qry, 24, 16)
    body = 3                                    # lblock + 2 bit-words
    assert log.by_op("bloom.insert").bytes_out == 24 * (body + 1) * 4
    assert log.by_op("bloom.find").bytes_out == 16 * (body + 1) * 4
    assert log.by_op("bloom.insert").bytes_in == 24 * 1 * 4
    assert log.by_op("bloom.find").bytes_in == 16 * 1 * 4


def test_plan_commit_bytes_equal_sum_of_single_flow_routes():
    """The acceptance criterion that makes fusion unconditionally
    profitable: a fused mixed-width plan moves EXACTLY the bytes of its
    flows' standalone route()/reply() lowerings — fusing saves rounds
    and collectives, never costs wire."""
    bk = get_backend(None)
    rng = np.random.default_rng(21)
    widths, caps, rls = (1, 2, 4), (8, 5, 9), (1, 0, 3)
    pays = [jnp.asarray(rng.integers(0, 1 << 30, (12, w)), jnp.uint32)
            for w in widths]
    dest = jnp.zeros(12, jnp.int32)

    with costs.recording() as log_f:
        plan = ExchangePlan(name="plan")
        hs = [plan.add(p, dest, c, reply_lanes=rl, op_name=f"f{i}")
              for i, (p, c, rl) in enumerate(zip(pays, caps, rls))]
        c = plan.commit(bk)
        for h, rl in zip(hs, rls):
            if rl:
                c.set_reply(h, jnp.tile(c.view(h).payload[:, :1], (1, rl)))
        c.finish(bk)
    with costs.recording() as log_s:
        for i, (p, cap, rl) in enumerate(zip(pays, caps, rls)):
            res = route(bk, p, dest, cap, op_name=f"f{i}")
            if rl:
                reply(bk, res, jnp.tile(res.payload[:, :1], (1, rl)),
                      orig_n=12, op_name=f"f{i}")
    for i in range(3):
        assert log_f.by_op(f"f{i}").bytes_out == \
            log_s.by_op(f"f{i}").bytes_out
        assert log_f.by_op(f"f{i}").bytes_in == log_s.by_op(f"f{i}").bytes_in
    assert log_f.total().bytes_moved == log_s.total().bytes_moved
    # ...while the collective counts are where fusion wins
    assert log_f.total().collectives == 2
    assert log_s.total().collectives == 3 + 2   # 3 routes + 2 replies


def test_moe_dispatch_stats_ragged_bytes_exact():
    """The motivating mixed-width plan: the 1-lane MoE stats flow rides
    the token plan at 2 request words + 1 reply word per row instead of
    the token flow's width — its wire cost is now independent of
    d_model."""
    import dataclasses
    from repro.compat import make_mesh
    from repro.configs import get_config, reduced
    from repro.models import moe as moe_mod
    from repro.models.sharding import Axes
    import jax

    cfg = reduced(get_config("arctic-480b"), d_model=32, vocab=256)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2,
                                     expert_d_ff=16),
        moe_capacity_slack=8.0)
    mesh = make_mesh((1, 1), ("data", "model"))
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    with costs.recording() as log:
        moe_mod.moe_apply(params, x, cfg, mesh, Axes.from_mesh(mesh))

    b, t, k, e = 2, 8, cfg.moe.top_k, cfg.moe.n_experts
    act_lanes = cfg.d_model                     # float32 payload
    cap = max(1, int(b * t * k * cfg.moe_capacity_slack) + 1)
    l_tok = act_lanes + 1                       # activations + expert id
    assert log.by_op("moe.dispatch").bytes_out == cap * (l_tok + 1) * 4
    assert log.by_op("moe.dispatch").bytes_in == cap * act_lanes * 4
    assert log.by_op("moe.stats").bytes_out == e * 2 * 4
    assert log.by_op("moe.stats").bytes_in == e * 1 * 4
    # rectangular: stats rows were padded to the token flow's width
    assert log.by_op("moe.stats").bytes_out < e * (l_tok + 1) * 4


# ---------------------------------------------------------------------------
# transports: dense-vs-hierarchical pins (DESIGN.md section 1.7)
# ---------------------------------------------------------------------------

def test_dense_default_records_one_hop_per_launch():
    """The hops observable: every dense launch is one physical stage —
    request and reply each record hops=1 under the op."""
    bk = get_backend(None)
    n = 16
    with costs.recording() as log:
        req = route(bk, jnp.zeros((n, 1), jnp.uint32),
                    jnp.zeros(n, jnp.int32), capacity=n, op_name="op")
        reply(bk, req, req.payload[:, :1], orig_n=n, op_name="op")
    c = log.by_op("op")
    assert c.hops == 2 and c.collectives == 2
    assert log.by_op("op.relay").bytes_moved == 0   # dense has no relay


def test_hier_transport_hop_and_byte_pins_serial():
    """HierarchicalTransport per-hop attribution, exact (1x1
    factorization on the serial backend: c1 = min(Pr*C, N), c2 =
    Pc*min(C, N), rows carry ONE extra hop lane):

      request: op       = Pc * c1 * (L+2) * 4 bytes out
               op.relay = Pr * c2 * (L+2) * 4 bytes out
      reply:   op       = Pc * c1 * R * 4 bytes in
               op.relay = Pr * c2 * R * 4 bytes in

    and each direction is 2 collectives / 2 rounds / 2 hops."""
    from repro.core import ExchangePlan, HierarchicalTransport
    bk = get_backend(None)
    n, cap, lanes, rl = 12, 16, 3, 2
    c1 = min(1 * cap, n)                 # 12
    c2 = 1 * min(cap, n)                 # 12
    with costs.recording() as log:
        plan = ExchangePlan(name="op")
        h = plan.add(jnp.zeros((n, lanes), jnp.uint32),
                     jnp.zeros(n, jnp.int32), cap, reply_lanes=rl,
                     op_name="op")
        c = plan.commit(bk, transport=HierarchicalTransport())
        c.set_reply(h, c.view(h).payload[:, :rl])
        c.finish(bk)
    w1 = lanes + 2                       # payload + meta + hop lane
    cop, crel = log.by_op("op"), log.by_op("op.relay")
    assert cop.bytes_out == 1 * c1 * w1 * 4
    assert crel.bytes_out == 1 * c2 * w1 * 4
    assert cop.bytes_in == 1 * c1 * rl * 4
    assert crel.bytes_in == 1 * c2 * rl * 4
    assert cop.collectives == 4 and cop.rounds == 4 and cop.hops == 4
    assert crel.collectives == 0         # relay records bytes only


def test_hier_transport_matches_dense_serial():
    """Containers over transport="hier" are bit-identical to dense on
    the serial backend (the 8-rank 2-D mesh version runs in
    spmd_check.py); the hier run burns extra binning passes (2 per hop
    pair) but the SAME logical admission."""
    from repro.core import HierarchicalTransport
    bk = get_backend(None)
    hier = HierarchicalTransport()
    spec, st = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=8)
    keys = jnp.arange(40, dtype=jnp.uint32) * 7 + 1
    d_st, d_ok = hm.insert(bk, spec, st, keys, keys * 3, capacity=40)
    h_st, h_ok = hm.insert(bk, spec, st, keys, keys * 3, capacity=40,
                           transport=hier)
    assert np.array_equal(np.asarray(d_ok), np.asarray(h_ok))
    for a, b in zip(d_st, h_st):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    d = hm.find(bk, spec, d_st, keys, capacity=40)
    h = hm.find(bk, spec, h_st, keys, capacity=40, transport=hier)
    for a, b in zip(d[1:], h[1:]):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_hier_transport_guards():
    """Factorization and hop-lane bounds fail loudly, named usefully."""
    from repro.core import ExchangePlan, HierarchicalTransport
    bk = get_backend(None)
    plan = ExchangePlan(name="op")
    plan.add(jnp.zeros((4, 1), jnp.uint32), jnp.zeros(4, jnp.int32), 4,
             op_name="op")
    with pytest.raises(ValueError, match="factor"):
        plan.commit(bk, transport=HierarchicalTransport(3, 5))
    plan2 = ExchangePlan(name="op")
    plan2.add(jnp.zeros((4, 1), jnp.uint32), jnp.zeros(4, jnp.int32),
              1 << 21, op_name="op")
    with pytest.raises(ValueError, match="hop lane"):
        plan2.commit(bk, transport=HierarchicalTransport())


def test_make_transport_knob():
    from repro.core import (DenseTransport, HierarchicalTransport,
                            make_transport)
    assert make_transport(None) is make_transport("dense")
    assert isinstance(make_transport("dense"), DenseTransport)
    t = make_transport("hier", 2, 4)
    assert isinstance(t, HierarchicalTransport)
    assert t._factor(8) == (2, 4)
    assert make_transport(t) is t
    with pytest.raises(ValueError, match="transport"):
        make_transport("mesh3d")


# ---------------------------------------------------------------------------
# one-kernel wire path: jaxpr-level scatter census (DESIGN.md section 1.10)
# ---------------------------------------------------------------------------

def _commit_census(impl, transport=None, integrity=False):
    """Primitive counts of ONE traced plan commit (request + owner view)."""
    from repro.launch import jaxpr_stats
    bk = get_backend(None)

    def go(pay, dest):
        plan = ExchangePlan(name="op")
        h = plan.add(pay, dest, 16, reply_lanes=1, op_name="op")
        c = plan.commit(bk, impl=impl, transport=transport,
                        integrity=integrity)
        v = c.view(h)
        return v.payload, v.valid

    return jaxpr_stats.op_counts(go, jnp.zeros((12, 2), jnp.uint32),
                                 jnp.zeros(12, jnp.int32))


def test_fused_wire_traces_zero_scatter_ops():
    """The tentpole pin: with ``impl="pallas"`` a commit writes the wire
    exactly once — the traced program contains ZERO standalone XLA
    scatter ops, dense AND both hierarchical hops; the jnp fallback
    keeps its exact two-pass scatter counts (4 dense: pack + 2 send
    maps + owner assembly; 8 hier: both hops' packs + maps).  Any new
    ``.at[].set`` on the commit path moves these numbers and fails
    here."""
    from repro.core import HierarchicalTransport
    dense_p = _commit_census("pallas")
    hier_p = _commit_census("pallas", transport=HierarchicalTransport())
    assert dense_p.get("scatter", 0) == 0
    assert hier_p.get("scatter", 0) == 0
    # the fused lowering really is Pallas, not an elided wire
    assert dense_p.get("pallas_call", 0) == 4
    assert hier_p.get("pallas_call", 0) == 8
    dense_j = _commit_census("jnp")
    hier_j = _commit_census("jnp", transport=HierarchicalTransport())
    assert dense_j.get("scatter", 0) == 4
    assert hier_j.get("scatter", 0) == 8
    assert dense_j.get("pallas_call", 0) == 0


def test_integrity_checksum_is_scatter_add_not_scatter():
    """Wire checksums (segment-summed row hashes) lower to scatter-add —
    a reduction, not a wire pack — and stay OUT of the fused-wire pin:
    the pallas commit keeps zero plain-scatter ops with integrity on."""
    c = _commit_census("pallas", integrity=True)
    assert c.get("scatter", 0) == 0
    assert c.get("scatter-add", 0) == 1


def test_op_counts_pallas_bodies_opaque_by_default():
    """The census treats a pallas_call as one opaque primitive: in-kernel
    functional stores are vector writes, not XLA scatter passes — the
    raw (non-opaque) census still sees them, pinning that the distinction
    is real."""
    from repro.launch import jaxpr_stats
    raw = _commit_census("pallas")
    assert raw.get("scatter", 0) == 0
    bk = get_backend(None)

    def go(pay, dest):
        plan = ExchangePlan(name="op")
        h = plan.add(pay, dest, 16, op_name="op")
        return plan.commit(bk, impl="pallas").view(h).payload

    full = jaxpr_stats.op_counts(go, jnp.zeros((12, 2), jnp.uint32),
                                 jnp.zeros(12, jnp.int32),
                                 opaque_kernels=False)
    assert full.get("scatter", 0) > 0        # the in-kernel stores


# ---------------------------------------------------------------------------
# fused reply == oracle alignment
# ---------------------------------------------------------------------------

def test_fused_reply_aligns_with_request_batch():
    bk = get_backend(None)
    n = 32
    pay = jnp.asarray(np.random.default_rng(3).permutation(n), jnp.uint32)
    valid = jnp.asarray(np.random.default_rng(4).random(n) < 0.7)
    req = route(bk, pay, jnp.zeros(n, jnp.int32), capacity=n, valid=valid)
    out, answered = reply(bk, req, req.payload[:, 0] * 5 + 2, orig_n=n)
    ans = np.asarray(answered)
    assert np.array_equal(ans, np.asarray(valid))
    assert np.array_equal(np.asarray(out[:, 0])[ans],
                          np.asarray(pay)[ans] * 5 + 2)


# ---------------------------------------------------------------------------
# send-buffer construction kernel: all impls agree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_bin_offsets_impls_match_oracle(impl):
    rng = np.random.default_rng(11)
    nbins = 8
    bins = jnp.asarray(rng.integers(0, nbins, 300), jnp.int32)
    valid = jnp.asarray(rng.random(300) < 0.8)
    oc, oo = ref.bin_offsets_ref(bins, nbins, valid)
    c, o = kops.bin_offsets(bins, nbins, valid, impl=impl)
    assert np.array_equal(np.asarray(oc), np.asarray(c)), impl
    ov = np.asarray(valid)
    assert np.array_equal(np.asarray(oo)[ov], np.asarray(o)[ov]), impl


@pytest.mark.parametrize("impl", ["oracle", "jnp", "pallas"])
def test_multi_bin_offsets_impls_agree(impl):
    """Segmented multi-flow slot assignment: every impl bins the same
    composite (dest, flow) buckets with stable within-bucket ranks."""
    rng = np.random.default_rng(17)
    nbins, nflows, n = 4, 3, 200
    bins = jnp.asarray(rng.integers(0, nbins, n), jnp.int32)
    flow = jnp.asarray(rng.integers(0, nflows, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    counts, offs = kops.multi_bin_offsets(bins, flow, nbins, nflows, valid,
                                          impl=impl)
    b, f, v, o = map(np.asarray, (bins, flow, valid, offs))
    c = np.asarray(counts)
    for d in range(nbins):
        for fl in range(nflows):
            sel = (b == d) & (f == fl) & v
            assert c[d, fl] == sel.sum(), impl
            assert np.array_equal(np.sort(o[sel]),
                                  np.arange(sel.sum())), impl  # dense+stable


def test_bin_offsets_slots_are_unique_per_bin():
    rng = np.random.default_rng(13)
    nbins = 4
    bins = jnp.asarray(rng.integers(0, nbins, 100), jnp.int32)
    _, offs = kops.bin_offsets(bins, nbins, impl="jnp")
    b, o = np.asarray(bins), np.asarray(offs)
    for d in range(nbins):
        mine = np.sort(o[b == d])
        assert np.array_equal(mine, np.arange(mine.size))  # dense + stable
