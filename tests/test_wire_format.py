"""Wire-format and collective-round assertions for the fused exchange.

Pins the tentpole optimization quantitatively via ``costs.recording()``:

  * route ships exactly ONE metadata lane (L+1 u32 lanes per item);
  * reply ships ZERO metadata lanes (L u32 lanes per item) — the
    inverse-permutation all-to-all needs no src_pos on the wire;
  * a 2-attempt hashmap find costs 2 collectives (speculative dual
    attempt), down from 4 for the sequential attempt loop;

and pins the semantics of both fusions against the serial oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from jax import ShapeDtypeStruct as SDS

from repro.core import ConProm, costs, get_backend, route
from repro.core.exchange import reply
from repro.containers import hashmap as hm
from repro.kernels import ops as kops
from repro.kernels import ref


# ---------------------------------------------------------------------------
# bytes per item: one metadata lane out, zero lanes back
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lanes", [1, 3])
def test_route_ships_one_metadata_lane(lanes):
    bk = get_backend(None)
    n, cap = 16, 16
    pay = jnp.zeros((n, lanes), jnp.uint32)
    with costs.recording() as log:
        route(bk, pay, jnp.zeros(n, jnp.int32), capacity=cap, op_name="op")
    c = log.by_op("op")
    # P * C * (L + 1) u32 lanes: payload + packed (valid | src_pos) meta
    assert c.bytes_out == 1 * cap * (lanes + 1) * 4
    assert c.bytes_moved == c.bytes_out and c.bytes_in == 0
    assert c.collectives == 1 and c.rounds == 1


@pytest.mark.parametrize("lanes", [1, 3])
def test_reply_ships_zero_metadata_lanes(lanes):
    bk = get_backend(None)
    n, cap = 16, 16
    req = route(bk, jnp.zeros((n, 2), jnp.uint32), jnp.zeros(n, jnp.int32),
                capacity=cap)
    with costs.recording() as log:
        reply(bk, req, jnp.zeros((cap, lanes), jnp.uint32), orig_n=n,
              op_name="op")
    c = log.by_op("op")
    # pure inverse all-to-all: P * C * L u32 lanes, no src_pos, no valid
    assert c.bytes_in == 1 * cap * lanes * 4
    assert c.bytes_moved == c.bytes_in and c.bytes_out == 0
    assert c.collectives == 1 and c.rounds == 1


def test_request_reply_direction_split():
    bk = get_backend(None)
    n = 8
    with costs.recording() as log:
        req = route(bk, jnp.zeros((n, 1), jnp.uint32),
                    jnp.zeros(n, jnp.int32), capacity=n, op_name="op")
        reply(bk, req, req.payload[:, :1], orig_n=n, op_name="op")
    c = log.by_op("op")
    assert c.bytes_out == n * 2 * 4          # 1 payload lane + meta lane
    assert c.bytes_in == n * 1 * 4           # 1 payload lane only
    assert c.bytes_moved == c.bytes_out + c.bytes_in
    assert c.rounds == 2


# ---------------------------------------------------------------------------
# collective rounds: speculative dual-attempt find
# ---------------------------------------------------------------------------

def _loaded_map(nkeys=200, capacity=256, block_size=4):
    """A hash map loaded to ~0.8 so many keys need attempt-1/2 homes."""
    bk = get_backend(None)
    spec, st = hm.hashmap_create(bk, capacity, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=block_size)
    keys = jnp.asarray(np.random.default_rng(7).permutation(1 << 20)[:nkeys],
                       jnp.uint32)
    vals = keys * 3 + 1
    st, ok = hm.insert(bk, spec, st, keys, vals, capacity=nkeys, attempts=3)
    return bk, spec, st, keys, vals, ok


def test_find_two_attempts_two_collectives():
    bk, spec, st, keys, _, _ = _loaded_map()
    with costs.recording() as log:
        hm.find(bk, spec, st, keys, capacity=keys.shape[0], attempts=2)
    c = log.by_op("hashmap.find")
    assert c.collectives == 2 and c.rounds == 2


def test_find_sequential_attempts_four_collectives():
    bk, spec, st, keys, _, _ = _loaded_map()
    with costs.recording() as log:
        hm.find(bk, spec, st, keys, capacity=keys.shape[0], attempts=2,
                speculative=False)
    c = log.by_op("hashmap.find")
    assert c.collectives == 4 and c.rounds == 4


def test_speculative_find_matches_serial_oracle():
    bk, spec, st, keys, vals, ok = _loaded_map()
    n = keys.shape[0]
    # mix of present keys (including attempt-1 residents) and absent keys
    queries = jnp.concatenate([keys, keys + jnp.uint32(1 << 21)])
    _, v_spec, f_spec = hm.find(bk, spec, st, queries, capacity=2 * n)
    _, v_ser, f_ser = hm.find(bk, spec, st, queries, capacity=2 * n,
                              speculative=False)
    assert np.array_equal(np.asarray(f_spec), np.asarray(f_ser))
    assert np.array_equal(np.asarray(v_spec), np.asarray(v_ser))
    # inserted keys found at 2 attempts must carry the inserted value
    fs = np.asarray(f_spec[:n])
    assert fs.sum() > 0
    assert (np.asarray(v_spec[:n])[fs] ==
            (np.asarray(keys) * 3 + 1)[fs]).all()
    # absent keys are never "found"
    assert not np.asarray(f_spec[n:]).any()


def test_speculative_find_atomic_promise():
    bk, spec, st, keys, _, _ = _loaded_map()
    st1, v1, f1 = hm.find(bk, spec, st, keys, capacity=keys.shape[0],
                          promise=ConProm.HashMap.find_insert)
    st2, v2, f2 = hm.find(bk, spec, st, keys, capacity=keys.shape[0],
                          promise=ConProm.HashMap.find_insert,
                          speculative=False)
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
    assert np.array_equal(np.asarray(v1), np.asarray(v2))
    # the read-bit dance is net-zero on the status word either way
    assert np.array_equal(np.asarray(st1.status), np.asarray(st2.status))


# ---------------------------------------------------------------------------
# fused reply == oracle alignment
# ---------------------------------------------------------------------------

def test_fused_reply_aligns_with_request_batch():
    bk = get_backend(None)
    n = 32
    pay = jnp.asarray(np.random.default_rng(3).permutation(n), jnp.uint32)
    valid = jnp.asarray(np.random.default_rng(4).random(n) < 0.7)
    req = route(bk, pay, jnp.zeros(n, jnp.int32), capacity=n, valid=valid)
    out, answered = reply(bk, req, req.payload[:, 0] * 5 + 2, orig_n=n)
    ans = np.asarray(answered)
    assert np.array_equal(ans, np.asarray(valid))
    assert np.array_equal(np.asarray(out[:, 0])[ans],
                          np.asarray(pay)[ans] * 5 + 2)


# ---------------------------------------------------------------------------
# send-buffer construction kernel: all impls agree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_bin_offsets_impls_match_oracle(impl):
    rng = np.random.default_rng(11)
    nbins = 8
    bins = jnp.asarray(rng.integers(0, nbins, 300), jnp.int32)
    valid = jnp.asarray(rng.random(300) < 0.8)
    oc, oo = ref.bin_offsets_ref(bins, nbins, valid)
    c, o = kops.bin_offsets(bins, nbins, valid, impl=impl)
    assert np.array_equal(np.asarray(oc), np.asarray(c)), impl
    ov = np.asarray(valid)
    assert np.array_equal(np.asarray(oo)[ov], np.asarray(o)[ov]), impl


def test_bin_offsets_slots_are_unique_per_bin():
    rng = np.random.default_rng(13)
    nbins = 4
    bins = jnp.asarray(rng.integers(0, nbins, 100), jnp.int32)
    _, offs = kops.bin_offsets(bins, nbins, impl="jnp")
    b, o = np.asarray(bins), np.asarray(offs)
    for d in range(nbins):
        mine = np.sort(o[b == d])
        assert np.array_equal(mine, np.arange(mine.size))  # dense + stable
