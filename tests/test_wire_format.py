"""Wire-format and collective-round assertions for the fused exchange.

Pins the tentpole optimizations quantitatively via ``costs.recording()``:

  * route ships exactly ONE metadata lane (L+1 u32 lanes per item);
  * reply ships ZERO metadata lanes (L u32 lanes per item) — the
    inverse-permutation all-to-all needs no src_pos on the wire;
  * a 2-attempt hashmap find costs 2 collectives (two speculative
    flows on one ExchangePlan), down from 4 for the sequential loop,
    at the SAME wire bytes as the pre-plan hand-fused dual batch;
  * a fused find+insert under ``ConProm.HashMap.find_insert`` costs 2
    collectives per round trip where ``Promise.FINE`` costs 4;
  * a fused push+pop costs 2 collectives where ``Promise.FINE`` costs 3;

and pins the semantics of every fusion against the serial oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from jax import ShapeDtypeStruct as SDS

from repro.core import ConProm, Promise, costs, get_backend, route
from repro.core.exchange import reply
from repro.containers import hashmap as hm
from repro.containers import queue as q
from repro.kernels import ops as kops
from repro.kernels import ref


# ---------------------------------------------------------------------------
# bytes per item: one metadata lane out, zero lanes back
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lanes", [1, 3])
def test_route_ships_one_metadata_lane(lanes):
    bk = get_backend(None)
    n, cap = 16, 16
    pay = jnp.zeros((n, lanes), jnp.uint32)
    with costs.recording() as log:
        route(bk, pay, jnp.zeros(n, jnp.int32), capacity=cap, op_name="op")
    c = log.by_op("op")
    # P * C * (L + 1) u32 lanes: payload + packed (valid | src_pos) meta
    assert c.bytes_out == 1 * cap * (lanes + 1) * 4
    assert c.bytes_moved == c.bytes_out and c.bytes_in == 0
    assert c.collectives == 1 and c.rounds == 1


@pytest.mark.parametrize("lanes", [1, 3])
def test_reply_ships_zero_metadata_lanes(lanes):
    bk = get_backend(None)
    n, cap = 16, 16
    req = route(bk, jnp.zeros((n, 2), jnp.uint32), jnp.zeros(n, jnp.int32),
                capacity=cap)
    with costs.recording() as log:
        reply(bk, req, jnp.zeros((cap, lanes), jnp.uint32), orig_n=n,
              op_name="op")
    c = log.by_op("op")
    # pure inverse all-to-all: P * C * L u32 lanes, no src_pos, no valid
    assert c.bytes_in == 1 * cap * lanes * 4
    assert c.bytes_moved == c.bytes_in and c.bytes_out == 0
    assert c.collectives == 1 and c.rounds == 1


def test_request_reply_direction_split():
    bk = get_backend(None)
    n = 8
    with costs.recording() as log:
        req = route(bk, jnp.zeros((n, 1), jnp.uint32),
                    jnp.zeros(n, jnp.int32), capacity=n, op_name="op")
        reply(bk, req, req.payload[:, :1], orig_n=n, op_name="op")
    c = log.by_op("op")
    assert c.bytes_out == n * 2 * 4          # 1 payload lane + meta lane
    assert c.bytes_in == n * 1 * 4           # 1 payload lane only
    assert c.bytes_moved == c.bytes_out + c.bytes_in
    assert c.rounds == 2


# ---------------------------------------------------------------------------
# collective rounds: speculative dual-attempt find
# ---------------------------------------------------------------------------

def _loaded_map(nkeys=200, capacity=256, block_size=4):
    """A hash map loaded to ~0.8 so many keys need attempt-1/2 homes."""
    bk = get_backend(None)
    spec, st = hm.hashmap_create(bk, capacity, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=block_size)
    keys = jnp.asarray(np.random.default_rng(7).permutation(1 << 20)[:nkeys],
                       jnp.uint32)
    vals = keys * 3 + 1
    st, ok = hm.insert(bk, spec, st, keys, vals, capacity=nkeys, attempts=3)
    return bk, spec, st, keys, vals, ok


def test_find_two_attempts_two_collectives():
    bk, spec, st, keys, _, _ = _loaded_map()
    with costs.recording() as log:
        hm.find(bk, spec, st, keys, capacity=keys.shape[0], attempts=2)
    c = log.by_op("hashmap.find")
    assert c.collectives == 2 and c.rounds == 2


def test_find_sequential_attempts_four_collectives():
    bk, spec, st, keys, _, _ = _loaded_map()
    with costs.recording() as log:
        hm.find(bk, spec, st, keys, capacity=keys.shape[0], attempts=2,
                speculative=False)
    c = log.by_op("hashmap.find")
    assert c.collectives == 4 and c.rounds == 4


def test_speculative_find_matches_serial_oracle():
    bk, spec, st, keys, vals, ok = _loaded_map()
    n = keys.shape[0]
    # mix of present keys (including attempt-1 residents) and absent keys
    queries = jnp.concatenate([keys, keys + jnp.uint32(1 << 21)])
    _, v_spec, f_spec = hm.find(bk, spec, st, queries, capacity=2 * n)
    _, v_ser, f_ser = hm.find(bk, spec, st, queries, capacity=2 * n,
                              speculative=False)
    assert np.array_equal(np.asarray(f_spec), np.asarray(f_ser))
    assert np.array_equal(np.asarray(v_spec), np.asarray(v_ser))
    # inserted keys found at 2 attempts must carry the inserted value
    fs = np.asarray(f_spec[:n])
    assert fs.sum() > 0
    assert (np.asarray(v_spec[:n])[fs] ==
            (np.asarray(keys) * 3 + 1)[fs]).all()
    # absent keys are never "found"
    assert not np.asarray(f_spec[n:]).any()


def test_speculative_find_atomic_promise():
    bk, spec, st, keys, _, _ = _loaded_map()
    st1, v1, f1 = hm.find(bk, spec, st, keys, capacity=keys.shape[0],
                          promise=ConProm.HashMap.find_insert)
    st2, v2, f2 = hm.find(bk, spec, st, keys, capacity=keys.shape[0],
                          promise=ConProm.HashMap.find_insert,
                          speculative=False)
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
    assert np.array_equal(np.asarray(v1), np.asarray(v2))
    # the read-bit dance is net-zero on the status word either way
    assert np.array_equal(np.asarray(st1.status), np.asarray(st2.status))


def test_speculative_find_two_flow_lane_counts():
    """The two-flow plan ships EXACTLY the bytes of the old hand-fused
    dual batch: request 2C rows x (1 + Lk + meta) lanes, reply 2C rows x
    (Lv + found) lanes — the plan refactor changes the scheduler, not
    the wire."""
    bk, spec, st, keys, _, _ = _loaded_map()
    n = keys.shape[0]
    lk = spec.key_packer.lanes        # 1
    lv = spec.val_packer.lanes        # 1
    with costs.recording() as log:
        hm.find(bk, spec, st, keys, capacity=n, attempts=2)
    c = log.by_op("hashmap.find")
    assert c.bytes_out == 2 * n * (1 + lk + 1) * 4    # two C-row segments
    assert c.bytes_in == 2 * n * (lv + 1) * 4
    assert c.collectives == 2 and c.rounds == 2


# ---------------------------------------------------------------------------
# collective rounds: fused find+insert (the plan/commit acceptance pin)
# ---------------------------------------------------------------------------

def test_find_insert_fused_two_collectives_fine_four():
    """ConProm.HashMap.find_insert fuses both ops into 2 collectives per
    round trip; the Promise.FINE sequential schedule costs exactly 4."""
    bk, spec, st, keys, _, _ = _loaded_map()
    n = keys.shape[0]
    ins = keys + jnp.uint32(1 << 22)
    with costs.recording() as log_f:
        hm.find_insert(bk, spec, st, keys, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert)
    with costs.recording() as log_s:
        hm.find_insert(bk, spec, st, keys, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert | Promise.FINE)
    assert log_f.total().collectives == 2 and log_f.total().rounds == 2
    assert log_s.total().collectives == 4 and log_s.total().rounds == 4


def test_find_insert_fused_matches_fine_oracle():
    bk, spec, st, keys, _, _ = _loaded_map()
    n = keys.shape[0]
    queries = jnp.concatenate([keys[:100], keys[:100] + jnp.uint32(1 << 21)])
    ins = keys + jnp.uint32(1 << 22)
    f = hm.find_insert(bk, spec, st, queries, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert)
    s = hm.find_insert(bk, spec, st, queries, ins, ins * 9, capacity=n,
                       promise=ConProm.HashMap.find_insert | Promise.FINE)
    for got, want in zip(f[1:], s[1:]):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(f[0], s[0]):         # table state, bit-identical
        assert np.array_equal(np.asarray(got), np.asarray(want))
    found = np.asarray(f[2])
    # single-attempt probe: attempt-0 residents found, attempt-1 homes
    # legitimately missed (the op documents attempts=1 semantics)
    assert found[:100].sum() > 50
    assert not found[100:].any()              # absent keys never found
    vals = np.asarray(f[1])[:100]
    keys_np = np.asarray(queries)[:100]
    assert (vals[found[:100]] == (keys_np * 3 + 1)[found[:100]]).all()


# ---------------------------------------------------------------------------
# collective rounds: fused push+pop
# ---------------------------------------------------------------------------

def test_push_pop_fused_two_collectives_fine_three():
    bk = get_backend(None)
    spec, st = q.queue_create(bk, 128, SDS((), jnp.uint32), circular=True)
    vals = jnp.arange(32, dtype=jnp.uint32) + 1
    dest = jnp.zeros(32, jnp.int32)
    with costs.recording() as log_f:
        f = q.push_pop(bk, spec, st, vals, dest, 32, 16, 0)
    with costs.recording() as log_s:
        s = q.push_pop(bk, spec, st, vals, dest, 32, 16, 0,
                       promise=ConProm.CircularQueue.push_pop | Promise.FINE)
    assert log_f.total().collectives == 2 and log_f.total().rounds == 2
    assert log_s.total().collectives == 3 and log_s.total().rounds == 3
    for got, want in zip(f[1:], s[1:]):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(f[0], s[0]):
        assert np.array_equal(np.asarray(got), np.asarray(want))
    # push lands before pop: this round's pushes are poppable
    assert int(f[4].sum()) == 16
    assert np.array_equal(np.asarray(f[3])[np.asarray(f[4])],
                          np.arange(16, dtype=np.uint32) + 1)


# ---------------------------------------------------------------------------
# fused reply == oracle alignment
# ---------------------------------------------------------------------------

def test_fused_reply_aligns_with_request_batch():
    bk = get_backend(None)
    n = 32
    pay = jnp.asarray(np.random.default_rng(3).permutation(n), jnp.uint32)
    valid = jnp.asarray(np.random.default_rng(4).random(n) < 0.7)
    req = route(bk, pay, jnp.zeros(n, jnp.int32), capacity=n, valid=valid)
    out, answered = reply(bk, req, req.payload[:, 0] * 5 + 2, orig_n=n)
    ans = np.asarray(answered)
    assert np.array_equal(ans, np.asarray(valid))
    assert np.array_equal(np.asarray(out[:, 0])[ans],
                          np.asarray(pay)[ans] * 5 + 2)


# ---------------------------------------------------------------------------
# send-buffer construction kernel: all impls agree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_bin_offsets_impls_match_oracle(impl):
    rng = np.random.default_rng(11)
    nbins = 8
    bins = jnp.asarray(rng.integers(0, nbins, 300), jnp.int32)
    valid = jnp.asarray(rng.random(300) < 0.8)
    oc, oo = ref.bin_offsets_ref(bins, nbins, valid)
    c, o = kops.bin_offsets(bins, nbins, valid, impl=impl)
    assert np.array_equal(np.asarray(oc), np.asarray(c)), impl
    ov = np.asarray(valid)
    assert np.array_equal(np.asarray(oo)[ov], np.asarray(o)[ov]), impl


@pytest.mark.parametrize("impl", ["oracle", "jnp", "pallas"])
def test_multi_bin_offsets_impls_agree(impl):
    """Segmented multi-flow slot assignment: every impl bins the same
    composite (dest, flow) buckets with stable within-bucket ranks."""
    rng = np.random.default_rng(17)
    nbins, nflows, n = 4, 3, 200
    bins = jnp.asarray(rng.integers(0, nbins, n), jnp.int32)
    flow = jnp.asarray(rng.integers(0, nflows, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    counts, offs = kops.multi_bin_offsets(bins, flow, nbins, nflows, valid,
                                          impl=impl)
    b, f, v, o = map(np.asarray, (bins, flow, valid, offs))
    c = np.asarray(counts)
    for d in range(nbins):
        for fl in range(nflows):
            sel = (b == d) & (f == fl) & v
            assert c[d, fl] == sel.sum(), impl
            assert np.array_equal(np.sort(o[sel]),
                                  np.arange(sel.sum())), impl  # dense+stable


def test_bin_offsets_slots_are_unique_per_bin():
    rng = np.random.default_rng(13)
    nbins = 4
    bins = jnp.asarray(rng.integers(0, nbins, 100), jnp.int32)
    _, offs = kops.bin_offsets(bins, nbins, impl="jnp")
    b, o = np.asarray(bins), np.asarray(offs)
    for d in range(nbins):
        mine = np.sort(o[b == d])
        assert np.array_equal(mine, np.arange(mine.size))  # dense + stable
