"""Tier-1 lint hook: dead locals stay dead.

Porting the containers onto the ExchangePlan scheduler flagged unused
locals that had survived review (``nprocs`` in ``queue.pop``, ``m`` in
``queue._append``).  This hook keeps the class of bug out:

  * when ``ruff`` is on PATH, run the configured ruleset
    (``[tool.ruff]`` in pyproject.toml — pyflakes + core pycodestyle);
  * always run a dependency-free AST fallback for the highest-signal
    rule, F841 (local assigned but never read), so the check holds even
    in environments without ruff.

The fallback is deliberately conservative: only simple ``name = expr``
/ annotated assignments in function scopes, names not starting with an
underscore, never flagged when the name is read anywhere in the
function (including nested closures).
"""

from __future__ import annotations

import ast
import pathlib
import shutil
import subprocess
import sys

import pytest

_ROOT = pathlib.Path(__file__).resolve().parent.parent
_SCAN = ["src", "benchmarks", "tests"]


def _py_files():
    for top in _SCAN:
        yield from sorted((_ROOT / top).rglob("*.py"))


def test_ruff_clean():
    """The configured ruff ruleset passes repo-wide (skip if no ruff)."""
    if shutil.which("ruff") is None:
        pytest.skip("ruff not installed in this environment")
    proc = subprocess.run(
        ["ruff", "check", *_SCAN], cwd=_ROOT,
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def _scope_nodes(fn: ast.AST):
    """Yield nodes of ``fn``'s own scope (nested def/class bodies cut)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def _unused_locals(tree: ast.AST, path: pathlib.Path):
    findings = []
    funcs = [n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        declared = set()
        for node in _scope_nodes(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                declared.update(node.names)
        # every name read anywhere in the function, closures included
        loaded = {n.id for n in ast.walk(fn)
                  if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
        assigns = {}
        for node in _scope_nodes(fn):
            target = None
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                target = node.targets[0]
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                target = node.target
            if isinstance(target, ast.Name):
                assigns.setdefault(target.id, node.lineno)
        for name, lineno in sorted(assigns.items(), key=lambda kv: kv[1]):
            if name.startswith("_") or name in loaded or name in declared:
                continue
            findings.append(f"{path.relative_to(_ROOT)}:{lineno}: "
                            f"local '{name}' assigned in {fn.name}() "
                            "but never read (F841)")
    return findings


def test_no_unused_locals():
    """F841 fallback: no function-scope local is assigned and never read."""
    findings = []
    for path in _py_files():
        tree = ast.parse(path.read_text(), filename=str(path))
        findings.extend(_unused_locals(tree, path))
    assert not findings, "\n".join(findings)


#: the physical collective layer (DESIGN.md sections 1.7/1.9): only the
#: transport implementations, the backend itself, and the fault-injection
#: wrapper may launch the raw all-to-all — everything else goes through
#: Transport.request/request_start so split-phase scheduling, fault
#: injection, and cost attribution stay layered.
_ALL_TO_ALL_ALLOWED = {
    "src/repro/core/transport.py",
    "src/repro/core/backend.py",
    "src/repro/core/faults.py",
}


def test_no_raw_all_to_all_outside_transport():
    """Layering rule: no ``<obj>.all_to_all(...)`` call outside the
    physical collective layer.  The standalone ``exchange.reply`` used
    to hold the last such call; it now rides ``DenseTransport.reply``,
    so a new direct launch is a layering regression."""
    findings = []
    for path in _py_files():
        rel = str(path.relative_to(_ROOT))
        if rel in _ALL_TO_ALL_ALLOWED:
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "all_to_all"):
                findings.append(
                    f"{rel}:{node.lineno}: direct all_to_all launch "
                    "outside core/transport.py (route it through "
                    "Transport.request / request_start)")
    assert not findings, "\n".join(findings)


def test_no_scatter_updates_in_transport():
    """One-kernel wire rule (DESIGN.md section 1.10): the physical
    transport layer builds every wire buffer through
    ``kernels/ops.pack_rows`` / ``place_rows`` — the scatter fallback
    lives in ONE declared place (``object_container.scatter_rows``), so
    ``core/transport.py`` must contain ZERO ``<expr>.at[...].set(...)``
    updates.  A new one silently reintroduces a standalone XLA scatter
    pass per commit and breaks the jaxpr census pin
    (tests/test_wire_format.py::test_fused_wire_traces_zero_scatter_ops)."""
    path = _ROOT / "src" / "repro" / "core" / "transport.py"
    tree = ast.parse(path.read_text(), filename=str(path))
    findings = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "set"
                and isinstance(node.func.value, ast.Subscript)
                and isinstance(node.func.value.value, ast.Attribute)
                and node.func.value.value.attr == "at"):
            findings.append(
                f"src/repro/core/transport.py:{node.lineno}: "
                ".at[...].set scatter update in the transport layer "
                "(use kernels/ops.pack_rows or place_rows; the jnp "
                "fallback is object_container.scatter_rows)")
    assert not findings, "\n".join(findings)


if __name__ == "__main__":
    test_no_unused_locals()
    test_no_raw_all_to_all_outside_transport()
    test_no_scatter_updates_in_transport()
    print("lint fallback clean", file=sys.stderr)
