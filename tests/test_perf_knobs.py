"""The EXPERIMENTS.md section-Perf knobs must preserve semantics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import init_state, make_train_step
from repro.models import lm
from repro.models.sharding import Axes


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh(1, 1)


def test_grad_accum_matches_full_batch(mesh):
    cfg1 = reduced(get_config("qwen3-4b"))
    cfg2 = dataclasses.replace(cfg1, grad_accum=2)
    rng = jax.random.PRNGKey(0)
    params, opt, _, _ = init_state(cfg1, mesh, rng)
    batch = {"tokens": jax.random.randint(rng, (4, 33), 0, cfg1.vocab),
             "loss_mask": jnp.ones((4, 32), jnp.float32)}
    p1, _, m1 = jax.jit(make_train_step(cfg1, mesh))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg2, mesh))(params, opt, batch)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree_util.tree_leaves(p1),
                            jax.tree_util.tree_leaves(p2)))
    assert d < 1e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4


def test_mla_absorb_exact(mesh):
    cfg = reduced(get_config("deepseek-v3-671b"))
    cfga = dataclasses.replace(cfg, mla_absorb=True)
    axes = Axes.from_mesh(mesh)
    rng = jax.random.PRNGKey(0)
    p = lm.init_params(cfg, rng)
    T = 20
    toks = jax.random.randint(rng, (2, T), 0, cfg.vocab)
    outs = []
    for c in (cfg, cfga):
        cache, _ = lm.prefill(p, c, {"tokens": toks[:, :T - 1]},
                              cache_len=T + 4, mesh=mesh, axes=axes)
        lg, cache = lm.decode_step(p, c, cache, toks[:, T - 1:],
                                   mesh=mesh, axes=axes)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        for _ in range(2):
            lg, cache = lm.decode_step(p, c, cache, tok, mesh=mesh,
                                       axes=axes)
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4, rtol=1e-4)


def test_window_cache_ring_exact(mesh):
    cfg = reduced(get_config("gemma3-4b"))
    cfgw = dataclasses.replace(cfg, window_cache=True)
    axes = Axes.from_mesh(mesh)
    rng = jax.random.PRNGKey(1)
    p = lm.init_params(cfg, rng)
    T = 40
    toks = jax.random.randint(rng, (1, T), 0, cfg.vocab)
    outs = []
    for c in (cfg, cfgw):
        cache, _ = lm.prefill(p, c, {"tokens": toks[:, :T - 1]},
                              cache_len=T + 4, mesh=mesh, axes=axes)
        lg, cache = lm.decode_step(p, c, cache, toks[:, T - 1:],
                                   mesh=mesh, axes=axes)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        for _ in range(3):
            lg, cache = lm.decode_step(p, c, cache, tok, mesh=mesh,
                                       axes=axes)
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4, rtol=1e-4)
    # and the ring caches really are window-sized
    cache, _ = lm.prefill(p, cfgw, {"tokens": toks[:, :T - 1]},
                          cache_len=T + 4, mesh=mesh, axes=axes)
    k_shapes = [v.shape for kpath, v in
                jax.tree_util.tree_leaves_with_path(cache)
                if "'k'" in jax.tree_util.keystr(kpath)]
    assert any(s[-2] == cfgw.sliding_window for s in k_shapes)


def test_window_decode_masks_like_forward(mesh):
    """Full-cache decode with sliding mask == teacher-forced forward."""
    cfg = reduced(get_config("gemma3-4b"))
    axes = Axes.from_mesh(mesh)
    rng = jax.random.PRNGKey(2)
    p = lm.init_params(cfg, rng)
    T = 36
    toks = jax.random.randint(rng, (1, T), 0, cfg.vocab)
    h, _, _, _ = lm.forward(p, cfg, toks, mesh=mesh, axes=axes)
    fl = jnp.einsum("bd,vd->bv", h[:, -1], lm.head_table(p, cfg))
    cache, _ = lm.prefill(p, cfg, {"tokens": toks[:, :T - 1]},
                          cache_len=T + 4, mesh=mesh, axes=axes)
    lg, _ = lm.decode_step(p, cfg, cache, toks[:, T - 1:],
                           mesh=mesh, axes=axes)
    np.testing.assert_allclose(np.asarray(fl[:, :cfg.vocab]),
                               np.asarray(lg[:, :cfg.vocab]),
                               atol=2e-2, rtol=2e-2)


def test_bf16_exchange_close(mesh):
    cfg = reduced(get_config("arctic-480b"))
    cfgb = dataclasses.replace(cfg, moe_payload_dtype="bfloat16")
    axes = Axes.from_mesh(mesh)
    rng = jax.random.PRNGKey(3)
    p = lm.init_params(cfg, rng)
    batch = {"tokens": jax.random.randint(rng, (2, 17), 0, cfg.vocab),
             "loss_mask": jnp.ones((2, 16), jnp.float32)}
    l1, _ = lm.loss_fn(p, cfg, batch, mesh=mesh, axes=axes)
    l2, _ = lm.loss_fn(p, cfgb, batch, mesh=mesh, axes=axes)
    assert abs(float(l1) - float(l2)) < 0.02


def test_bf16_probs_close(mesh):
    cfg = reduced(get_config("qwen3-4b"))
    cfgb = dataclasses.replace(cfg, attn_probs_bf16=True)
    axes = Axes.from_mesh(mesh)
    rng = jax.random.PRNGKey(4)
    p = lm.init_params(cfg, rng)
    batch = {"tokens": jax.random.randint(rng, (2, 33), 0, cfg.vocab),
             "loss_mask": jnp.ones((2, 32), jnp.float32)}
    l1, _ = lm.loss_fn(p, cfg, batch, mesh=mesh, axes=axes)
    l2, _ = lm.loss_fn(p, cfgb, batch, mesh=mesh, axes=axes)
    assert abs(float(l1) - float(l2)) < 0.02


def test_moe_dedup_dispatch_exact(mesh):
    import repro.models.moe as moe_mod
    cfg = reduced(get_config("deepseek-v3-671b"))
    cfgd = dataclasses.replace(cfg, moe_dedup_dispatch=True)
    rng = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(rng, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    axes = Axes.from_mesh(mesh)
    y1, _, s1 = moe_mod.moe_apply(p, x, cfg, mesh, axes)
    y2, _, s2 = moe_mod.moe_apply(p, x, cfgd, mesh, axes)
    np.testing.assert_array_equal(np.asarray(s1["expert_load"]),
                                  np.asarray(s2["expert_load"]))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-5, rtol=1e-5)


def test_mla_cp_decode_exact_multirank():
    """Context-parallel MLA decode == serial decode, model axis = 4.

    Runs in a subprocess world of 8 devices via spmd battery as well;
    here we check the nm=1 degenerate form composes with absorb."""
    import subprocess, sys, os
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh
from repro.configs import get_config, reduced
from repro.models import lm
from repro.models.sharding import Axes
rng = jax.random.PRNGKey(0)
cfg = reduced(get_config('deepseek-v3-671b'))
cfg = dataclasses.replace(cfg, moe_capacity_slack=8.0)
p = lm.init_params(cfg, rng)
T = 24
toks = jax.random.randint(rng, (2, T), 0, cfg.vocab)
def run(c, mesh):
    axes = Axes.from_mesh(mesh)
    cache, _ = lm.prefill(p, c, {'tokens': toks[:, :T-1]}, cache_len=T+8, mesh=mesh, axes=axes)
    lg, cache = lm.decode_step(p, c, cache, toks[:, T-1:], mesh=mesh, axes=axes)
    for _ in range(2):
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        lg, cache = lm.decode_step(p, c, cache, tok, mesh=mesh, axes=axes)
    return np.asarray(lg)
mesh1 = make_mesh((1,1), ('data','model'))
mesh24 = make_mesh((2,4), ('data','model'))
base = run(cfg, mesh1)
cfgc = dataclasses.replace(cfg, mla_absorb=True, mla_cp_decode=True)
cp4 = run(cfgc, mesh24)
err = float(np.abs(base - cp4).max())
assert err < 1e-4, err
print('OK', err)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
