"""Container semantics vs python-dict/list oracles (serial backend)."""

import jax.numpy as jnp
import numpy as np
import pytest
from jax import ShapeDtypeStruct as SDS

from repro.core import ConProm, costs, get_backend
from repro.kernels import ops as kops
from repro.containers import bloom as bl
from repro.containers import darray as da
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb
from repro.containers import queue as q


@pytest.fixture
def bk():
    return get_backend(None)


class TestHashMap:
    def test_insert_find_roundtrip(self, bk, rng):
        spec, st = hm.hashmap_create(bk, 2048, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        keys = jnp.asarray(rng.permutation(10000)[:500], jnp.uint32)
        vals = keys * 13 + 1
        st, ok = hm.insert(bk, spec, st, keys, vals, capacity=500)
        assert bool(ok.all())
        st, v, found = hm.find(bk, spec, st, keys, capacity=500)
        assert bool(found.all())
        assert np.array_equal(np.asarray(v), np.asarray(vals))

    def test_missing_keys_not_found(self, bk, rng):
        spec, st = hm.hashmap_create(bk, 1024, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        keys = jnp.arange(100, dtype=jnp.uint32)
        st, _ = hm.insert(bk, spec, st, keys, keys, capacity=128)
        st, _, found = hm.find(
            bk, spec, st, jnp.arange(1000, 1100, dtype=jnp.uint32),
            capacity=128, promise=ConProm.HashMap.find)
        assert not bool(found.any())

    def test_overwrite_semantics(self, bk):
        spec, st = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        keys = jnp.asarray([7, 7, 7], jnp.uint32)
        vals = jnp.asarray([1, 2, 3], jnp.uint32)
        st, _ = hm.insert(bk, spec, st, keys, vals, capacity=8)
        st, v, found = hm.find(bk, spec, st, keys[:1], capacity=8)
        assert int(v[0]) == 3  # sequential last-wins

    def test_vs_dict_oracle(self, bk, rng):
        spec, st = hm.hashmap_create(bk, 4096, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        oracle = {}
        for _ in range(4):
            keys = rng.integers(0, 400, 200).astype(np.uint32)
            vals = rng.integers(0, 1 << 30, 200).astype(np.uint32)
            for k_, v_ in zip(keys, vals):
                oracle[int(k_)] = int(v_)
            st, ok = hm.insert(bk, spec, st, jnp.asarray(keys),
                               jnp.asarray(vals), capacity=256)
            assert bool(ok.all())
        probe = jnp.asarray(sorted(oracle), jnp.uint32)
        st, v, found = hm.find(bk, spec, st, probe, capacity=512)
        assert bool(found.all())
        assert np.array_equal(np.asarray(v),
                              np.asarray([oracle[int(k_)] for k_ in probe]))

    def test_accumulate_mode(self, bk):
        from repro.kernels.ops import MODE_ADD
        spec, st = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        keys = jnp.asarray([1, 2, 1, 1, 2], jnp.uint32)
        ones = jnp.ones(5, jnp.uint32)
        st, _ = hm.insert(bk, spec, st, keys, ones, capacity=8,
                          mode=MODE_ADD)
        st, _ = hm.insert(bk, spec, st, keys, ones, capacity=8,
                          mode=MODE_ADD)
        st, v, found = hm.find(bk, spec, st, jnp.asarray([1, 2], jnp.uint32),
                               capacity=8)
        assert v.tolist() == [6, 4]

    def test_count_and_entries(self, bk):
        spec, st = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        keys = jnp.arange(40, dtype=jnp.uint32)
        st, _ = hm.insert(bk, spec, st, keys, keys, capacity=64)
        assert int(hm.count_ready(bk, st)) == 40
        k, v, occ = hm.local_entries(spec, st)
        assert int(occ.sum()) == 40

    def test_resize(self, bk):
        spec, st = hm.hashmap_create(bk, 256, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        keys = jnp.arange(100, dtype=jnp.uint32)
        st, _ = hm.insert(bk, spec, st, keys, keys * 2, capacity=128)
        spec2, st2 = hm.resize(bk, spec, st, 1024, capacity_per_pair=256)
        st2, v, found = hm.find(bk, spec2, st2, keys, capacity=128)
        assert bool(found.all())
        assert np.array_equal(np.asarray(v), np.asarray(keys * 2))

    def test_full_table_fails_gracefully(self, bk):
        spec, st = hm.hashmap_create(bk, 16, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        keys = jnp.arange(100, dtype=jnp.uint32) + 1
        st, ok = hm.insert(bk, spec, st, keys, keys, capacity=128,
                           attempts=1)
        assert int(ok.sum()) <= 16
        assert not bool(ok.all())


class TestQueues:
    def test_fifo_order(self, bk):
        spec, st = q.queue_create(bk, 64, SDS((), jnp.uint32))
        vals = jnp.arange(10, dtype=jnp.uint32) + 1
        st, pushed, dropped = q.push(bk, spec, st, vals,
                                     jnp.zeros(10, jnp.int32), capacity=16)
        assert int(pushed) == 10 and int(dropped) == 0
        st, out, got = q.local_nonatomic_pop(spec, st, 10)
        assert np.array_equal(np.asarray(out)[np.asarray(got)],
                              np.asarray(vals))

    def test_ring_wraparound(self, bk):
        spec, st = q.queue_create(bk, 8, SDS((), jnp.uint32))
        for wave in range(5):
            vals = jnp.arange(4, dtype=jnp.uint32) + wave * 10
            st, _, dropped = q.push(bk, spec, st, vals,
                                    jnp.zeros(4, jnp.int32), capacity=8)
            assert int(dropped) == 0
            st, out, got = q.local_nonatomic_pop(spec, st, 4)
            assert np.array_equal(np.asarray(out)[np.asarray(got)],
                                  np.asarray(vals))

    def test_full_ring_drops(self, bk):
        spec, st = q.queue_create(bk, 8, SDS((), jnp.uint32))
        vals = jnp.arange(20, dtype=jnp.uint32)
        st, pushed, dropped = q.push(bk, spec, st, vals,
                                     jnp.zeros(20, jnp.int32), capacity=32)
        assert int(pushed) == 8 and int(dropped) == 12

    def test_remote_pop(self, bk):
        spec, st = q.queue_create(bk, 64, SDS((), jnp.uint32))
        vals = jnp.arange(20, dtype=jnp.uint32) + 1
        st, _, _ = q.push(bk, spec, st, vals, jnp.zeros(20, jnp.int32),
                          capacity=32)
        st, out, got = q.pop(bk, spec, st, 5, 0)
        assert int(got.sum()) == 5
        assert np.array_equal(np.asarray(out)[np.asarray(got)],
                              np.asarray(vals[:5]))
        assert int(q.size(st)) == 15

    def test_resize_preserves(self, bk):
        spec, st = q.queue_create(bk, 16, SDS((), jnp.uint32))
        vals = jnp.arange(10, dtype=jnp.uint32) + 1
        st, _, _ = q.push(bk, spec, st, vals, jnp.zeros(10, jnp.int32),
                          capacity=16)
        spec2, st2 = q.resize(bk, spec, st, 64)
        st2, out, got = q.local_nonatomic_pop(spec2, st2, 10)
        assert np.array_equal(np.asarray(out)[np.asarray(got)],
                              np.asarray(vals))

    def test_circular_cost_extra_amo(self, bk):
        specF, stF = q.queue_create(bk, 32, SDS((), jnp.uint32))
        specC, stC = q.queue_create(bk, 32, SDS((), jnp.uint32),
                                    circular=True)
        vals = jnp.arange(4, dtype=jnp.uint32)
        with costs.recording() as lf:
            q.push(bk, specF, stF, vals, jnp.zeros(4, jnp.int32), capacity=8)
        with costs.recording() as lc:
            q.push(bk, specC, stC, vals, jnp.zeros(4, jnp.int32), capacity=8)
        assert lf.by_op("queue.push").A == 1      # Table 2: A + nW
        assert lc.by_op("queue.push").A == 2      # Table 2: 2A + nW


class TestBloom:
    def test_no_false_negatives(self, bk, rng):
        spec, st = bl.bloom_create(bk, 1 << 15, SDS((), jnp.uint32), k=4)
        items = jnp.asarray(rng.permutation(1 << 20)[:512], jnp.uint32)
        st, _ = bl.insert(bk, spec, st, items, capacity=512)
        present = bl.find(bk, spec, st, items, capacity=512)
        assert bool(present.all())

    def test_false_positive_rate_bounded(self, bk, rng):
        spec, st = bl.bloom_create(bk, 1 << 16, SDS((), jnp.uint32), k=4)
        items = jnp.asarray(rng.permutation(1 << 20)[:1000], jnp.uint32)
        st, _ = bl.insert(bk, spec, st, items, capacity=1024)
        absent = jnp.asarray(rng.permutation(1 << 20)[:1000] + (1 << 21),
                             jnp.uint32)
        fp = bl.find(bk, spec, st, absent, capacity=1024)
        assert float(fp.mean()) < 0.05

    def test_atomic_first_inserter(self, bk):
        """Paper 5.4.2: duplicate batch insertions — exactly one 'new'."""
        spec, st = bl.bloom_create(bk, 1 << 12, SDS((), jnp.uint32), k=4)
        dup = jnp.full((32,), 12345, jnp.uint32)
        st, already = bl.insert(bk, spec, st, dup, capacity=64)
        assert int((~already).sum()) == 1

    def test_second_insert_present(self, bk):
        spec, st = bl.bloom_create(bk, 1 << 12, SDS((), jnp.uint32), k=4)
        items = jnp.arange(64, dtype=jnp.uint32)
        st, _ = bl.insert(bk, spec, st, items, capacity=64)
        st, already = bl.insert(bk, spec, st, items, capacity=64)
        assert bool(already.all())

    def test_insert_cost_single_amo(self, bk):
        spec, st = bl.bloom_create(bk, 1 << 12, SDS((), jnp.uint32), k=4)
        with costs.recording() as log:
            bl.insert(bk, spec, st, jnp.arange(8, dtype=jnp.uint32),
                      capacity=8)
        assert log.by_op("bloom.insert").A == 1   # Table 2: A


class TestDArray:
    def test_rput_rget(self, bk, rng):
        spec, st = da.darray_create(bk, 256, SDS((), jnp.float32))
        idx = jnp.asarray(rng.permutation(256)[:64], jnp.int32)
        vals = jnp.asarray(rng.standard_normal(64), jnp.float32)
        st = da.rput(bk, spec, st, idx, vals, capacity=64)
        out, found = da.rget(bk, spec, st, idx, capacity=64)
        assert bool(found.all())
        assert np.allclose(np.asarray(out), np.asarray(vals))

    def test_rput_add_mode(self, bk):
        spec, st = da.darray_create(bk, 64, SDS((), jnp.uint32))
        idx = jnp.asarray([3, 3, 3, 5], jnp.int32)
        vals = jnp.asarray([1, 2, 3, 9], jnp.uint32)
        st = da.rput(bk, spec, st, idx, vals, capacity=8, mode="add")
        out, _ = da.rget(bk, spec, st, jnp.asarray([3, 5], jnp.int32),
                         capacity=8)
        assert out.tolist() == [6, 9]

    def test_to_global(self, bk):
        spec, st = da.darray_create(bk, 32, SDS((), jnp.uint32))
        st = da.local_write(spec, st, jnp.arange(32),
                            jnp.arange(32, dtype=jnp.uint32) * 2)
        full = da.to_global(bk, spec, st)
        assert np.array_equal(np.asarray(full),
                              np.arange(32, dtype=np.uint32) * 2)


class TestHashMapBuffer:
    def test_figure4_workflow(self, bk, rng):
        """Paper Fig. 4: insert into the buffer, flush, then find."""
        mspec, mstate = hm.hashmap_create(bk, 2048, SDS((), jnp.uint32),
                                          SDS((), jnp.uint32), block_size=16)
        bspec, bstate = hb.create(bk, mspec, mstate, queue_capacity=1024,
                                  buffer_cap=512)
        keys = jnp.asarray(rng.permutation(5000)[:300], jnp.uint32)
        vals = keys + 7
        bstate, ovf = hb.insert(bspec, bstate, keys, vals)
        assert int(ovf) == 0
        bstate, dropped = hb.flush(bk, bspec, bstate, capacity=512)
        assert int(dropped) == 0
        _, v, found = hm.find(bk, mspec, bstate.map, keys, capacity=512,
                              promise=ConProm.HashMap.find)
        assert bool(found.all())
        assert np.array_equal(np.asarray(v), np.asarray(vals))

    def test_buffer_overflow_reported(self, bk):
        mspec, mstate = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                          SDS((), jnp.uint32), block_size=16)
        bspec, bstate = hb.create(bk, mspec, mstate, queue_capacity=64,
                                  buffer_cap=16)
        keys = jnp.arange(40, dtype=jnp.uint32)
        bstate, ovf = hb.insert(bspec, bstate, keys, keys)
        assert int(ovf) == 24

    def test_insert_is_local(self, bk):
        mspec, mstate = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                          SDS((), jnp.uint32), block_size=16)
        bspec, bstate = hb.create(bk, mspec, mstate, queue_capacity=64,
                                  buffer_cap=64)
        with costs.recording() as log:
            hb.insert(bspec, bstate, jnp.arange(8, dtype=jnp.uint32),
                      jnp.arange(8, dtype=jnp.uint32))
        c = log.by_op("hashmap_buffer.insert")
        assert c.collectives == 0 and c.local == 8

    def test_multiple_spill_flush_cycles(self, bk, rng):
        """Fill -> flush, repeatedly: every cycle's keys stay findable
        and every cycle reports zero drops (today's single-flush test
        generalized to the paper's steady-state usage)."""
        mspec, mstate = hm.hashmap_create(bk, 4096, SDS((), jnp.uint32),
                                          SDS((), jnp.uint32), block_size=16)
        bspec, bstate = hb.create(bk, mspec, mstate, queue_capacity=512,
                                  buffer_cap=128)
        all_keys = jnp.asarray(rng.permutation(1 << 16)[:384], jnp.uint32)
        for cyc in range(3):
            keys = all_keys[cyc * 128:(cyc + 1) * 128]
            bstate, ovf = hb.insert(bspec, bstate, keys, keys * 3 + cyc)
            assert int(ovf) == 0
            bstate, dropped = hb.flush(bk, bspec, bstate, capacity=128)
            assert int(dropped) == 0, f"cycle {cyc}"
            # buffer and ring are empty again after each flush
            assert int(bstate.buf_n[0]) == 0
            assert int(q.size(bstate.queue)) == 0
        _, v, found = hm.find(bk, mspec, bstate.map, all_keys, capacity=384,
                              promise=ConProm.HashMap.find, attempts=3)
        assert bool(found.all())
        expect = np.concatenate([np.asarray(all_keys[c * 128:(c + 1) * 128])
                                 * 3 + c for c in range(3)])
        assert np.array_equal(np.asarray(v), expect)

    def test_ring_full_drops_accounted_across_cycles(self, bk):
        """Spill into a too-small FastQueue: the overflowed items are
        counted, the survivors are still inserted, and the NEXT cycle is
        unaffected (ring drained by the flush)."""
        mspec, mstate = hm.hashmap_create(bk, 1024, SDS((), jnp.uint32),
                                          SDS((), jnp.uint32), block_size=16)
        bspec, bstate = hb.create(bk, mspec, mstate, queue_capacity=16,
                                  buffer_cap=64)
        keys = jnp.arange(40, dtype=jnp.uint32) + 1
        bstate, ovf = hb.insert(bspec, bstate, keys, keys)
        assert int(ovf) == 0
        bstate, dropped = hb.flush(bk, bspec, bstate, capacity=64)
        assert int(dropped) == 24            # ring admits 16 of 40
        _, _, found = hm.find(bk, mspec, bstate.map, keys, capacity=64,
                              promise=ConProm.HashMap.find)
        assert int(found.sum()) == 16
        # second cycle on the drained ring: no residue, full success
        keys2 = jnp.arange(10, dtype=jnp.uint32) + 100
        bstate, _ = hb.insert(bspec, bstate, keys2, keys2)
        bstate, dropped2 = hb.flush(bk, bspec, bstate, capacity=64)
        assert int(dropped2) == 0
        _, _, found2 = hm.find(bk, mspec, bstate.map, keys2, capacity=64,
                               promise=ConProm.HashMap.find)
        assert bool(found2.all())

    def test_table_full_drops_accounted(self, bk):
        """Flush into a table with no room: failed local inserts are
        counted in the drop total, not silently lost."""
        mspec, mstate = hm.hashmap_create(bk, 16, SDS((), jnp.uint32),
                                          SDS((), jnp.uint32), block_size=16)
        bspec, bstate = hb.create(bk, mspec, mstate, queue_capacity=64,
                                  buffer_cap=64)
        keys = jnp.arange(40, dtype=jnp.uint32) + 1
        bstate, _ = hb.insert(bspec, bstate, keys, keys)
        bstate, dropped = hb.flush(bk, bspec, bstate, capacity=64)
        assert int(dropped) == 40 - 16       # 16-slot table, 40 arrivals
        assert int(hm.count_ready(bk, bstate.map)) == 16

    def test_spill_rides_shared_plan(self, bk, rng):
        """spill_flow/spill_apply fuse the spill with a concurrent
        hashmap find: 2 collectives for both ops, same results as the
        eager spill."""
        from repro.core import ExchangePlan, costs as _costs
        mspec, mstate = hm.hashmap_create(bk, 2048, SDS((), jnp.uint32),
                                          SDS((), jnp.uint32), block_size=16)
        probe_keys = jnp.asarray(rng.permutation(4096)[:64], jnp.uint32)
        mstate, _ = hm.insert(bk, mspec, mstate, probe_keys, probe_keys * 5,
                              capacity=64)
        bspec, bstate = hb.create(bk, mspec, mstate, queue_capacity=256,
                                  buffer_cap=64)
        keys = jnp.asarray(rng.permutation(4096)[64:128], jnp.uint32)
        bstate, _ = hb.insert(bspec, bstate, keys, keys)

        with _costs.recording() as log:
            plan = ExchangePlan(name="spill_find")
            h_spill = hb.spill_flow(plan, bspec, bstate, capacity=64)
            lb = hm._block_of(mspec, probe_keys[:, None], 0)
            h_find = plan.add(jnp.concatenate(
                [(lb % mspec.nblocks_local).astype(jnp.uint32)[:, None],
                 probe_keys[:, None]], axis=1),
                lb // mspec.nblocks_local, 64, reply_lanes=2,
                op_name="hashmap.find")
            c = plan.commit(bk)
            bstate, dropped = hb.spill_apply(bk, c, h_spill, bspec, bstate)
            vf = c.view(h_find)
            rb = jnp.where(vf.valid, vf.payload[:, 0].astype(jnp.int32), 0)
            fnd, vls = kops.bulk_find(bstate.map.tkeys, bstate.map.tvals,
                                      bstate.map.status, rb,
                                      vf.payload[:, 1:], vf.valid)
            c.set_reply(h_find, jnp.concatenate(
                [vls, fnd.astype(jnp.uint32)[:, None]], axis=1))
            outs = c.finish(bk)
        back, _ = outs[h_find]
        assert log.total().collectives == 2      # spill + find, one plan
        assert int(dropped) == 0
        assert bool((back[:, -1] == 1).all())
        assert np.array_equal(np.asarray(back[:, 0]),
                              np.asarray(probe_keys) * 5)
        # the spilled items are in the ring, ready for the owner's flush
        assert int(q.size(bstate.queue)) == 64
