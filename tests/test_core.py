"""BCL core unit tests: pointers, hashing, object containers, promises,
cost accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import ShapeDtypeStruct as SDS

from repro.core import costs
from repro.core.hashing import double_hash, fmix32, hash_lanes
from repro.core.object_container import (IdentityPacker, StructPacker,
                                         packer_for)
from repro.core.pointers import GlobalPointer, from_global_index, global_index
from repro.core.promises import (ConProm, Promise, find_only,
                                 fully_atomic_hashmap, local_only)


class TestPointers:
    def test_arithmetic(self):
        p = GlobalPointer(jnp.int32(2), jnp.int32(10))
        q = p + 5
        assert int(q.offset) == 15 and int(q.rank) == 2
        r = q - 3
        assert int(r.offset) == 12

    def test_global_index_roundtrip(self):
        idx = jnp.arange(100, dtype=jnp.int32)
        ptr = from_global_index(idx, local_n=16)
        back = global_index(ptr, local_n=16)
        assert np.array_equal(np.asarray(back), np.asarray(idx))

    def test_null(self):
        p = GlobalPointer.null((4,))
        assert bool(p.is_null().all())

    def test_is_pytree(self):
        p = GlobalPointer(jnp.zeros(3, jnp.int32), jnp.ones(3, jnp.int32))
        leaves = jax.tree_util.tree_leaves(p)
        assert len(leaves) == 2


class TestHashing:
    def test_avalanche(self):
        x = jnp.arange(1 << 12, dtype=jnp.uint32)
        h = fmix32(x)
        # bit balance: every output bit set 40-60% of the time
        bits = ((np.asarray(h)[:, None] >> np.arange(32)[None]) & 1)
        frac = bits.mean(axis=0)
        assert (frac > 0.4).all() and (frac < 0.6).all()

    def test_lane_hash_distinct_seeds(self):
        lanes = jnp.arange(256, dtype=jnp.uint32)[:, None]
        h1 = hash_lanes(lanes, seed=1)
        h2 = hash_lanes(lanes, seed=2)
        assert not np.array_equal(np.asarray(h1), np.asarray(h2))

    def test_double_hash_range(self):
        lanes = jnp.arange(64, dtype=jnp.uint32)[:, None]
        hk = double_hash(lanes, k=4, modulo=64)
        assert hk.shape == (64, 4)
        assert int(hk.max()) < 64


class TestObjectContainers:
    def test_identity_f32_roundtrip(self):
        p = packer_for(SDS((), jnp.float32))
        assert isinstance(p, IdentityPacker) and p.lanes == 1
        x = jnp.linspace(-5, 5, 17)
        assert np.allclose(np.asarray(p.unpack(p.pack(x))), np.asarray(x))

    def test_identity_is_bitcast_only(self):
        """Copy elision: packing 32-bit data lowers to a bitcast, no math."""
        p = packer_for(SDS((), jnp.float32))
        jaxpr = jax.make_jaxpr(p.pack)(jnp.zeros(8))
        prims = {e.primitive.name for e in jaxpr.eqns}
        assert prims <= {"bitcast_convert_type", "reshape", "broadcast_in_dim"}

    def test_struct_roundtrip(self):
        p = packer_for({"hi": SDS((), jnp.uint32), "lo": SDS((), jnp.uint32),
                        "val": SDS((), jnp.float32),
                        "vec": SDS((3,), jnp.int32)})
        assert isinstance(p, StructPacker)
        rec = {"hi": jnp.arange(5, dtype=jnp.uint32),
               "lo": jnp.arange(5, dtype=jnp.uint32) * 3,
               "val": jnp.linspace(0, 1, 5),
               "vec": jnp.arange(15, dtype=jnp.int32).reshape(5, 3)}
        out = p.unpack(p.pack(rec))
        for k in rec:
            assert np.array_equal(np.asarray(out[k]), np.asarray(rec[k])), k

    def test_small_dtypes(self):
        p = packer_for({"b": SDS((), jnp.uint8), "h": SDS((), jnp.bfloat16)})
        rec = {"b": jnp.arange(4, dtype=jnp.uint8),
               "h": jnp.asarray([1.0, -2.0, 0.5, 3.25], jnp.bfloat16)}
        out = p.unpack(p.pack(rec))
        assert np.array_equal(np.asarray(out["b"]), np.asarray(rec["b"]))
        assert np.array_equal(np.asarray(out["h"], dtype=np.float32),
                              np.asarray(rec["h"], dtype=np.float32))

    def test_64bit_rejected(self):
        with pytest.raises(TypeError):
            packer_for({"x": SDS((), jnp.int64)})

    def test_lane_count_passthrough(self):
        p = packer_for(4)
        assert p.lanes == 4


class TestPromises:
    def test_paper_spelling(self):
        pr = ConProm.HashMap.find | ConProm.HashMap.insert
        assert fully_atomic_hashmap(pr)
        assert not find_only(pr)
        assert find_only(ConProm.HashMap.find)
        assert local_only(ConProm.HashMap.local)

    def test_queue_promises(self):
        pr = ConProm.CircularQueue.push_pop
        assert pr & Promise.PUSH and pr & Promise.POP


class TestCosts:
    def test_formula_rendering(self):
        c = costs.Cost(A=2, W=1)
        assert c.formula() == "2A + W"
        c = costs.Cost(A=1, R=5)
        assert c.formula() == "A + 5R"

    def test_recording_scopes(self):
        with costs.recording() as log:
            costs.record("op", costs.Cost(A=1))
            with costs.recording() as inner:
                costs.record("op", costs.Cost(R=2))
            costs.record("op", costs.Cost(W=3))
        assert inner.total().R == 2 and inner.total().A == 0
        assert log.total().A == 1 and log.total().W == 3
