"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st
from jax import ShapeDtypeStruct as SDS

from repro.core import ExchangePlan, Promise, get_backend, route
from repro.containers import bloom as bl
from repro.containers import hashmap as hm
from repro.containers import queue as q
from repro.kernels import ops, ref

_keys = st.lists(st.integers(0, 200), min_size=1, max_size=80)


@given(_keys)
@settings(max_examples=20, deadline=None)
def test_hashmap_insert_then_find_total(keys):
    """forall K: find(insert(table, K), K) succeeds with the last value."""
    bk = get_backend(None)
    spec, state = hm.hashmap_create(bk, 2048, SDS((), jnp.uint32),
                                    SDS((), jnp.uint32), block_size=16)
    ks = jnp.asarray(keys, jnp.uint32)
    vs = jnp.arange(len(keys), dtype=jnp.uint32) + 1
    state, ok = hm.insert(bk, spec, state, ks, vs, capacity=len(keys))
    assert bool(ok.all())
    state, v, found = hm.find(bk, spec, state, ks, capacity=len(keys))
    assert bool(found.all())
    oracle = {}
    for k_, v_ in zip(keys, range(1, len(keys) + 1)):
        oracle[k_] = v_
    for k_, got in zip(keys, np.asarray(v)):
        assert got == oracle[k_]


@given(_keys)
@settings(max_examples=20, deadline=None)
def test_bloom_no_false_negatives(keys):
    bk = get_backend(None)
    spec, state = bl.bloom_create(bk, 1 << 14, SDS((), jnp.uint32), k=4)
    ks = jnp.asarray(keys, jnp.uint32)
    state, _ = bl.insert(bk, spec, state, ks, capacity=len(keys))
    present = bl.find(bk, spec, state, ks, capacity=len(keys))
    assert bool(present.all())


@given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=60))
@settings(max_examples=20, deadline=None)
def test_queue_preserves_multiset(vals):
    bk = get_backend(None)
    spec, state = q.queue_create(bk, 128, SDS((), jnp.uint32))
    v = jnp.asarray(vals, jnp.uint32)
    state, pushed, dropped = q.push(bk, spec, state, v,
                                    jnp.zeros(len(vals), jnp.int32),
                                    capacity=len(vals))
    assert int(dropped) == 0
    state, out, got = q.local_nonatomic_pop(spec, state, len(vals))
    assert sorted(np.asarray(out)[np.asarray(got)].tolist()) == sorted(vals)


@given(st.lists(st.tuples(st.integers(0, 30), st.integers(1, 100)),
                min_size=1, max_size=60),
       st.sampled_from([ref.MODE_SET, ref.MODE_ADD, ref.MODE_KEEP]))
@settings(max_examples=20, deadline=None)
def test_bulk_insert_impls_agree(pairs, mode):
    """jnp and pallas implementations match the sequential oracle on
    arbitrary (dup-heavy) batches."""
    nb, B = 4, 8
    tk = jnp.zeros((nb, B, 1), jnp.uint32)
    tv = jnp.zeros((nb, B, 1), jnp.uint32)
    stt = jnp.zeros((nb, B), jnp.uint32)
    qk = jnp.asarray([[k] for k, _ in pairs], jnp.uint32)
    qv = jnp.asarray([[v] for _, v in pairs], jnp.uint32)
    qb = qk[:, 0] % nb
    valid = jnp.ones(len(pairs), bool)
    o = ref.hash_probe_insert_ref(tk, tv, stt, qb, qk, qv, valid, mode)
    for impl in ("jnp", "pallas"):
        j = ops.bulk_insert(tk, tv, stt, qb, qk, qv, valid, mode, impl=impl)
        for a, b_ in zip(o, j):
            assert np.array_equal(np.asarray(a), np.asarray(b_)), impl


@given(st.integers(1, 1 << 30), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_bloom_dup_atomicity(value, n_dups):
    """Exactly one inserter of n duplicates observes 'not present'."""
    bk = get_backend(None)
    spec, state = bl.bloom_create(bk, 1 << 12, SDS((), jnp.uint32), k=4)
    dup = jnp.full((n_dups,), value, jnp.uint32)
    state, already = bl.insert(bk, spec, state, dup, capacity=n_dups)
    assert int((~already).sum()) == 1


@given(st.lists(st.integers(0, 3), min_size=1, max_size=64),
       st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_route_multiset_preserved(dests, ncopies):
    """Property: with enough capacity, routing preserves the multiset."""
    bk = get_backend(None)
    n = len(dests)
    pay = jnp.arange(n, dtype=jnp.uint32) * ncopies
    res = route(bk, pay, jnp.zeros(n, jnp.int32), capacity=n)
    got = sorted(np.asarray(res.payload[res.valid][:, 0]).tolist())
    assert got == sorted(np.asarray(pay).tolist())


@given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=60),
       st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_route_retry_rounds_preserve_multiset(vals, rounds):
    """Property: whenever rounds x capacity covers the hottest bucket,
    carryover retries make routing lossless at any per-round capacity."""
    bk = get_backend(None)
    n = len(vals)
    cap = max(1, -(-n // rounds))
    pay = jnp.asarray(vals, jnp.uint32)
    res = route(bk, pay, jnp.zeros(n, jnp.int32), capacity=cap,
                max_rounds=rounds)
    got = sorted(np.asarray(res.payload[res.valid][:, 0]).tolist())
    assert got == sorted(vals)
    assert int(res.dropped) == 0


def _tree_equal(a, b):
    if isinstance(a, (tuple, list)):
        return len(a) == len(b) and all(_tree_equal(x, y)
                                        for x, y in zip(a, b))
    return np.array_equal(np.asarray(a), np.asarray(b))


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_fused_plan_interleavings_match_fine_schedule(data):
    """Any interleaving of fused-plan ops is bit-identical to the
    Promise.FINE sequential schedule — outputs AND container state —
    over random keys, values, capacities, AND carryover retry rounds
    (including the overflow regime: the same per-flow binning drops the
    same items on both schedules, and each retry round ships the same
    rank window).  The 8-rank version of this check, with random dests,
    runs in tests/spmd_check.py."""
    ops_seq = []
    for _ in range(data.draw(st.integers(1, 4), label="n_ops")):
        kind = data.draw(st.sampled_from(
            ["find_insert", "push_pop", "bloom_insert_find"]), label="kind")
        n = data.draw(st.integers(1, 24), label="n")
        cap = data.draw(st.integers(max(1, n // 2), n + 8), label="cap")
        rounds = data.draw(st.integers(1, 3), label="rounds")
        a = data.draw(st.lists(st.integers(0, 300), min_size=n, max_size=n),
                      label="a")
        b = data.draw(st.lists(st.integers(0, 300), min_size=n, max_size=n),
                      label="b")
        ops_seq.append((kind, cap, rounds, a, b))

    def run(fine):
        bk = get_backend(None)
        extra = Promise.FINE if fine else Promise.NONE
        spec, hst = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                      SDS((), jnp.uint32), block_size=8)
        qspec, qst = q.queue_create(bk, 64, SDS((), jnp.uint32),
                                    circular=True)
        bspec, bst = bl.bloom_create(bk, 1 << 10, SDS((), jnp.uint32), k=4)
        outs = []
        for kind, cap, rounds, a, b in ops_seq:
            av = jnp.asarray(a, jnp.uint32)
            bv = jnp.asarray(b, jnp.uint32)
            if kind == "find_insert":
                hst, v, f, ok = hm.find_insert(
                    bk, spec, hst, av, bv, bv * 7 + 1, capacity=cap,
                    promise=Promise.FIND | Promise.INSERT | extra,
                    max_rounds=rounds)
                outs.append((v, f, ok))
            elif kind == "push_pop":
                qst, pushed, dropped, out, got = q.push_pop(
                    bk, qspec, qst, av, jnp.zeros(len(a), jnp.int32),
                    cap, len(b), 0,
                    promise=Promise.PUSH | Promise.POP | extra,
                    max_rounds=rounds)
                outs.append((pushed, dropped, out, got))
            else:
                bst, already, present = bl.insert_find(
                    bk, bspec, bst, av, bv, cap, cap, promise=extra,
                    max_rounds=rounds)
                outs.append((already, present))
        return outs, (tuple(hst), tuple(qst), tuple(bst))

    fused_out, fused_state = run(False)
    fine_out, fine_state = run(True)
    assert _tree_equal(fused_out, fine_out)
    assert _tree_equal(fused_state, fine_state)


@given(st.data())
@settings(max_examples=15, deadline=None)
def test_ragged_fused_plans_equal_fine_over_flow_mixes(data):
    """Ragged fused wire == Promise.FINE oracle over random flow mixes:
    1-4 flows of lane widths 1..4 and reply widths 0..3, random
    capacities and carryover retry rounds 1..3 — owner views, replies,
    answered masks, and per-flow drop counts are all bit-identical, so
    the ragged layout is pure wire compression, never a semantic
    change."""
    bk = get_backend(None)
    nflows = data.draw(st.integers(1, 4), label="nflows")
    rounds = data.draw(st.integers(1, 3), label="rounds")
    flows = []
    for i in range(nflows):
        n = data.draw(st.integers(1, 20), label=f"n{i}")
        lanes = data.draw(st.integers(1, 4), label=f"lanes{i}")
        cap = data.draw(st.integers(1, n + 4), label=f"cap{i}")
        rl = data.draw(st.integers(0, 3), label=f"rl{i}")
        pay = jnp.asarray(
            data.draw(st.lists(st.integers(0, 1 << 30),
                               min_size=n * lanes, max_size=n * lanes),
                      label=f"pay{i}"), jnp.uint32).reshape(n, lanes)
        valid = jnp.asarray(
            data.draw(st.lists(st.booleans(), min_size=n, max_size=n),
                      label=f"valid{i}"))
        flows.append((pay, valid, cap, rl))

    def run(promise):
        plan = ExchangePlan(promise=promise, name="mix")
        hs = [plan.add(p, jnp.zeros(p.shape[0], jnp.int32), cap,
                       reply_lanes=rl, valid=v, op_name=f"f{i}")
              for i, (p, v, cap, rl) in enumerate(flows)]
        c = plan.commit(bk, max_rounds=rounds)
        for h, (p, v, cap, rl) in zip(hs, flows):
            if rl:
                c.set_reply(h, jnp.tile(
                    c.view(h).payload[:, :1] * 3 + h + 1, (1, rl)))
        fin = c.finish(bk)
        return ([tuple(c.view(h)) for h in hs],
                sorted(fin.items()))

    fused = run(Promise.NONE)
    fine = run(Promise.FINE)
    assert _tree_equal(fused[0], fine[0])
    for (hf, (of, af)), (hs_, (os_, as_)) in zip(fused[1], fine[1]):
        assert hf == hs_
        assert np.array_equal(np.asarray(of), np.asarray(os_))
        assert np.array_equal(np.asarray(af), np.asarray(as_))


@given(st.data())
@settings(max_examples=15, deadline=None)
def test_hierarchical_transport_equals_dense_over_flow_mixes(data):
    """HierarchicalTransport == DenseTransport over random flow mixes:
    1-4 flows of lane widths 1..4, reply widths 0..3, random validity,
    capacities, and carryover retry rounds 1..3 — owner views, replies,
    answered masks, and drop counts are bit-identical, so the two-stage
    movement is pure physical re-routing, never a semantic change.  (The
    8-rank 2-D mesh version, with random destinations, runs in
    tests/spmd_check.py as ``exchange.hier_equals_dense_8rank``.)"""
    from repro.core import HierarchicalTransport
    bk = get_backend(None)
    nflows = data.draw(st.integers(1, 4), label="nflows")
    rounds = data.draw(st.integers(1, 3), label="rounds")
    flows = []
    for i in range(nflows):
        n = data.draw(st.integers(1, 20), label=f"n{i}")
        lanes = data.draw(st.integers(1, 4), label=f"lanes{i}")
        cap = data.draw(st.integers(1, n + 4), label=f"cap{i}")
        rl = data.draw(st.integers(0, 3), label=f"rl{i}")
        pay = jnp.asarray(
            data.draw(st.lists(st.integers(0, 1 << 19),
                               min_size=n * lanes, max_size=n * lanes),
                      label=f"pay{i}"), jnp.uint32).reshape(n, lanes)
        valid = jnp.asarray(
            data.draw(st.lists(st.booleans(), min_size=n, max_size=n),
                      label=f"valid{i}"))
        flows.append((pay, valid, cap, rl))

    def run(transport):
        plan = ExchangePlan(name="mix")
        hs = [plan.add(p, jnp.zeros(p.shape[0], jnp.int32), cap,
                       reply_lanes=rl, valid=v, op_name=f"f{i}")
              for i, (p, v, cap, rl) in enumerate(flows)]
        c = plan.commit(bk, max_rounds=rounds, transport=transport)
        for h, (p, v, cap, rl) in zip(hs, flows):
            if rl:
                c.set_reply(h, jnp.tile(
                    c.view(h).payload[:, :1] * 3 + h + 1, (1, rl)))
        fin = c.finish(bk)
        return ([tuple(c.view(h)) for h in hs], sorted(fin.items()))

    dense = run(None)
    hier = run(HierarchicalTransport())
    assert _tree_equal(dense[0], hier[0])
    for (hd, (od, ad)), (hh, (oh, ah)) in zip(dense[1], hier[1]):
        assert hd == hh
        assert np.array_equal(np.asarray(od), np.asarray(oh))
        assert np.array_equal(np.asarray(ad), np.asarray(ah))


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_commit_async_equals_sync_over_flow_mixes(data):
    """Split-phase commit_async -> finish == synchronous commit == the
    Promise.FINE sequential oracle over random flow mixes (1-4 flows,
    lane widths 1..4, reply widths 0..3, carryover retry rounds 1..3)
    on BOTH physical transports — owner views, replies, answered masks,
    and per-flow drop counts are all bit-identical, so deferring the
    wait is pure scheduling, never a semantic change (DESIGN.md
    section 1.9).  FINE + async stays the sequential oracle (run
    eagerly, wrapped in a degenerate pending)."""
    bk = get_backend(None)
    nflows = data.draw(st.integers(1, 4), label="nflows")
    rounds = data.draw(st.integers(1, 3), label="rounds")
    transport = data.draw(st.sampled_from(["dense", "hier"]),
                          label="transport")
    flows = []
    for i in range(nflows):
        n = data.draw(st.integers(1, 16), label=f"n{i}")
        lanes = data.draw(st.integers(1, 4), label=f"lanes{i}")
        cap = data.draw(st.integers(1, n + 4), label=f"cap{i}")
        rl = data.draw(st.integers(0, 3), label=f"rl{i}")
        pay = jnp.asarray(
            data.draw(st.lists(st.integers(0, 1 << 19),
                               min_size=n * lanes, max_size=n * lanes),
                      label=f"pay{i}"), jnp.uint32).reshape(n, lanes)
        valid = jnp.asarray(
            data.draw(st.lists(st.booleans(), min_size=n, max_size=n),
                      label=f"valid{i}"))
        flows.append((pay, valid, cap, rl))

    def run(promise, async_):
        plan = ExchangePlan(promise=promise, name="mix")
        hs = [plan.add(p, jnp.zeros(p.shape[0], jnp.int32), cap,
                       reply_lanes=rl, valid=v, op_name=f"f{i}")
              for i, (p, v, cap, rl) in enumerate(flows)]
        if async_:
            c = plan.commit_async(bk, max_rounds=rounds,
                                  transport=transport).finish(bk)
        else:
            c = plan.commit(bk, max_rounds=rounds, transport=transport)
        for h, (p, v, cap, rl) in zip(hs, flows):
            if rl:
                c.set_reply(h, jnp.tile(
                    c.view(h).payload[:, :1] * 3 + h + 1, (1, rl)))
        fin = c.finish(bk)
        return ([tuple(c.view(h)) for h in hs], sorted(fin.items()))

    sync = run(Promise.NONE, False)
    asyn = run(Promise.NONE, True)
    fine = run(Promise.FINE, True)
    for other in (sync, fine):
        assert _tree_equal(asyn[0], other[0])
        for (ha, (oa, aa)), (ho, (oo, ao)) in zip(asyn[1], other[1]):
            assert ha == ho
            assert np.array_equal(np.asarray(oa), np.asarray(oo))
            assert np.array_equal(np.asarray(aa), np.asarray(ao))


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=2,
                max_size=64))
@settings(max_examples=20, deadline=None)
def test_int8_error_feedback_invariant(vals):
    """dequantized + residual == original (EF preserves information)."""
    from repro.optim.compress import int8_compress, int8_decompress
    g = jnp.asarray(vals, jnp.float32)
    q, scale, res = int8_compress(g)
    recon = int8_decompress(q, scale).reshape(g.shape) + res
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g),
                               rtol=1e-5, atol=1e-5)
