"""Paper Tables 2/3/4: exact best-case cost formulas per operation."""

import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.core import ConProm, costs, get_backend
from repro.containers import bloom as bl
from repro.containers import hashmap as hm
from repro.containers import queue as q


def _one_op(fn):
    with costs.recording() as log:
        fn()
    return log


def test_hashmap_insert_fully_atomic_2A_W():
    bk = get_backend(None)
    spec, st = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=16)
    log = _one_op(lambda: hm.insert(
        bk, spec, st, jnp.arange(4, dtype=jnp.uint32),
        jnp.arange(4, dtype=jnp.uint32), capacity=8,
        promise=ConProm.HashMap.find_insert))
    c = log.by_op("hashmap.insert")
    assert c.A == 2 and c.W == 4          # Table 3a: 2A + W per element


def test_hashmap_insert_local_is_ell():
    bk = get_backend(None)
    spec, st = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=16)
    log = _one_op(lambda: hm.insert(
        bk, spec, st, jnp.arange(4, dtype=jnp.uint32),
        jnp.arange(4, dtype=jnp.uint32), capacity=8,
        promise=ConProm.HashMap.local))
    c = log.by_op("hashmap.insert")
    assert c.A == 0 and c.W == 0 and c.local == 4   # Table 3b: l


def test_hashmap_find_atomic_vs_relaxed():
    bk = get_backend(None)
    spec, st = hm.hashmap_create(bk, 512, SDS((), jnp.uint32),
                                 SDS((), jnp.uint32), block_size=16)
    keys = jnp.arange(4, dtype=jnp.uint32)
    st, _ = hm.insert(bk, spec, st, keys, keys, capacity=8)
    atomic = _one_op(lambda: hm.find(
        bk, spec, st, keys, capacity=8,
        promise=ConProm.HashMap.find_insert)).by_op("hashmap.find")
    relaxed = _one_op(lambda: hm.find(
        bk, spec, st, keys, capacity=8,
        promise=ConProm.HashMap.find)).by_op("hashmap.find")
    assert atomic.A == 2 and atomic.R == 4      # Table 3c: 2A + R
    assert relaxed.A == 0 and relaxed.R == 4    # Table 3d: R


def test_queue_costs_table2():
    bk = get_backend(None)
    vals = jnp.arange(6, dtype=jnp.uint32)
    dest = jnp.zeros(6, jnp.int32)

    fspec, fst = q.queue_create(bk, 64, SDS((), jnp.uint32))
    cspec, cst = q.queue_create(bk, 64, SDS((), jnp.uint32), circular=True)

    fpush = _one_op(lambda: q.push(bk, fspec, fst, vals, dest,
                                   capacity=8)).by_op("queue.push")
    cpush = _one_op(lambda: q.push(bk, cspec, cst, vals, dest,
                                   capacity=8)).by_op("queue.push")
    assert fpush.A == 1 and fpush.W == 6        # FastQueue: A + nW
    assert cpush.A == 2 and cpush.W == 6        # CircularQueue: 2A + nW

    fst, _, _ = q.push(bk, fspec, fst, vals, dest, capacity=8)
    fpop = _one_op(lambda: q.pop(bk, fspec, fst, 3, 0)).by_op("queue.pop")
    assert fpop.A == 1 and fpop.R == 3          # FastQueue: A + nR

    lpop = _one_op(lambda: q.local_nonatomic_pop(fspec, fst, 3)
                   ).by_op("queue.local_nonatomic_pop")
    assert lpop.A == 0 and lpop.local == 3      # l

    res = _one_op(lambda: q.resize(bk, fspec, fst, 128)).by_op("queue.resize")
    assert res.B == 1                            # B + l


def test_bloom_costs_table2():
    bk = get_backend(None)
    spec, st = bl.bloom_create(bk, 1 << 12, SDS((), jnp.uint32), k=4)
    items = jnp.arange(5, dtype=jnp.uint32)
    ins = _one_op(lambda: bl.insert(bk, spec, st, items,
                                    capacity=8)).by_op("bloom.insert")
    fnd = _one_op(lambda: bl.find(bk, spec, st, items,
                                  capacity=8)).by_op("bloom.find")
    assert ins.A == 1                            # Table 2: A (single AMO!)
    assert fnd.A == 0 and fnd.R == 5             # Table 2: R
