"""Roofline machinery unit tests: HLO collective parser + jaxpr stats."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import jaxpr_stats
from repro.launch.roofline import (compute_roofline, parse_collectives,
                                   _shape_bytes)


SAMPLE_HLO = """
HloModule test
%x = f32[16,128]{1,0} parameter(0)
%ar = f32[16,128]{1,0} all-reduce(%x), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%sum
%ag = f32[64,128]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
%a2a = (f32[4,128]{1,0}, f32[4,128]{1,0}) all-to-all(%s0, %s1), replica_groups={{0,1}}
%cp = bf16[8,8]{1,0} collective-permute(%y), source_target_pairs={{0,1},{1,0}}
%rs = f32[4,128]{1,0} reduce-scatter(%x), replica_groups={{0,1,2,3}}, dimensions={0}
"""


class TestHLOParse:
    def test_shape_bytes(self):
        assert _shape_bytes("f32[16,128]{1,0}") == 16 * 128 * 4
        assert _shape_bytes("(f32[4,128]{1,0}, f32[4,128]{1,0})") == \
            2 * 4 * 128 * 4
        assert _shape_bytes("bf16[8,8]") == 128

    def test_parse_counts(self):
        st = parse_collectives(SAMPLE_HLO, n_devices=8)
        assert st.counts == {"all-reduce": 1, "all-gather": 1,
                             "all-to-all": 1, "collective-permute": 1,
                             "reduce-scatter": 1}

    def test_wire_model(self):
        st = parse_collectives(SAMPLE_HLO, n_devices=8)
        ar = 16 * 128 * 4
        assert abs(st.wire_bytes["all-reduce"] - 2 * ar * 3 / 4) < 1
        ag = 64 * 128 * 4
        assert abs(st.wire_bytes["all-gather"] - ag * 3 / 4) < 1
        cp = 128
        assert st.wire_bytes["collective-permute"] == cp

    def test_dominant(self):
        st = parse_collectives(SAMPLE_HLO, n_devices=8)
        assert st.dominant() == "all-gather"


class TestJaxprStats:
    def test_dot_flops_exact(self):
        f = lambda a, b: a @ b
        st = jaxpr_stats.analyze(f, jnp.zeros((64, 32)), jnp.zeros((32, 16)))
        assert st.flops >= 2 * 64 * 32 * 16
        assert st.flops < 2 * 64 * 32 * 16 * 1.1

    def test_scan_multiplication(self):
        w = jnp.zeros((32, 32))

        def f(x):
            return jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                                length=10)[0]

        st = jaxpr_stats.analyze(f, jnp.zeros((32, 32)))
        st1 = jaxpr_stats.analyze(f, jnp.zeros((32, 32)),
                                  count_trips=False)
        one = 2 * 32 ** 3
        assert st.flops >= 10 * one and st.flops < 10.5 * one
        assert st1.flops < 1.5 * one

    def test_nested_scan(self):
        w = jnp.zeros((16, 16))

        def inner(x):
            return jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                                length=3)[0]

        def f(x):
            return jax.lax.scan(lambda c, _: (inner(c), None), x, None,
                                length=5)[0]

        st = jaxpr_stats.analyze(f, jnp.zeros((16, 16)))
        assert st.flops >= 15 * 2 * 16 ** 3

    def test_remat_counted(self):
        w = jnp.zeros((32, 32))
        f = jax.grad(lambda x: jax.checkpoint(
            lambda y: jnp.sum(jnp.sin(y @ w) @ w))(x))
        st = jaxpr_stats.analyze(f, jnp.zeros((32, 32)))
        # remat-fwd 2 + bwd 2-3 matmuls (primal value is DCE'd by grad)
        assert st.flops >= 4.5 * 2 * 32 ** 3
        no_remat = jaxpr_stats.analyze(
            jax.grad(lambda x: jnp.sum(jnp.sin(x @ w) @ w)),
            jnp.zeros((32, 32)))
        assert st.flops > no_remat.flops   # recompute is visible

    def test_grad_doubles(self):
        w = jnp.zeros((64, 64))
        fwd = jaxpr_stats.analyze(lambda x: jnp.sum(x @ w),
                                  jnp.zeros((64, 64)))
        bwd = jaxpr_stats.analyze(
            jax.grad(lambda x: jnp.sum(x @ w)), jnp.zeros((64, 64)))
        assert bwd.flops >= 1.9 * fwd.flops


class TestRooflineTerms:
    def test_dominant_selection(self):
        r = compute_roofline(flops=1e15, hbm_bytes=1e9, wire_bytes=1e6,
                             n_chips=256, model_flops=2e17)
        assert r.dominant == "compute"
        r = compute_roofline(flops=1e9, hbm_bytes=1e13, wire_bytes=1e6,
                             n_chips=256, model_flops=1e12)
        assert r.dominant == "memory"

    def test_useful_ratio(self):
        r = compute_roofline(flops=4e12, hbm_bytes=1, wire_bytes=1,
                             n_chips=1, model_flops=3e12)
        assert abs(r.useful_ratio - 0.75) < 1e-6
