"""Per-kernel validation: Pallas (interpret) and vectorized-jnp vs the
sequential oracles, swept over shapes/dtypes/modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hashing import double_hash
from repro.kernels import binning, bloom_kernel, hash_probe, ops, ref
from repro.kernels import flash_attention as fa


def _mk_table(nb, B, lk, lv):
    return (jnp.zeros((nb, B, lk), jnp.uint32),
            jnp.zeros((nb, B, lv), jnp.uint32),
            jnp.zeros((nb, B), jnp.uint32))


def _mk_queries(rng, m, nb, lk, lv, key_space):
    qk = jnp.asarray(rng.integers(0, key_space, (m, lk)), jnp.uint32)
    mix = np.asarray(qk[:, 0])
    for i in range(1, lk):
        mix = mix * 31 + np.asarray(qk[:, i])
    qb = jnp.asarray(mix % nb, jnp.int32)
    qv = jnp.asarray(rng.integers(1, 1 << 20, (m, lv)), jnp.uint32)
    qvalid = jnp.asarray(rng.random(m) < 0.9)
    return qb, qk, qv, qvalid


SWEEP = [
    # nb, B, lk, lv, m
    (8, 16, 1, 1, 100),
    (16, 32, 2, 2, 400),
    (4, 8, 3, 1, 64),
    (32, 16, 2, 1, 900),
]


@pytest.mark.parametrize("nb,B,lk,lv,m", SWEEP)
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
@pytest.mark.parametrize("mode", [ref.MODE_SET, ref.MODE_ADD, ref.MODE_KEEP])
def test_insert_matches_oracle(rng, nb, B, lk, lv, m, impl, mode):
    tk, tv, st = _mk_table(nb, B, lk, lv)
    qb, qk, qv, qvalid = _mk_queries(rng, m, nb, lk, lv, key_space=m // 2)
    o = ref.hash_probe_insert_ref(tk, tv, st, qb, qk, qv, qvalid, mode)
    j = ops.bulk_insert(tk, tv, st, qb, qk, qv, qvalid, mode, impl=impl)
    for a, b_, name in zip(o, j, ["tkeys", "tvals", "status", "success"]):
        assert np.array_equal(np.asarray(a), np.asarray(b_)), \
            f"{name} mismatch ({impl}, mode={mode})"


@pytest.mark.parametrize("nb,B,lk,lv,m", SWEEP[:2])
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_find_matches_oracle(rng, nb, B, lk, lv, m, impl):
    tk, tv, st = _mk_table(nb, B, lk, lv)
    qb, qk, qv, qvalid = _mk_queries(rng, m, nb, lk, lv, key_space=m // 2)
    tk, tv, st, _ = ref.hash_probe_insert_ref(tk, tv, st, qb, qk, qv,
                                              qvalid, ref.MODE_SET)
    fb, fk, _, fvalid = _mk_queries(rng, m, nb, lk, lv, key_space=m)
    fo, vo = ref.hash_probe_find_ref(tk, tv, st, fb, fk, fvalid)
    fj, vj = ops.bulk_find(tk, tv, st, fb, fk, fvalid, impl=impl)
    assert np.array_equal(np.asarray(fo), np.asarray(fj))
    assert np.array_equal(np.asarray(vo), np.asarray(vj))


@pytest.mark.parametrize("nb,B,lk,lv,m", SWEEP[:2])
def test_arrivals_match_column_ops(rng, nb, B, lk, lv, m):
    """Owner-side arrival entry points (DESIGN.md section 1.10): probing
    straight off the contiguous (block | key | val) exchange segment is
    bit-identical to slicing the columns and running bulk_find /
    bulk_insert, across both impls."""
    tk, tv, st = _mk_table(nb, B, lk, lv)
    qb, qk, qv, qvalid = _mk_queries(rng, m, nb, lk, lv, key_space=m // 2)
    seg_i = jnp.concatenate([qb.astype(jnp.uint32)[:, None], qk, qv], axis=1)
    col = ops.bulk_insert(tk, tv, st, qb, qk, qv, qvalid, impl="jnp")
    for impl in ("jnp", "pallas"):
        got = ops.bulk_insert_arrivals(tk, tv, st, seg_i, qvalid, impl=impl)
        for a, b_, name in zip(col, got, ["tkeys", "tvals", "status", "ok"]):
            assert np.array_equal(np.asarray(a), np.asarray(b_)), \
                f"insert {name} ({impl})"
    tk, tv, st, _ = col[0], col[1], col[2], col[3]
    fb, fk, _, fvalid = _mk_queries(rng, m, nb, lk, lv, key_space=m)
    seg_f = jnp.concatenate([fb.astype(jnp.uint32)[:, None], fk], axis=1)
    fo, vo = ops.bulk_find(tk, tv, st, fb, fk, fvalid, impl="jnp")
    for impl in ("jnp", "pallas"):
        fg, vg = ops.bulk_find_arrivals(tk, tv, st, seg_f, fvalid, impl=impl)
        assert np.array_equal(np.asarray(fo), np.asarray(fg)), impl
        assert np.array_equal(np.asarray(vo), np.asarray(vg)), impl


def test_insert_stateful_sequence(rng):
    """Kernel equals oracle across a chain of dependent batches."""
    nb, B, lk, lv = 8, 16, 2, 1
    tko, tvo, sto = _mk_table(nb, B, lk, lv)
    tkp, tvp, stp = _mk_table(nb, B, lk, lv)
    for i in range(4):
        qb, qk, qv, qvalid = _mk_queries(rng, 120, nb, lk, lv, 60)
        tko, tvo, sto, oko = ref.hash_probe_insert_ref(
            tko, tvo, sto, qb, qk, qv, qvalid, ref.MODE_ADD)
        tkp, tvp, stp, okp = hash_probe.insert(
            tkp, tvp, stp, qb, qk, qv, qvalid, ref.MODE_ADD)
        assert np.array_equal(np.asarray(oko), np.asarray(okp)), f"batch {i}"
    assert np.array_equal(np.asarray(tvo), np.asarray(tvp))


class TestBloomKernel:
    @pytest.mark.parametrize("m,k,lanes", [(64, 4, 1), (333, 6, 2),
                                           (1000, 3, 2)])
    def test_hash_words(self, rng, m, k, lanes):
        items = jnp.asarray(rng.integers(0, 1 << 31, (m, lanes)), jnp.uint32)
        w_ref = ref.bloom_words_ref(double_hash(items, k, 64), k)
        w_ker = bloom_kernel.hash_words(items, k, tile=128)
        assert np.array_equal(np.asarray(w_ref), np.asarray(w_ker))

    def test_membership(self, rng):
        m = 500
        prior = jnp.asarray(rng.integers(0, 1 << 31, (m, 2)), jnp.uint32)
        words = jnp.asarray(rng.integers(0, 1 << 31, (m, 2)), jnp.uint32)
        valid = jnp.asarray(rng.random(m) < 0.8)
        expect = ((prior & words) == words).all(axis=1) & valid
        got = bloom_kernel.membership(prior, words, valid, tile=128)
        assert np.array_equal(np.asarray(expect), np.asarray(got))

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_bloom_insert_impls(self, rng, impl):
        nw, m = 32, 400
        fw = jnp.zeros((nw, 2), jnp.uint32)
        items = jnp.asarray(rng.integers(0, 50, (m, 2)), jnp.uint32)
        hb = jnp.asarray((np.asarray(items[:, 0]) * 3 +
                          np.asarray(items[:, 1])) % nw, jnp.int32)
        words = ref.bloom_words_ref(double_hash(items, 4, 64), 4)
        valid = jnp.asarray(rng.random(m) < 0.9)
        fo, po = ref.bloom_insert_ref(fw, hb, words, valid)
        fj, pj = ops.bloom_insert(fw, hb, words, valid, impl=impl)
        assert np.array_equal(np.asarray(fo), np.asarray(fj))
        assert np.array_equal(np.asarray(po), np.asarray(pj))


class TestBinning:
    @pytest.mark.parametrize("n,nbins,tile", [(100, 7, 32), (5000, 13, 512),
                                              (2048, 256, 256)])
    def test_histogram(self, rng, n, nbins, tile):
        bins = jnp.asarray(rng.integers(0, nbins, n), jnp.int32)
        valid = jnp.asarray(rng.random(n) < 0.7)
        h_ref = ref.bin_histogram_ref(bins, nbins, valid)
        h_ker = binning.histogram(bins, nbins, valid, tile=tile)
        assert np.array_equal(np.asarray(h_ref), np.asarray(h_ker))

    @pytest.mark.parametrize("n,nbins,nflows", [(100, 3, 2), (3000, 8, 4)])
    def test_ragged_slots_pallas_matches_jnp(self, rng, n, nbins, nflows):
        """The ragged-wire slot kernel against its jnp oracle, over
        every retry round of an uneven flow mix (the exchange engine
        dispatches whichever the backend picks — they must agree)."""
        bins = jnp.asarray(rng.integers(0, nbins, n), jnp.int32)
        flow = jnp.asarray(rng.integers(0, nflows, n), jnp.int32)
        valid = jnp.asarray(rng.random(n) < 0.8)
        _, offs = ops.multi_bin_offsets(bins, flow, nbins, nflows, valid)
        roww = jnp.asarray(rng.integers(2, 6, nflows), jnp.int32)
        caps = jnp.asarray(rng.integers(1, 9, nflows), jnp.int32)
        rounds = jnp.asarray(rng.integers(1, 4, nflows), jnp.int32)
        woff, wtot = [], 0
        for f in range(nflows):
            woff.append(wtot)
            wtot += int(caps[f]) * int(roww[f])
        woff = jnp.asarray(woff, jnp.int32)
        for r in range(int(rounds.max())):
            args = (bins, flow, offs, valid, r, woff, roww, caps, rounds,
                    wtot, nbins * wtot)
            sj = ops.ragged_slots(*args, impl="jnp")
            sp = ops.ragged_slots(*args, impl="pallas")
            assert np.array_equal(np.asarray(sj), np.asarray(sp)), r
            # in-round slots are unique (disjoint word ranges per item)
            live = np.asarray(sj) < nbins * wtot
            assert np.unique(np.asarray(sj)[live]).size == live.sum()

    @pytest.mark.parametrize("n,nbins,nflows", [(100, 3, 2), (3000, 8, 4)])
    def test_pack_rows_pallas_matches_jnp(self, rng, n, nbins, nflows):
        """The one-kernel wire pack against its two-pass jnp oracle
        (ragged_slots + scatter_rows), over every retry round: identical
        buffers, and every live item's words land at distinct addresses
        (sentinel rows — out of round, invalid, overflow — drop)."""
        bins = jnp.asarray(rng.integers(0, nbins, n), jnp.int32)
        flow = jnp.asarray(rng.integers(0, nflows, n), jnp.int32)
        valid = jnp.asarray(rng.random(n) < 0.8)
        _, offs = ops.multi_bin_offsets(bins, flow, nbins, nflows, valid)
        roww = jnp.asarray(rng.integers(2, 6, nflows), jnp.int32)
        caps = jnp.asarray(rng.integers(1, 9, nflows), jnp.int32)
        rounds = jnp.asarray(rng.integers(1, 4, nflows), jnp.int32)
        woff, wtot = [], 0
        for f in range(nflows):
            woff.append(wtot)
            wtot += int(caps[f]) * int(roww[f])
        woff = jnp.asarray(woff, jnp.int32)
        wmax = int(roww.max())
        # distinct nonzero payload per (item, lane) so uniqueness of the
        # packed words proves no two rows overlapped in the buffer
        rows = (jnp.arange(n, dtype=jnp.uint32)[:, None] * wmax
                + jnp.arange(wmax, dtype=jnp.uint32)[None, :] + 1)
        total = nbins * wtot
        for r in range(int(rounds.max())):
            args = (rows, bins, flow, offs, valid, r, woff, roww, caps,
                    rounds, wtot, total)
            bj = ops.pack_rows(*args, impl="jnp")
            bp = ops.pack_rows(*args, impl="pallas")
            assert np.array_equal(np.asarray(bj), np.asarray(bp)), r
            slots = np.asarray(ops.ragged_slots(
                bins, flow, offs, valid, r, woff, roww, caps, rounds,
                wtot, total, impl="jnp"))
            live = slots < total
            written = np.asarray(bj)[np.asarray(bj) != 0]
            expect_words = int((np.asarray(roww)[np.asarray(flow)])[live].sum())
            assert written.size == expect_words, r
            assert np.unique(written).size == written.size, r
            # rows past the round window / invalid contribute nothing
            drop_vals = np.asarray(rows)[~live].ravel()
            assert not np.intersect1d(written, drop_vals).size, r

    def test_place_rows_pallas_matches_jnp(self, rng):
        """Analytic-slot row placement (dense replies, owner assembly):
        kernel == scatter_rows oracle, OOB sentinel slots drop."""
        n, w, total = 200, 3, 900
        base = jnp.asarray(rng.permutation(total // w)[:n] * w, jnp.int32)
        base = jnp.where(jnp.asarray(rng.random(n) < 0.15), total, base)
        rows = jnp.asarray(rng.integers(1, 1 << 30, (n, w)), jnp.uint32)
        dst = jnp.asarray(rng.integers(0, 1 << 30, total), jnp.uint32)
        oj = ops.place_rows(dst, base, rows, impl="jnp")
        op_ = ops.place_rows(dst, base, rows, impl="pallas")
        assert np.array_equal(np.asarray(oj), np.asarray(op_))
        kept = np.asarray(base) >= total
        assert np.array_equal(np.asarray(oj)[np.asarray(base)[~kept]],
                              np.asarray(rows)[~kept, 0])


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,hq,hkv,tq,tk,d,causal,window",
        [(2, 4, 2, 64, 64, 32, True, 0),
         (1, 8, 1, 128, 128, 64, True, 0),     # MQA
         (2, 4, 4, 64, 128, 32, True, 0),      # suffix-aligned
         (1, 2, 2, 96, 96, 32, True, 32),      # sliding window
         (1, 4, 2, 1, 256, 64, True, 0),       # decode-like
         (2, 2, 2, 64, 64, 16, False, 0)])     # bidirectional
    def test_vs_oracle(self, rng, b, hq, hkv, tq, tk, d, causal, window):
        q = jnp.asarray(rng.standard_normal((b, hq, tq, d)),
                        jnp.float32) * 0.3
        k = jnp.asarray(rng.standard_normal((b, hkv, tk, d)),
                        jnp.float32) * 0.3
        v = jnp.asarray(rng.standard_normal((b, hkv, tk, d)), jnp.float32)
        o_ref = ref.flash_attention_ref(q, k, v, causal=causal,
                                        window=window)
        o_ker = fa.flash_attention(q, k, v, causal=causal, window=window,
                                   block_q=32, block_k=32)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_ker),
                                   atol=3e-5, rtol=3e-5)

    def test_bf16(self, rng):
        q = jnp.asarray(rng.standard_normal((1, 2, 64, 32)),
                        jnp.bfloat16) * 0.3
        k = jnp.asarray(rng.standard_normal((1, 2, 64, 32)),
                        jnp.bfloat16) * 0.3
        v = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.bfloat16)
        o_ref = ref.flash_attention_ref(q, k, v)
        o_ker = fa.flash_attention(q, k, v, block_q=32, block_k=32)
        np.testing.assert_allclose(
            np.asarray(o_ref, dtype=np.float32),
            np.asarray(o_ker, dtype=np.float32), atol=2e-2, rtol=2e-2)

    def test_blockwise_xla_path_matches(self, rng):
        """models/attention.blockwise == oracle (the dry-run path)."""
        from repro.models.attention import blockwise_attention
        q = jnp.asarray(rng.standard_normal((2, 4, 80, 32)),
                        jnp.float32) * 0.3
        k = jnp.asarray(rng.standard_normal((2, 2, 80, 32)),
                        jnp.float32) * 0.3
        v = jnp.asarray(rng.standard_normal((2, 2, 80, 32)), jnp.float32)
        o_ref = ref.flash_attention_ref(q, k, v, causal=True, window=24)
        o_blk = blockwise_attention(q, k, v, causal=True, window=24,
                                    q_block=32, k_block=16)
        np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o_blk),
                                   atol=3e-5, rtol=3e-5)
