"""Lossless exchange under skew: carryover retry rounds (DESIGN.md §1.6).

Serial-backend regime: all-to-one destinations are the maximal
destination skew (every item lands in ONE (src,dst) bucket), and
multi-flow plans realize zipf-skewed per-bucket loads across the
composite (dest, flow) buckets.  The 8-rank zipf-*destination* version
— real skewed all-to-alls over a mesh axis — runs in spmd_check.py
(``exchange.zipf_retry_lossless``).

The acceptance pins live here: skewed ``queue.push`` / ``hashmap.insert``
at mean-load capacity reach ZERO drops with ``max_rounds > 1`` while the
drop-mode run loses items, and the retry path launches extra all-to-alls
but NO additional ``multi_bin_offsets`` pass.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from jax import ShapeDtypeStruct as SDS

from repro.core import (ExchangeOverflowError, ExchangePlan, Promise,
                        carry_mask, costs, get_backend, route)
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb
from repro.containers import queue as q


def _zipf_sizes(nflows: int, total: int, s: float = 1.2) -> list[int]:
    """Deterministic zipf-ish load split: flow f gets ~ total/(f+1)^s."""
    w = np.array([1.0 / (f + 1) ** s for f in range(nflows)])
    sizes = np.maximum((w / w.sum() * total).astype(int), 1)
    sizes[0] += total - sizes.sum()
    return sizes.tolist()


# ---------------------------------------------------------------------------
# engine-level semantics
# ---------------------------------------------------------------------------

def test_retry_rounds_equal_single_round_at_wider_capacity():
    """route(C, max_rounds=R) is bit-identical to route(R*C): the rounds
    concatenate into the same owner layout; only the launch count (and
    its cost attribution) differs."""
    bk = get_backend(None)
    rng = np.random.default_rng(3)
    pay = jnp.asarray(rng.integers(0, 1 << 30, (50, 2)), jnp.uint32)
    dest = jnp.zeros(50, jnp.int32)
    valid = jnp.asarray(rng.random(50) < 0.8)
    wide = route(bk, pay, dest, capacity=36, valid=valid)
    rr = route(bk, pay, dest, capacity=12, valid=valid, max_rounds=3)
    assert rr.capacity == wide.capacity == 36
    for a, b in zip(wide, rr):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_zipf_flow_loads_fused_equals_fine_with_retries():
    """Zipf-skewed per-bucket loads (one flow per bucket): the fused
    retry schedule matches the Promise.FINE sequential oracle on views,
    replies, and per-flow drop counts, with and without retries."""
    bk = get_backend(None)
    rng = np.random.default_rng(11)
    sizes = _zipf_sizes(5, 120)
    cap = int(np.ceil(np.mean(sizes)))          # mean-load capacity
    pays = [jnp.asarray(rng.integers(0, 1 << 28, (n,)), jnp.uint32)
            for n in sizes]

    def run(promise, max_rounds):
        plan = ExchangePlan(promise=promise, name="zipf")
        hs = [plan.add(p, jnp.zeros(p.shape[0], jnp.int32), cap,
                       reply_lanes=1, op_name=f"f{i}")
              for i, p in enumerate(pays)]
        c = plan.commit(bk, max_rounds=max_rounds)
        for h in hs:
            c.set_reply(h, c.view(h).payload[:, 0] * 2 + 1)
        outs = c.finish(bk)
        return ([c.view(h) for h in hs], [outs[h] for h in hs])

    for r in (1, 3):
        vf, of = run(Promise.NONE, r)
        vs, os_ = run(Promise.FINE, r)
        for (a, b) in zip(vf, vs):
            for x, y in zip(a, b):
                assert np.array_equal(np.asarray(x), np.asarray(y))
        for (a, b) in zip(of, os_):
            assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
            assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))
    # hot flow overflows mean-load capacity without retries, not with
    v1, _ = run(Promise.NONE, 1)
    v3, _ = run(Promise.NONE, 3)
    assert int(v1[0].dropped) > 0
    assert sum(int(v.dropped) for v in v3) == 0


def test_carry_leftover_reinjection_is_lossless():
    """overflow="carry": leftover(h) marks exactly the unshipped items;
    re-injecting them through a second plan recovers every item once."""
    bk = get_backend(None)
    pay = jnp.arange(40, dtype=jnp.uint32) + 1
    dest = jnp.zeros(40, jnp.int32)
    plan = ExchangePlan(name="c")
    h = plan.add(pay, dest, 8, op_name="c")
    c = plan.commit(bk, max_rounds=2, overflow="carry")
    left_pay, mask = c.leftover(h)
    got1 = np.asarray(c.view(h).payload[c.view(h).valid][:, 0])
    assert got1.size == 16 and int(mask.sum()) == 24
    # shipped and leftover partition the batch
    assert not np.intersect1d(got1, np.asarray(pay)[np.asarray(mask)]).size
    res2 = route(bk, left_pay, dest, 8, valid=mask, max_rounds=3,
                 overflow="carry")
    got2 = np.asarray(res2.payload[res2.valid][:, 0])
    assert int(res2.dropped) == 0
    assert sorted(np.concatenate([got1, got2]).tolist()) == \
        list(range(1, 41))
    # carry_mask on a fully-shipped flow is empty
    assert int(carry_mask(res2, mask).sum()) == 0


def test_raise_in_test_policy():
    bk = get_backend(None)
    pay = jnp.arange(10, dtype=jnp.uint32)
    dest = jnp.zeros(10, jnp.int32)
    with pytest.raises(ExchangeOverflowError, match="queue.push"):
        route(bk, pay, dest, capacity=4, op_name="queue.push",
              overflow="raise-in-test")
    # enough rounds -> no overflow -> no raise
    res = route(bk, pay, dest, capacity=4, max_rounds=3,
                overflow="raise-in-test")
    assert int(res.dropped) == 0


# ---------------------------------------------------------------------------
# plan validation (satellite): errors at add(), named after the flow
# ---------------------------------------------------------------------------

def test_plan_add_validates_shapes_and_capacity():
    plan = ExchangePlan()
    pay = jnp.zeros((8, 2), jnp.uint32)
    with pytest.raises(ValueError, match="myop.*dest"):
        plan.add(pay, jnp.zeros(5, jnp.int32), 8, op_name="myop")
    with pytest.raises(ValueError, match="myop.*valid"):
        plan.add(pay, jnp.zeros(8, jnp.int32), 8,
                 valid=jnp.ones(3, bool), op_name="myop")
    with pytest.raises(ValueError, match="myop.*capacity"):
        plan.add(pay, jnp.zeros(8, jnp.int32), 0, op_name="myop")
    with pytest.raises(ValueError, match="myop.*capacity"):
        plan.add(pay, jnp.zeros(8, jnp.int32), -4, op_name="myop")
    with pytest.raises(ValueError, match="myop.*payload"):
        plan.add(jnp.zeros((2, 2, 2), jnp.uint32), jnp.zeros(8, jnp.int32),
                 8, op_name="myop")
    with pytest.raises(ValueError, match="myop.*reply_lanes"):
        plan.add(pay, jnp.zeros(8, jnp.int32), 8, reply_lanes=-1,
                 op_name="myop")
    assert plan.add(pay, jnp.zeros(8, jnp.int32), 8, op_name="myop") == 0


def test_commit_validates_rounds_and_policy():
    bk = get_backend(None)

    def mk():
        plan = ExchangePlan()
        plan.add(jnp.zeros((4, 1), jnp.uint32), jnp.zeros(4, jnp.int32), 4)
        return plan

    with pytest.raises(ValueError, match="max_rounds"):
        mk().commit(bk, max_rounds=0)
    with pytest.raises(ValueError, match="overflow"):
        mk().commit(bk, overflow="retry")


# ---------------------------------------------------------------------------
# acceptance pins: skewed containers + cost accounting
# ---------------------------------------------------------------------------

def test_pin_skewed_queue_push_lossless_with_retries():
    """All-to-one skew at mean-load capacity: drop-mode loses items,
    max_rounds=4 loses none and the ring holds the full multiset."""
    bk = get_backend(None)
    n, vp = 96, 4                     # vp: virtual uniform peer count
    cap = n // vp                     # mean-load capacity
    vals = jnp.arange(n, dtype=jnp.uint32) * 3 + 1
    dest = jnp.zeros(n, jnp.int32)    # all-to-one: the hot bucket
    spec, st0 = q.queue_create(bk, 2 * n, SDS((), jnp.uint32))

    st, pushed, dropped = q.push(bk, spec, st0, vals, dest, capacity=cap)
    assert int(dropped) == n - cap and int(pushed) == cap    # data loss

    st, pushed, dropped = q.push(bk, spec, st0, vals, dest, capacity=cap,
                                 max_rounds=vp)
    assert int(dropped) == 0 and int(pushed) == n            # lossless
    rows, got = q.local_drain(spec, st)
    assert sorted(np.asarray(rows)[np.asarray(got)].tolist()) == \
        sorted(np.asarray(vals).tolist())


def test_pin_skewed_hashmap_insert_lossless_with_retries():
    """Hot-block skew (all keys owned by one rank, capacity at the
    uniform mean): drop-mode fails inserts, retries succeed them all and
    every value is findable."""
    bk = get_backend(None)
    n, vp = 64, 4
    cap = n // vp
    spec, st0 = hm.hashmap_create(bk, 2048, SDS((), jnp.uint32),
                                  SDS((), jnp.uint32), block_size=16)
    keys = jnp.arange(n, dtype=jnp.uint32) + 5
    vals = keys * 7

    st, ok = hm.insert(bk, spec, st0, keys, vals, capacity=cap, attempts=1)
    assert int(ok.sum()) == cap                              # data loss

    st, ok = hm.insert(bk, spec, st0, keys, vals, capacity=cap, attempts=1,
                       max_rounds=vp)
    assert bool(ok.all())                                    # lossless
    st, v, found = hm.find(bk, spec, st, keys, capacity=cap, max_rounds=vp)
    assert bool(found.all())
    assert np.array_equal(np.asarray(v), np.asarray(vals))


def test_pin_retries_launch_collectives_but_no_extra_binning():
    """Cost accounting: max_rounds=R launches R-1 extra request
    all-to-alls (attributed under <op>.retry) off ONE multi_bin_offsets
    pass — never a second binning pass."""
    bk = get_backend(None)
    vals = jnp.arange(64, dtype=jnp.uint32)
    dest = jnp.zeros(64, jnp.int32)
    spec, st0 = q.queue_create(bk, 128, SDS((), jnp.uint32))

    def run(rounds):
        with costs.recording() as log:
            q.push(bk, spec, st0, vals, dest, capacity=16,
                   max_rounds=rounds)
        return log

    base = run(1)
    retry = run(4)
    nbin = lambda log: sum(1 for op, _ in log.entries if op == "exchange.bin")
    assert nbin(base) == 1 and nbin(retry) == 1              # ONE pass
    assert base.by_op("queue.push").collectives == 1
    assert base.by_op("queue.push.retry").collectives == 0
    assert retry.by_op("queue.push").collectives == 1
    assert retry.by_op("queue.push.retry").collectives == 3  # extra launches
    assert retry.total().rounds == 4
    # each retry round re-ships the same wire segment width
    assert retry.by_op("queue.push.retry").bytes_out == \
        3 * base.by_op("queue.push").bytes_out


def test_exact_capacity_flows_skip_retry_launches():
    """A flow whose capacity already covers its whole batch clamps to
    ONE launch (ceil(N/C) rounds) even on a retrying plan: queue.pop's
    unit-request flow pays no retry wire when push_pop retries."""
    bk = get_backend(None)
    vals = jnp.arange(48, dtype=jnp.uint32)
    spec, st = q.queue_create(bk, 256, SDS((), jnp.uint32), circular=True)
    with costs.recording() as log:
        q.push_pop(bk, spec, st, vals, jnp.zeros(48, jnp.int32), 12, 24, 0,
                   max_rounds=4)
    # push flow: ceil(48/12) = 4 rounds of retry wire; pop flow: exact
    # capacity (24 requests, C=24) -> no retry bytes at all
    assert log.by_op("queue.push.retry").bytes_out > 0
    assert log.by_op("queue.pop.retry").bytes_out == 0
    assert log.by_op("queue.push_pop.retry").collectives == 3
    # and the clamp itself: rounds beyond ceil(N/C) are never launched
    with costs.recording() as log2:
        route(bk, vals, jnp.zeros(48, jnp.int32), capacity=24,
              op_name="r", max_rounds=8)
    assert log2.by_op("r.retry").collectives == 1        # ceil(48/24)-1


def test_fused_retry_plan_equals_fine_for_containers():
    """find_insert and push_pop with max_rounds>1: fused schedule ==
    FINE sequential oracle under overflow-heavy all-to-one load."""
    bk = get_backend(None)
    rng = np.random.default_rng(9)
    keys = jnp.asarray(rng.permutation(1 << 16)[:48], jnp.uint32)

    def run(extra):
        spec, st = hm.hashmap_create(bk, 1024, SDS((), jnp.uint32),
                                     SDS((), jnp.uint32), block_size=16)
        st, v, f, ok = hm.find_insert(
            bk, spec, st, keys, keys, keys * 3, capacity=12,
            promise=Promise.FIND | Promise.INSERT | extra, max_rounds=2)
        qspec, qst = q.queue_create(bk, 256, SDS((), jnp.uint32),
                                    circular=True)
        qst, pushed, dropped, out, got = q.push_pop(
            bk, qspec, qst, keys, jnp.zeros(48, jnp.int32), 12, 24, 0,
            promise=Promise.PUSH | Promise.POP | extra, max_rounds=2)
        return v, f, ok, pushed, dropped, out, got, tuple(st), tuple(qst)

    fused = run(Promise.NONE)
    fine = run(Promise.FINE)
    for a, b in zip(fused, fine):
        if isinstance(a, tuple):
            for x, y in zip(a, b):
                assert np.array_equal(np.asarray(x), np.asarray(y))
        else:
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_pin_queue_push_ring_full_carry_lossless():
    """The LAST drop path (ROADMAP): ring-full rejects.  All-to-one push
    at over-ring load with ``overflow="carry"`` ships the owner's
    per-arrival acceptance bit back on the reply wire; re-injecting the
    carried rows after each drain recovers every item exactly once."""
    bk = get_backend(None)
    n, ring = 48, 16
    vals = jnp.arange(n, dtype=jnp.uint32) + 1
    dest = jnp.zeros(n, jnp.int32)
    spec, st0 = q.queue_create(bk, ring, SDS((), jnp.uint32))

    # drop mode: the ring overflow is lost even though the wire kept all
    _, pushed, dropped = q.push(bk, spec, st0, vals, dest, capacity=n)
    assert int(pushed) == ring and int(dropped) == n - ring

    # carry mode: drains + re-injections are lossless
    st, got = st0, []
    carry = jnp.ones(n, bool)
    for want_carry in (n - ring, n - 2 * ring, 0):
        st, pushed, dropped, carry = q.push(bk, spec, st, vals, dest,
                                            capacity=n, valid=carry,
                                            overflow="carry")
        assert int(dropped) == 0
        assert int(carry.sum()) == want_carry
        st, out, gotm = q.local_nonatomic_pop(spec, st, ring)
        got += np.asarray(out)[np.asarray(gotm)].tolist()
    assert sorted(got) == np.asarray(vals).tolist()


def test_queue_push_carry_covers_wire_and_ring_overflow():
    """One carry mask marks BOTH loss paths: items the wire never
    shipped (capacity window) and items a full ring refused."""
    bk = get_backend(None)
    n, ring, wire = 48, 16, 20
    vals = jnp.arange(n, dtype=jnp.uint32) + 1
    dest = jnp.zeros(n, jnp.int32)
    spec, st0 = q.queue_create(bk, ring, SDS((), jnp.uint32))
    st, pushed, dropped, carry = q.push(bk, spec, st0, vals, dest,
                                        capacity=wire, overflow="carry")
    # 20 shipped, 16 accepted: 4 ring rejects + 28 never shipped carried
    assert int(pushed) == ring and int(dropped) == 0
    assert int(carry.sum()) == n - ring
    rows, gotm = q.local_drain(spec, st)
    in_ring = np.asarray(rows)[np.asarray(gotm)]
    # ring ∪ carry is exactly the batch, with no overlap
    assert sorted(in_ring.tolist()
                  + np.asarray(vals)[np.asarray(carry)].tolist()) == \
        np.asarray(vals).tolist()
    # and the reply round is priced: 2 collectives, not fire-and-forget
    with costs.recording() as log:
        q.push(bk, spec, st0, vals, dest, capacity=wire, overflow="carry")
    assert log.total().collectives == 2
    with pytest.raises(ValueError, match="overflow"):
        q.push(bk, spec, st0, vals, dest, capacity=wire, overflow="retry")
    # a LOCAL push honors carry from its local accept mask — same
    # contract, zero collectives
    with costs.recording() as log:
        _, pushed_l, dropped_l, carry_l = q.push(
            bk, spec, st0, vals, dest, capacity=wire,
            promise=Promise.PUSH | Promise.LOCAL, overflow="carry")
    assert log.total().collectives == 0
    assert int(pushed_l) == ring and int(dropped_l) == 0
    assert int(carry_l.sum()) == n - ring


def test_pin_push_pop_ring_full_carry_lossless():
    """Ring-full carry parity for the FUSED schedule (ROADMAP item):
    push_pop(overflow="carry") ships the owner's accept mask back on a
    1-lane reply riding the pop's inverse all-to-all — re-injecting the
    carried rows drains losslessly, and the fused schedule matches the
    FINE sequential oracle's carry mask exactly."""
    bk = get_backend(None)
    n, ring = 48, 16
    vals = jnp.arange(n, dtype=jnp.uint32) + 1
    dest = jnp.zeros(n, jnp.int32)
    spec, st0 = q.queue_create(bk, ring, SDS((), jnp.uint32), circular=True)

    st, got = st0, []
    carry = jnp.ones(n, bool)
    for want in (n - ring, n - 2 * ring, 0):
        st, pushed, dropped, out, gm, carry = q.push_pop(
            bk, spec, st, vals, dest, n, ring, 0, valid=carry,
            overflow="carry")
        assert int(dropped) == 0
        assert int(carry.sum()) == want
        got += np.asarray(out)[np.asarray(gm)].tolist()
    # pops interleave with pushes: everything lands exactly once
    assert sorted(got) == np.asarray(vals).tolist()

    # fused == FINE on the whole 6-tuple (carry mask included)
    def run(extra):
        st1, *rest = q.push_pop(
            bk, spec, st0, vals, dest, n, 8, 0,
            promise=Promise.PUSH | Promise.POP | extra, overflow="carry")
        return tuple(st1) + tuple(rest)

    for a, b in zip(run(Promise.NONE), run(Promise.FINE)):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # the carry reply rides the pop's collective: still 2, not 3
    with costs.recording() as log:
        q.push_pop(bk, spec, st0, vals, dest, n, 8, 0, overflow="carry")
    assert log.total().collectives == 2
    with pytest.raises(ValueError, match="overflow"):
        q.push_pop(bk, spec, st0, vals, dest, n, 8, 0, overflow="retry")


def test_pin_spill_ring_full_carry_lossless():
    """Ring-full carry parity for the buffer spill (ROADMAP item): a
    carry spill declares the 1-lane ring reply, so ring rejects re-stage
    in the buffer instead of dropping — repeated spill+drain cycles are
    lossless even when the owner ring is smaller than the spill."""
    bk = get_backend(None)
    mspec, mst = hm.hashmap_create(bk, 2048, SDS((), jnp.uint32),
                                   SDS((), jnp.uint32), block_size=16)
    ring = 16
    bspec, bst = hb.create(bk, mspec, mst, queue_capacity=ring,
                           buffer_cap=64)
    keys = jnp.arange(48, dtype=jnp.uint32) + 1
    bst, _ = hb.insert(bspec, bst, keys, keys * 3)

    # wire admits everything (capacity 64) — the ring is the bottleneck;
    # drop-mode spill would lose 32 here, carry re-stages them
    staged = []
    for _ in range(3):
        bst, dropped = hb.spill(bk, bspec, bst, capacity=64,
                                overflow="carry")
        assert int(dropped) == 0
        staged.append(int(bst.buf_n[0]))
        # owner drains its ring into the table (flush's local half)
        rows, gotm = q.local_drain(bspec.queue_spec, bst.queue)
        qst = bst.queue._replace(head=bst.queue.tail)
        ms = bspec.map_spec
        mst2, ok = hm.insert(
            bk, ms, bst.map, ms.key_packer.unpack(rows[:, :1]),
            ms.val_packer.unpack(rows[:, 1:]), capacity=1,
            promise=Promise.INSERT | Promise.LOCAL, valid=gotm)
        assert bool(ok[np.asarray(gotm)].all())
        bst = bst._replace(map=mst2, queue=qst)
    assert staged == [32, 16, 0]       # ring-full rejects re-staged
    _, v, found = hm.find(bk, mspec, bst.map, keys, capacity=48)
    assert bool(found.all())
    assert np.array_equal(np.asarray(v), np.asarray(keys) * 3)


def test_buffer_flush_carry_is_lossless_across_cycles():
    """hashmap_buffer.flush(overflow="carry"): wire leftovers re-stage
    instead of dropping; bounded cycles drain them all."""
    bk = get_backend(None)
    mspec, mst = hm.hashmap_create(bk, 2048, SDS((), jnp.uint32),
                                   SDS((), jnp.uint32), block_size=16)
    bspec, bst = hb.create(bk, mspec, mst, queue_capacity=256,
                           buffer_cap=64)
    keys = jnp.arange(48, dtype=jnp.uint32) + 1
    bst, ovf = hb.insert(bspec, bst, keys, keys * 3)
    assert int(ovf) == 0
    staged = []
    for _ in range(3):
        bst, dropped = hb.flush(bk, bspec, bst, capacity=16,
                                overflow="carry")
        assert int(dropped) == 0
        staged.append(int(bst.buf_n[0]))
    assert staged == [32, 16, 0]       # 16 shipped per cycle, none lost
    _, v, found = hm.find(bk, mspec, bst.map, keys, capacity=48)
    assert bool(found.all())
    assert np.array_equal(np.asarray(v), np.asarray(keys) * 3)
    # retry rounds collapse the cycles: one flush drains everything
    bspec2, bst2 = hb.create(bk, mspec, mst, queue_capacity=256,
                             buffer_cap=64)
    bst2, _ = hb.insert(bspec2, bst2, keys, keys * 3)
    bst2, dropped = hb.flush(bk, bspec2, bst2, capacity=16,
                             overflow="carry", max_rounds=3)
    assert int(dropped) == 0 and int(bst2.buf_n[0]) == 0
