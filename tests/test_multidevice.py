"""Multi-device coverage via subprocess (parent stays 1-device)."""

import os
import subprocess
import sys

import pytest

_HERE = os.path.dirname(__file__)


@pytest.mark.slow
def test_spmd_battery():
    """Containers + mini dry-run + MoE parity on 8 fake devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_HERE, "..", "src")
    proc = subprocess.run(
        [sys.executable, os.path.join(_HERE, "spmd_check.py")],
        capture_output=True, text=True, timeout=900, env=env)
    print(proc.stdout)
    print(proc.stderr[-4000:] if proc.stderr else "")
    assert proc.returncode == 0, "spmd battery failed"
    assert "ALL SPMD CHECKS PASSED" in proc.stdout


@pytest.mark.slow
def test_train_restart_determinism(tmp_path):
    """Kill at step 12, restart from checkpoint at 10, finish; the loss
    trajectory must continue (FT restart contract)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_HERE, "..", "src")
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "stablelm-1.6b", "--reduced", "--steps", "20",
           "--batch", "4", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"]
    p1 = subprocess.run(cmd + ["--kill-at", "12"], capture_output=True,
                        text=True, timeout=600, env=env)
    assert p1.returncode == 17, p1.stdout + p1.stderr
    p2 = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                        env=env)
    assert p2.returncode == 0, p2.stdout + p2.stderr
    assert "restored checkpoint at step 10" in p2.stdout
    assert "improved" in p2.stdout
