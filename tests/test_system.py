"""End-to-end behaviour tests for the whole system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import ShapeDtypeStruct as SDS

from repro.configs import get_config, reduced
from repro.core import ConProm, get_backend
from repro.containers import bloom as bl
from repro.containers import hashmap as hm
from repro.containers import hashmap_buffer as hb
from repro.containers import queue as q
from repro.data.genomics import GenomeSim, extract_kmers, pack_kmers
from repro.kernels.ops import MODE_ADD


def test_isx_bucket_sort_end_to_end(rng):
    """Paper Fig. 3: bucket sort via queue exchange, then local sort."""
    bk = get_backend(None)
    n, nbuckets = 4096, 1
    keys = rng.integers(0, 1 << 16, n).astype(np.uint32)
    spec, st = q.queue_create(bk, 8192, SDS((), jnp.uint32))
    dest = jnp.zeros(n, jnp.int32)
    st, _, dropped = q.push(bk, spec, st, jnp.asarray(keys), dest,
                            capacity=n)
    assert int(dropped) == 0
    rows, got = q.local_drain(spec, st)
    local = np.sort(np.asarray(rows)[np.asarray(got)])
    assert np.array_equal(local, np.sort(keys))


def test_kmer_counting_with_bloom(rng):
    """Paper section 9.2.2: histogram k-mers, Bloom filter pre-pass."""
    bk = get_backend(None)
    sim = GenomeSim(genome_len=1 << 10, coverage=6, error_rate=0.02, seed=1)
    kmers = pack_kmers(extract_kmers(sim.reads(), k=15))
    kspec = {"hi": SDS((), jnp.uint32), "lo": SDS((), jnp.uint32)}
    items = {"hi": jnp.asarray(kmers[:, 0]), "lo": jnp.asarray(kmers[:, 1])}
    n = kmers.shape[0]

    bspec, bst = bl.bloom_create(bk, 1 << 18, kspec, k=4)
    bst, seen_before = bl.insert(bk, bspec, bst, items, capacity=n)

    # only k-mers seen 2+ times enter the table (the paper's memory win)
    hspec, hst = hm.hashmap_create(bk, 1 << 15, kspec, SDS((), jnp.uint32),
                                   block_size=16)
    hst, ok = hm.insert(bk, hspec, hst, items,
                        jnp.ones(n, jnp.uint32), capacity=n,
                        valid=seen_before, mode=MODE_ADD, attempts=3)
    stored = int(hm.count_ready(bk, hst))
    uniq = len(np.unique(kmers, axis=0))
    assert 0 < stored < uniq          # the filter pruned singletons

    # ground-truth histogram agreement on repeated kmers
    vals, counts = np.unique(kmers, axis=0, return_counts=True)
    repeated = vals[counts >= 2]
    probe = {"hi": jnp.asarray(repeated[:, 0]),
             "lo": jnp.asarray(repeated[:, 1])}
    hst, v, found = hm.find(bk, hspec, hst, probe,
                            capacity=len(repeated) + 1,
                            promise=ConProm.HashMap.find)
    got = np.asarray(v) + 1           # first occurrence only set the bloom
    assert bool(found.all())
    assert np.array_equal(got, counts[counts >= 2])


def test_contig_generation_walk(rng):
    """Paper section 9.2.1 (Meraculous): build a de Bruijn hash table and
    walk a contig through it."""
    from repro.data.genomics import kmer_neighbors
    bk = get_backend(None)
    k = 9
    genome = rng.integers(0, 4, 64).astype(np.uint8)
    kmers = pack_kmers(extract_kmers(genome[None], k))
    n = kmers.shape[0]
    kspec = {"hi": SDS((), jnp.uint32), "lo": SDS((), jnp.uint32)}
    # value = next base after this kmer
    next_base = genome[k:].astype(np.uint32)
    hspec, hst = hm.hashmap_create(bk, 1 << 12, kspec, SDS((), jnp.uint32),
                                   block_size=16)
    hst, ok = hm.insert(bk, hspec, hst,
                        {"hi": jnp.asarray(kmers[:-1, 0]),
                         "lo": jnp.asarray(kmers[:-1, 1])},
                        jnp.asarray(next_base), capacity=n, attempts=3)
    assert bool(ok.all())

    # walk from the first kmer, reconstruct the genome
    cur = kmers[0]
    out = list(genome[:k])
    for _ in range(len(genome) - k):
        probe = {"hi": jnp.asarray([cur[0]]), "lo": jnp.asarray([cur[1]])}
        hst, v, found = hm.find(bk, hspec, hst, probe, capacity=4,
                                promise=ConProm.HashMap.find)
        if not bool(found[0]):
            break
        b = int(v[0])
        out.append(b)
        nbrs = kmer_neighbors(cur[None], k)
        cur = np.asarray(nbrs[b][0])
    assert np.array_equal(np.asarray(out), genome)


def test_hashmap_buffer_speedup_structure():
    """Buffered insertion does one exchange for the whole phase; direct
    insertion does one per call (the paper's 10x mechanism)."""
    from repro.core import costs
    bk = get_backend(None)
    kspec = SDS((), jnp.uint32)
    mspec, mstate = hm.hashmap_create(bk, 4096, kspec, kspec, block_size=16)
    keys = jnp.arange(256, dtype=jnp.uint32)

    with costs.recording() as direct:
        st = mstate
        for i in range(8):
            st, _ = hm.insert(bk, mspec, st, keys[i * 32:(i + 1) * 32],
                              keys[i * 32:(i + 1) * 32], capacity=64,
                              return_success=False, attempts=1)
    with costs.recording() as buffered:
        bspec, bstate = hb.create(bk, mspec, mstate, queue_capacity=512,
                                  buffer_cap=512)
        for i in range(8):
            bstate, _ = hb.insert(bspec, bstate, keys[i * 32:(i + 1) * 32],
                                  keys[i * 32:(i + 1) * 32])
        bstate, _ = hb.flush(bk, bspec, bstate, capacity=512)
    n_coll_direct = direct.total().collectives
    n_coll_buffered = buffered.total().collectives
    assert n_coll_buffered < n_coll_direct


def test_tiny_training_learns(mesh11, tmp_path):
    """~100k-param model on structured synthetic data: loss must drop."""
    from repro.data.tokens import TokenStream
    from repro.launch.steps import init_state, make_train_step
    cfg = reduced(get_config("stablelm-1.6b"))
    rng = jax.random.PRNGKey(0)
    params, opt, _, _ = init_state(cfg, mesh11, rng)
    step_fn = jax.jit(make_train_step(cfg, mesh11), donate_argnums=(0, 1))
    stream = TokenStream(vocab=cfg.vocab, seq_len=64, global_batch=4)
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in stream.next_batch().items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses
