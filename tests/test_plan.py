"""ExchangePlan scheduler semantics (serial backend; SPMD in spmd_check).

The plan/commit scheduler's contract: N flows committed together behave
exactly like N eager ``route``/``reply`` round trips — same owner views,
same replies, same per-flow drop accounting — while sharing ONE request
collective and ONE reply collective.  ``Promise.FINE`` lowers the same
plan to the eager schedule, which is the oracle these tests compare
against.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExchangePlan, Promise, costs, get_backend, route
from repro.core.exchange import reply
from repro.core.promises import validate


def _mk_flows(rng, n0=24, n1=15):
    pay0 = jnp.asarray(rng.integers(0, 1 << 30, (n0, 2)), jnp.uint32)
    pay1 = jnp.asarray(rng.integers(0, 1 << 30, (n1, 1)), jnp.uint32)
    d0 = jnp.zeros(n0, jnp.int32)
    d1 = jnp.zeros(n1, jnp.int32)
    v0 = jnp.asarray(rng.random(n0) < 0.8)
    v1 = jnp.asarray(rng.random(n1) < 0.8)
    return (pay0, d0, v0), (pay1, d1, v1)


def test_multi_flow_views_match_eager_routes():
    bk = get_backend(None)
    rng = np.random.default_rng(5)
    (p0, d0, v0), (p1, d1, v1) = _mk_flows(rng)
    plan = ExchangePlan(name="test")
    h0 = plan.add(p0, d0, 24, valid=v0, op_name="a")
    h1 = plan.add(p1, d1, 15, valid=v1, op_name="b")
    c = plan.commit(bk)
    e0 = route(bk, p0, d0, 24, valid=v0)
    e1 = route(bk, p1, d1, 15, valid=v1)
    for view, eager in ((c.view(h0), e0), (c.view(h1), e1)):
        assert np.array_equal(np.asarray(view.payload),
                              np.asarray(eager.payload))
        assert np.array_equal(np.asarray(view.valid), np.asarray(eager.valid))
        assert np.array_equal(np.asarray(view.src_pos),
                              np.asarray(eager.src_pos))
        assert int(view.dropped) == int(eager.dropped)


def test_fused_replies_match_eager_replies():
    bk = get_backend(None)
    rng = np.random.default_rng(6)
    (p0, d0, v0), (p1, d1, v1) = _mk_flows(rng)
    plan = ExchangePlan(name="test")
    h0 = plan.add(p0, d0, 24, reply_lanes=2, valid=v0, op_name="a")
    h1 = plan.add(p1, d1, 15, reply_lanes=1, valid=v1, op_name="b")
    c = plan.commit(bk)
    r0 = c.view(h0).payload * 3 + 1
    r1 = c.view(h1).payload * 5 + 2
    c.set_reply(h0, r0)
    c.set_reply(h1, r1)
    outs = c.finish(bk)

    e0 = route(bk, p0, d0, 24, valid=v0)
    e1 = route(bk, p1, d1, 15, valid=v1)
    x0 = reply(bk, e0, e0.payload * 3 + 1, orig_n=p0.shape[0])
    x1 = reply(bk, e1, e1.payload * 5 + 2, orig_n=p1.shape[0])
    for (out, ans), (xout, xans) in ((outs[h0], x0), (outs[h1], x1)):
        assert np.array_equal(np.asarray(ans), np.asarray(xans))
        assert np.array_equal(np.asarray(out), np.asarray(xout))


def test_per_flow_drop_accounting():
    """Each flow drops against its OWN capacity, not a shared budget."""
    bk = get_backend(None)
    plan = ExchangePlan(name="test")
    h0 = plan.add(jnp.arange(10, dtype=jnp.uint32), jnp.zeros(10, jnp.int32),
                  4, op_name="a")
    h1 = plan.add(jnp.arange(6, dtype=jnp.uint32), jnp.zeros(6, jnp.int32),
                  6, op_name="b")
    c = plan.commit(bk)
    assert int(c.view(h0).dropped) == 6
    assert int(c.view(h1).dropped) == 0
    assert int(c.view(h0).valid.sum()) == 4
    assert int(c.view(h1).valid.sum()) == 6


def test_fused_costs_one_collective_per_direction():
    """2 flows, both replying: 2 collectives total, each flow charged
    the EXACT bytes of its own ragged wire segment under its op name —
    L_f+1 request words and R_f reply words per row, with no cross-flow
    padding (the narrow flow pays nothing for the wide one)."""
    bk = get_backend(None)
    n0, n1, c0, c1 = 8, 8, 8, 8
    plan = ExchangePlan(name="planop")
    h0 = plan.add(jnp.zeros((n0, 3), jnp.uint32), jnp.zeros(n0, jnp.int32),
                  c0, reply_lanes=2, op_name="a")
    h1 = plan.add(jnp.zeros((n1, 1), jnp.uint32), jnp.zeros(n1, jnp.int32),
                  c1, reply_lanes=1, op_name="b")
    with costs.recording() as log:
        c = plan.commit(bk)
        c.set_reply(h0, jnp.zeros((c0, 2), jnp.uint32))
        c.set_reply(h1, jnp.zeros((c1, 1), jnp.uint32))
        c.finish(bk)
    tot = log.total()
    assert tot.collectives == 2 and tot.rounds == 2
    assert log.by_op("a").bytes_out == c0 * (3 + 1) * 4
    assert log.by_op("b").bytes_out == c1 * (1 + 1) * 4
    assert log.by_op("a").bytes_in == c0 * 2 * 4
    assert log.by_op("b").bytes_in == c1 * 1 * 4
    # physical collective + round attributed to the plan's op name
    assert log.by_op("planop").collectives == 2
    assert log.by_op("planop").rounds == 2
    assert tot.bytes_moved == c0 * (4 + 2) * 4 + c1 * (2 + 1) * 4


def test_fine_promise_lowers_to_sequential_schedule():
    bk = get_backend(None)
    rng = np.random.default_rng(7)
    (p0, d0, v0), (p1, d1, v1) = _mk_flows(rng)

    def run(promise):
        plan = ExchangePlan(promise=promise, name="test")
        h0 = plan.add(p0, d0, 24, reply_lanes=2, valid=v0, op_name="a")
        h1 = plan.add(p1, d1, 15, reply_lanes=1, valid=v1, op_name="b")
        with costs.recording() as log:
            c = plan.commit(bk)
            c.set_reply(h0, c.view(h0).payload * 3)
            c.set_reply(h1, c.view(h1).payload + 9)
            outs = c.finish(bk)
        return log, outs[h0], outs[h1]

    lf, f0, f1 = run(Promise.NONE)
    ls, s0, s1 = run(Promise.FINE)
    assert lf.total().collectives == 2          # fused: 1 out + 1 back
    assert ls.total().collectives == 4          # FINE: per-flow rounds
    for (a, b) in ((f0, s0), (f1, s1)):
        assert np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
        assert np.array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_fine_local_combination_rejected():
    with pytest.raises(ValueError):
        ExchangePlan(promise=Promise.FINE | Promise.LOCAL)
    with pytest.raises(ValueError):
        validate(Promise.FIND | Promise.FINE | Promise.LOCAL)


def test_empty_plan_rejected():
    with pytest.raises(ValueError):
        ExchangePlan().commit(get_backend(None))


def test_reply_lane_mismatch_rejected():
    bk = get_backend(None)
    plan = ExchangePlan()
    h = plan.add(jnp.zeros((4, 1), jnp.uint32), jnp.zeros(4, jnp.int32), 4,
                 reply_lanes=2, op_name="a")
    c = plan.commit(bk)
    with pytest.raises(ValueError):
        c.set_reply(h, jnp.zeros((4, 3), jnp.uint32))
    with pytest.raises(ValueError):
        c.finish(bk)        # declared reply never staged


def test_double_commit_and_double_finish_rejected():
    """Re-committing or re-finishing would silently launch duplicate
    collectives and double-record the cost pins — both raise instead."""
    bk = get_backend(None)
    plan = ExchangePlan()
    h = plan.add(jnp.zeros((4, 1), jnp.uint32), jnp.zeros(4, jnp.int32), 4,
                 reply_lanes=1, op_name="a")
    c = plan.commit(bk)
    with pytest.raises(ValueError):
        plan.commit(bk)
    c.set_reply(h, jnp.zeros((4, 1), jnp.uint32))
    c.finish(bk)
    with pytest.raises(ValueError):
        c.finish(bk)


def test_undeclared_reply_rejected():
    bk = get_backend(None)
    plan = ExchangePlan()
    h = plan.add(jnp.zeros((4, 1), jnp.uint32), jnp.zeros(4, jnp.int32), 4,
                 op_name="a")
    c = plan.commit(bk)
    with pytest.raises(ValueError):
        c.set_reply(h, jnp.zeros((4, 1), jnp.uint32))


def test_three_flow_mixed_reply_plan():
    """Flows without replies coexist; reply wire stays compact."""
    bk = get_backend(None)
    rng = np.random.default_rng(8)
    n = 12
    pays = [jnp.asarray(rng.integers(0, 1 << 20, (n, w)), jnp.uint32)
            for w in (1, 2, 1)]
    plan = ExchangePlan(name="test")
    hs = [plan.add(p, jnp.zeros(n, jnp.int32), n,
                   reply_lanes=(0 if i == 1 else 1), op_name=f"f{i}")
          for i, p in enumerate(pays)]
    c = plan.commit(bk)
    with costs.recording() as log:
        c.set_reply(hs[0], c.view(hs[0]).payload[:, 0] + 1)
        c.set_reply(hs[2], c.view(hs[2]).payload[:, 0] + 2)
        outs = c.finish(bk)
    assert hs[1] not in outs
    # reply wire: only the two replying flows' segments, 1 lane each
    assert log.total().bytes_in == 2 * n * 1 * 4
    out0, ans0 = outs[hs[0]]
    assert bool(ans0.all())
    assert np.array_equal(np.asarray(out0[:, 0]),
                          np.asarray(pays[0][:, 0]) + 1)
