"""Heap container + variable-length ObjectContainer (serial_ptr) tests."""

import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from repro.core import get_backend
from repro.core.object_container import SerialPtrPacker
from repro.containers import hashmap as hm
from repro.containers.heap import heap_create, rget_rows, store_local


def test_store_and_rget_spans():
    bk = get_backend(None)
    spec, st = heap_create(bk, 256, lanes=2)
    rows = jnp.arange(24, dtype=jnp.uint32).reshape(12, 2)
    lengths = jnp.asarray([4, 4, 4], jnp.int32)
    st, ptrs, ok = store_local(bk, spec, st, rows, lengths)
    assert bool(ok.all())
    got, found, dropped = rget_rows(bk, spec, st, ptrs, span=4, capacity=16)
    assert bool(found.all())
    assert int(dropped) == 0
    assert np.array_equal(np.asarray(got).reshape(12, 2), np.asarray(rows))


def test_heap_overflow_reported():
    bk = get_backend(None)
    spec, st = heap_create(bk, 8, lanes=1)
    rows = jnp.arange(16, dtype=jnp.uint32)[:, None]
    st, ptrs, ok = store_local(bk, spec, st, rows,
                               jnp.asarray([16], jnp.int32))
    assert not bool(ok.any())
    assert int(st.top[0]) == 0          # failed alloc does not advance


def test_failed_alloc_pointers_do_not_alias_live_rows():
    """Regression: a failed store_local used to hand out in-range
    offsets; a later rget_rows through them read OTHER records' data.
    Failed pointers now clamp to the sentinel and read as not-found."""
    bk = get_backend(None)
    spec, st = heap_create(bk, 8, lanes=1)
    live = jnp.arange(6, dtype=jnp.uint32)[:, None] + 100
    st, live_ptrs, ok = store_local(bk, spec, st, live,
                                    jnp.asarray([3, 3], jnp.int32))
    assert bool(ok.all())
    st, bad_ptrs, ok2 = store_local(
        bk, spec, st, jnp.full((4, 1), 7, jnp.uint32),
        jnp.asarray([4], jnp.int32))
    assert not bool(ok2.any())
    assert int(bad_ptrs.offset[0]) == spec.local_rows    # sentinel
    rows, found, dropped = rget_rows(bk, spec, st, bad_ptrs, span=4,
                                     capacity=8)
    assert not bool(found.any())        # not another record's bytes
    assert int(dropped) == 0            # absent, NOT wire overflow
    assert int(np.asarray(rows).sum()) == 0
    # live records unaffected
    rows2, found2, _ = rget_rows(bk, spec, st, live_ptrs, span=3,
                                 capacity=8)
    assert bool(found2.all())
    assert np.array_equal(np.asarray(rows2).reshape(6, 1), np.asarray(live))


def test_short_record_at_heap_end_stays_found_with_wider_span():
    """The documented varlen pattern (read max span, slice by stored
    length) must not unfind a live record whose span overshoots the
    heap end: only the BASE row decides liveness; tail rows read 0."""
    bk = get_backend(None)
    spec, st = heap_create(bk, 8, lanes=1)
    rows = jnp.asarray([[11], [22], [33], [44], [55], [66], [77], [88]],
                       jnp.uint32)
    st, ptrs, ok = store_local(bk, spec, st, rows,
                               jnp.asarray([6, 2], jnp.int32))
    assert bool(ok.all())
    got, found, dropped = rget_rows(bk, spec, st, ptrs, span=4, capacity=32)
    assert bool(found.all())            # record 1 (offset 6, len 2) lives
    assert int(dropped) == 0
    assert np.asarray(got)[1, :2, 0].tolist() == [77, 88]
    assert np.asarray(got)[1, 2:, 0].tolist() == [0, 0]   # overshoot -> 0


def test_rget_distinguishes_overflow_from_absent():
    """Regression: route overflow used to surface as a silent
    found=False.  The dropped count now separates the two, and retry
    rounds recover the reads without raising ``capacity``."""
    bk = get_backend(None)
    spec, st = heap_create(bk, 64, lanes=1)
    rows = jnp.arange(16, dtype=jnp.uint32)[:, None]
    st, ptrs, ok = store_local(bk, spec, st, rows,
                               jnp.full((8,), 2, jnp.int32))
    assert bool(ok.all())
    # capacity admits half the 8*2 unit row-requests
    got, found, dropped = rget_rows(bk, spec, st, ptrs, span=2, capacity=4)
    assert int(dropped) == 8
    assert not bool(found.all())        # wire overflow, flagged as such
    got2, found2, dropped2 = rget_rows(bk, spec, st, ptrs, span=2,
                                       capacity=4, max_rounds=2)
    assert int(dropped2) == 0
    assert bool(found2.all())
    assert np.array_equal(np.asarray(got2).reshape(16, 1), np.asarray(rows))


def test_varlen_strings_behind_hashmap():
    """The paper's serial_ptr flow: hashmap values are (rank, offset,
    length) records; the bytes live in the heap."""
    bk = get_backend(None)
    strings = [b"hello", b"bcl!", b"distributed containers", b"x"]
    max_rows = 8  # 4 bytes per u32 lane -> up to 32 chars

    def pack_str(s: bytes):
        padded = s.ljust(max_rows * 4, b"\0")
        return np.frombuffer(padded, np.uint32).reshape(max_rows, 1)

    rows = jnp.asarray(np.concatenate([pack_str(s) for s in strings]))
    lengths = jnp.full((len(strings),), max_rows, jnp.int32)

    hspec, hstate = heap_create(bk, 256, lanes=1)
    hstate, ptrs, ok = store_local(bk, hspec, hstate, rows, lengths)
    assert bool(ok.all())

    mspec, mstate = hm.hashmap_create(
        bk, 512, SDS((), jnp.uint32), SerialPtrPacker(), block_size=16)
    keys = jnp.arange(len(strings), dtype=jnp.uint32) + 100
    vals = {"rank": ptrs.rank, "offset": ptrs.offset,
            "length": jnp.asarray([len(s) for s in strings], jnp.int32)}
    mstate, ins_ok = hm.insert(bk, mspec, mstate, keys, vals, capacity=8)
    assert bool(ins_ok.all())

    mstate, got, found = hm.find(bk, mspec, mstate, keys, capacity=8)
    assert bool(found.all())
    back = GlobalFetch = rget_rows(
        bk, hspec, hstate,
        type(ptrs)(got["rank"], got["offset"]), span=max_rows,
        capacity=16)[0]
    for i, s in enumerate(strings):
        raw = np.asarray(back[i]).tobytes()[: int(got["length"][i])]
        assert raw == s, (raw, s)


def test_gpipe_equals_sequential():
    """4-stage pipeline == sequential stage composition (1-device mesh
    degenerates to S=1; the real multi-stage check runs in the
    multidevice subprocess battery)."""
    import jax
    from repro.compat import make_mesh
    from repro.parallel import gpipe
    mesh = make_mesh((1,), ("stage",))
    w = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8))

    def stage(params, xx):
        return jnp.tanh(xx @ params)

    out = gpipe(stage, w, x, mesh, axis="stage")
    expect = jnp.tanh(x @ w[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6)
