"""Heap container + variable-length ObjectContainer (serial_ptr) tests."""

import jax.numpy as jnp
import numpy as np
from jax import ShapeDtypeStruct as SDS

from repro.core import get_backend
from repro.core.object_container import SerialPtrPacker
from repro.containers import hashmap as hm
from repro.containers.heap import heap_create, rget_rows, store_local


def test_store_and_rget_spans():
    bk = get_backend(None)
    spec, st = heap_create(bk, 256, lanes=2)
    rows = jnp.arange(24, dtype=jnp.uint32).reshape(12, 2)
    lengths = jnp.asarray([4, 4, 4], jnp.int32)
    st, ptrs, ok = store_local(bk, spec, st, rows, lengths)
    assert bool(ok.all())
    got, found = rget_rows(bk, spec, st, ptrs, span=4, capacity=16)
    assert bool(found.all())
    assert np.array_equal(np.asarray(got).reshape(12, 2), np.asarray(rows))


def test_heap_overflow_reported():
    bk = get_backend(None)
    spec, st = heap_create(bk, 8, lanes=1)
    rows = jnp.arange(16, dtype=jnp.uint32)[:, None]
    st, ptrs, ok = store_local(bk, spec, st, rows,
                               jnp.asarray([16], jnp.int32))
    assert not bool(ok.any())
    assert int(st.top[0]) == 0          # failed alloc does not advance


def test_varlen_strings_behind_hashmap():
    """The paper's serial_ptr flow: hashmap values are (rank, offset,
    length) records; the bytes live in the heap."""
    bk = get_backend(None)
    strings = [b"hello", b"bcl!", b"distributed containers", b"x"]
    max_rows = 8  # 4 bytes per u32 lane -> up to 32 chars

    def pack_str(s: bytes):
        padded = s.ljust(max_rows * 4, b"\0")
        return np.frombuffer(padded, np.uint32).reshape(max_rows, 1)

    rows = jnp.asarray(np.concatenate([pack_str(s) for s in strings]))
    lengths = jnp.full((len(strings),), max_rows, jnp.int32)

    hspec, hstate = heap_create(bk, 256, lanes=1)
    hstate, ptrs, ok = store_local(bk, hspec, hstate, rows, lengths)
    assert bool(ok.all())

    mspec, mstate = hm.hashmap_create(
        bk, 512, SDS((), jnp.uint32), SerialPtrPacker(), block_size=16)
    keys = jnp.arange(len(strings), dtype=jnp.uint32) + 100
    vals = {"rank": ptrs.rank, "offset": ptrs.offset,
            "length": jnp.asarray([len(s) for s in strings], jnp.int32)}
    mstate, ins_ok = hm.insert(bk, mspec, mstate, keys, vals, capacity=8)
    assert bool(ins_ok.all())

    mstate, got, found = hm.find(bk, mspec, mstate, keys, capacity=8)
    assert bool(found.all())
    back = GlobalFetch = rget_rows(
        bk, hspec, hstate,
        type(ptrs)(got["rank"], got["offset"]), span=max_rows,
        capacity=16)[0]
    for i, s in enumerate(strings):
        raw = np.asarray(back[i]).tobytes()[: int(got["length"][i])]
        assert raw == s, (raw, s)


def test_gpipe_equals_sequential():
    """4-stage pipeline == sequential stage composition (1-device mesh
    degenerates to S=1; the real multi-stage check runs in the
    multidevice subprocess battery)."""
    import jax
    from repro.compat import make_mesh
    from repro.parallel import gpipe
    mesh = make_mesh((1,), ("stage",))
    w = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 8))

    def stage(params, xx):
        return jnp.tanh(xx @ params)

    out = gpipe(stage, w, x, mesh, axis="stage")
    expect = jnp.tanh(x @ w[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-6)
