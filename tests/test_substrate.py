"""Optimizer, schedules, compression, data, checkpoint, runtime tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.genomics import (GenomeSim, extract_kmers, kmer_neighbors,
                                 pack_kmers, unpack_kmers)
from repro.data.tokens import TokenStream
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.optim.compress import compressed_psum, int8_compress
from repro.runtime.elastic import plan_remesh
from repro.runtime.ft import (FaultToleranceManager, NodeHealth,
                              StragglerDetector)


class TestAdamW:
    def test_converges_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
        state = adamw_init(cfg, params)
        loss_fn = lambda p: jnp.sum(jnp.square(p["w"]))
        for _ in range(200):
            g = jax.grad(loss_fn)(params)
            params, state, _ = adamw_update(cfg, params, g, state)
        assert float(loss_fn(params)) < 1e-3

    def test_factored_second_moment_shapes(self):
        cfg = AdamWConfig(factored=True, factored_min_size=4)
        params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((8,))}
        state = adamw_init(cfg, params)
        st_w = state["per_param"]["w"]
        assert "vr" in st_w and st_w["vr"].shape == (8,)
        assert st_w["vc"].shape == (16,)
        assert "v" in state["per_param"]["b"]     # vectors stay unfactored

    def test_factored_converges(self):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, factored=True,
                          factored_min_size=2)
        params = {"w": jnp.ones((4, 4)) * 3}
        state = adamw_init(cfg, params)
        loss_fn = lambda p: jnp.sum(jnp.square(p["w"]))
        for _ in range(300):
            g = jax.grad(loss_fn)(params)
            params, state, _ = adamw_update(cfg, params, g, state)
        assert float(loss_fn(params)) < 1e-2

    def test_grad_clipping(self):
        cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
        params = {"w": jnp.zeros((4,))}
        state = adamw_init(cfg, params)
        g = {"w": jnp.full((4,), 1e6)}
        _, _, m = adamw_update(cfg, params, g, state)
        assert float(m["grad_norm"]) > 1e5    # reported unclipped

    def test_moment_dtype_policy(self):
        cfg = AdamWConfig(moment_dtype="bfloat16")
        state = adamw_init(cfg, {"w": jnp.zeros((4, 4))})
        assert state["per_param"]["w"]["m"].dtype == jnp.bfloat16


def test_warmup_cosine_shape():
    assert float(warmup_cosine(0, warmup=10, total=100)) == 0.0
    assert abs(float(warmup_cosine(10, warmup=10, total=100)) - 1.0) < 1e-6
    assert float(warmup_cosine(100, warmup=10, total=100)) <= \
        float(warmup_cosine(50, warmup=10, total=100))


def test_int8_compress_accuracy(rng):
    g = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    q, scale, res = int8_compress(g)
    err = np.abs(np.asarray(res))
    assert err.max() <= float(scale.max()) * 0.5 + 1e-6


class TestTokenStream:
    def test_deterministic_restart(self):
        a = TokenStream(vocab=100, seq_len=32, global_batch=4, seed=7)
        a.next_batch()          # advance past step 0
        b2 = a.next_batch()
        b = TokenStream(vocab=100, seq_len=32, global_batch=4, seed=7)
        b.load_state_dict({"step": 1, "seed": 7})
        b2_replay = b.next_batch()
        assert np.array_equal(b2["tokens"], b2_replay["tokens"])

    def test_shard_partition(self):
        full = TokenStream(vocab=100, seq_len=16, global_batch=8, seed=3)
        fb = full.next_batch()
        s0 = TokenStream(vocab=100, seq_len=16, global_batch=8, seed=3)
        s1 = TokenStream(vocab=100, seq_len=16, global_batch=8, seed=3)
        b0 = s0.next_batch(n_shards=2, shard=0)
        b1 = s1.next_batch(n_shards=2, shard=1)
        assert np.array_equal(fb["tokens"],
                              np.concatenate([b0["tokens"], b1["tokens"]]))

    def test_elastic_rescale_same_data(self):
        """4-shard and 2-shard runs see the same global batch."""
        shards4 = [TokenStream(vocab=50, seq_len=8, global_batch=8, seed=1)
                   for _ in range(4)]
        got4 = np.concatenate([s.next_batch(4, i)["tokens"]
                               for i, s in enumerate(shards4)])
        shards2 = [TokenStream(vocab=50, seq_len=8, global_batch=8, seed=1)
                   for _ in range(2)]
        got2 = np.concatenate([s.next_batch(2, i)["tokens"]
                               for i, s in enumerate(shards2)])
        assert np.array_equal(got4, got2)


class TestGenomics:
    def test_kmer_pack_roundtrip(self, rng):
        seqs = rng.integers(0, 4, (10, 50)).astype(np.uint8)
        kmers = extract_kmers(seqs, k=21)
        lanes = pack_kmers(kmers)
        back = unpack_kmers(lanes, 21)
        assert np.array_equal(kmers, back)

    def test_neighbors(self):
        km = np.array([[0, 1, 2, 3]], np.uint8)      # ACGT
        lanes = pack_kmers(km)
        nbrs = kmer_neighbors(lanes, 4)
        for b, nb in enumerate(nbrs):
            assert np.array_equal(unpack_kmers(nb, 4),
                                  np.array([[1, 2, 3, b]], np.uint8))

    def test_reads_cover_genome(self):
        sim = GenomeSim(genome_len=1 << 10, coverage=4, error_rate=0.0)
        reads = sim.reads()
        assert reads.shape[1] == sim.read_len
        g = sim.genome()
        # error-free reads are exact substrings
        row = reads[0]
        found = False
        for s in range(len(g) - len(row)):
            if np.array_equal(g[s:s + len(row)], row):
                found = True
                break
        assert found


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))},
                "step": jnp.int32(5)}
        save_checkpoint(str(tmp_path), 5, tree)
        like = jax.tree_util.tree_map(jnp.zeros_like, tree)
        got, step = restore_checkpoint(str(tmp_path), None, like)
        assert step == 5
        assert np.array_equal(np.asarray(got["a"]), np.arange(10))

    def test_retention(self, tmp_path):
        tree = {"x": jnp.zeros(4)}
        for s in range(6):
            save_checkpoint(str(tmp_path), s, tree, keep=2)
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
        assert steps == [4, 5]

    def test_atomic_no_tmp_visible(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(4)})
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
        assert latest_step(str(tmp_path)) == 1

    def test_structure_mismatch_rejected(self, tmp_path):
        save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros(4)})
        with pytest.raises(ValueError):
            restore_checkpoint(str(tmp_path), 1,
                               {"x": jnp.zeros(4), "y": jnp.zeros(2)})

    def test_corrupt_leaf_detected(self, tmp_path):
        from repro.checkpoint import CheckpointCorruptError
        tree = {"x": jnp.arange(16)}
        save_checkpoint(str(tmp_path), 1, tree)
        # bit-rot the array archive in place
        npz = os.path.join(str(tmp_path), "step_000000001", "arr_0.npz")
        data = bytearray(open(npz, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(npz, "wb").write(bytes(data))
        with pytest.raises(CheckpointCorruptError):
            restore_checkpoint(str(tmp_path), 1, tree)

    def test_torn_checkpoint_detected(self, tmp_path):
        from repro.checkpoint import CheckpointCorruptError
        tree = {"x": jnp.arange(16)}
        save_checkpoint(str(tmp_path), 1, tree)
        npz = os.path.join(str(tmp_path), "step_000000001", "arr_0.npz")
        data = open(npz, "rb").read()
        open(npz, "wb").write(data[:len(data) // 2])    # truncated write
        with pytest.raises(CheckpointCorruptError):
            restore_checkpoint(str(tmp_path), 1, tree)

    def test_restore_latest_falls_back_to_intact(self, tmp_path):
        """A corrupt newest checkpoint never bricks recovery: the manager
        restores the newest step that passes its integrity check."""
        from repro.checkpoint import CheckpointCorruptError, CheckpointManager
        tree5 = {"x": jnp.full((8,), 5)}
        tree9 = {"x": jnp.full((8,), 9)}
        save_checkpoint(str(tmp_path), 5, tree5)
        save_checkpoint(str(tmp_path), 9, tree9)
        npz = os.path.join(str(tmp_path), "step_000000009", "arr_0.npz")
        data = bytearray(open(npz, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(npz, "wb").write(bytes(data))
        mgr = CheckpointManager(str(tmp_path))
        got, step = mgr.restore_latest({"x": jnp.zeros(8, jnp.int32)})
        assert step == 5
        assert np.array_equal(np.asarray(got["x"]), np.full(8, 5))
        # both corrupt -> the newest step's error surfaces
        npz5 = os.path.join(str(tmp_path), "step_000000005", "arr_0.npz")
        data = bytearray(open(npz5, "rb").read())
        data[len(data) // 2] ^= 0xFF
        open(npz5, "wb").write(bytes(data))
        with pytest.raises(CheckpointCorruptError):
            mgr.restore_latest({"x": jnp.zeros(8, jnp.int32)})


class TestFaultTolerance:
    def test_failure_declared_after_timeout(self):
        ft = FaultToleranceManager(n_nodes=4, heartbeat_interval=1.0,
                                   timeout_beats=3)
        for n in range(4):
            ft.heartbeat(n, now=0.0)
        ft.heartbeat(0, now=5.0)
        ft.heartbeat(1, now=5.0)
        ft.heartbeat(2, now=5.0)          # node 3 silent
        dec = ft.tick(now=5.0, last_ckpt_step=42)
        assert dec.action == "restart"
        assert dec.failed_nodes == [3]
        assert dec.restart_step == 42
        assert ft.nodes[3].health == NodeHealth.FAILED

    def test_spare_promotion(self):
        ft = FaultToleranceManager(n_nodes=4, n_spares=1,
                                   heartbeat_interval=1.0, timeout_beats=2)
        for n in range(3):
            ft.heartbeat(n, now=0.0)
        ft.heartbeat(0, now=3.0)
        ft.heartbeat(1, now=3.0)
        dec = ft.tick(now=3.0, last_ckpt_step=7)
        assert dec.failed_nodes == [2]
        assert dec.promoted_spares == [3]
        assert ft.nodes[3].health == NodeHealth.HEALTHY

    def test_promoted_spare_survives_next_tick(self):
        """Regression: promotion must stamp the spare's heartbeat.

        A spare has never heartbeated (last_heartbeat=0.0); if promotion
        leaves that stamp, the very next tick sees a huge gap and
        instantly re-fails the node it just promoted."""
        ft = FaultToleranceManager(n_nodes=4, n_spares=1,
                                   heartbeat_interval=1.0, timeout_beats=2)
        for n in range(3):
            ft.heartbeat(n, now=0.0)
        ft.heartbeat(0, now=3.0)
        ft.heartbeat(1, now=3.0)
        dec = ft.tick(now=3.0, last_ckpt_step=7)
        assert dec.promoted_spares == [3]
        assert ft.nodes[3].last_heartbeat == 3.0
        assert ft.nodes[3].missed == 0
        # the promoted node keeps heartbeating like everyone else
        ft.heartbeat(0, now=3.5)
        ft.heartbeat(1, now=3.5)
        ft.heartbeat(3, now=3.5)
        dec2 = ft.tick(now=3.6, last_ckpt_step=8)
        assert dec2.action == "none"
        assert ft.nodes[3].health == NodeHealth.HEALTHY

    def test_suspect_recovers(self):
        ft = FaultToleranceManager(n_nodes=2, heartbeat_interval=1.0,
                                   timeout_beats=3)
        ft.heartbeat(0, 0.0)
        ft.heartbeat(1, 0.0)
        ft.tick(1.5, 0)
        assert ft.nodes[1].health == NodeHealth.SUSPECT
        ft.heartbeat(1, 1.6)
        ft.tick(1.7, 0)
        assert ft.nodes[1].health == NodeHealth.HEALTHY


class TestStraggler:
    def test_detects_slow_node(self):
        sd = StragglerDetector(n_nodes=8, threshold=2.0)
        for step in range(20):
            for n in range(8):
                sd.observe(n, 1.0 if n != 5 else 2.5)
        assert sd.stragglers() == [5]
        assert sd.mitigation(5) == "swap_at_checkpoint"

    def test_no_false_positives_uniform(self):
        sd = StragglerDetector(n_nodes=8)
        rng = np.random.default_rng(0)
        for _ in range(50):
            for n in range(8):
                sd.observe(n, 1.0 + rng.random() * 0.01)
        assert sd.stragglers() == []

    def test_cold_start_safe(self):
        """Regression: mitigation/_persistent on a fresh detector (no
        observations at all) must not crash on the empty EWMA list."""
        sd = StragglerDetector(n_nodes=4)
        assert sd.stragglers() == []
        assert sd._persistent(0) is False       # empty EWMA: safe default
        assert sd.mitigation(0) == "rebalance_data"
        # one lone observation: still no median crash, no straggler
        sd.observe(2, 1.0)
        assert sd.stragglers() == []
        assert sd.mitigation(2) in ("rebalance_data", "swap_at_checkpoint")


class TestElastic:
    def test_plan_preserves_model_axis(self):
        plan = plan_remesh(("data", "model"), (16, 16),
                           available_devices=192)
        assert plan.new_shape[1] == 16
        assert plan.new_shape[0] * 16 <= 192
        assert plan.batch_per_shard_scale >= 1.0

    def test_plan_multipod(self):
        plan = plan_remesh(("pod", "data", "model"), (2, 16, 16),
                           available_devices=384)
        assert plan.new_shape[-1] == 16
        total = np.prod(plan.new_shape)
        assert total <= 384

    def test_insufficient_devices_raises(self):
        with pytest.raises(ValueError):
            plan_remesh(("data", "model"), (16, 16), available_devices=8)

    def test_non_divisible_survivors(self):
        """13 survivors of a (4,4) mesh: only 3 data rows of 4 devices
        fit, one survivor is dropped, per-shard batch grows 4/3."""
        plan = plan_remesh(("data", "model"), (4, 4), available_devices=13)
        assert plan.new_shape == (3, 4)
        assert plan.dropped_devices == 1
        assert abs(plan.batch_per_shard_scale - 4 / 3) < 1e-9
        # rectangular invariant: the plan uses exactly its device grid
        assert int(np.prod(plan.new_shape)) + plan.dropped_devices == 13

    def test_no_model_axis_mesh(self):
        """Without a 'model' axis the LAST axis is preserved instead."""
        plan = plan_remesh(("replica", "data"), (4, 2),
                           available_devices=6)
        assert plan.new_shape[-1] == 2            # preserved axis intact
        assert int(np.prod(plan.new_shape)) <= 6
        assert int(np.prod(plan.new_shape)) + plan.dropped_devices == 6
        assert plan.batch_per_shard_scale == pytest.approx(4 / 3)

    def test_shrink_to_one_data_row(self):
        """Exactly model-axis devices left: one data row survives and
        every shard carries the whole former data dimension."""
        plan = plan_remesh(("data", "model"), (8, 4), available_devices=4)
        assert plan.new_shape == (1, 4)
        assert plan.dropped_devices == 0
        assert plan.batch_per_shard_scale == pytest.approx(8.0)

    def test_rectangular_invariant_sweep(self):
        """new_shape is always rectangular and never exceeds the
        survivors, across a survivor-count sweep."""
        for avail in range(4, 33):
            plan = plan_remesh(("data", "model"), (8, 4),
                               available_devices=avail)
            used = int(np.prod(plan.new_shape))
            assert plan.new_shape[1] == 4
            assert used + plan.dropped_devices == avail
            assert used <= avail
