"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting output shapes + finiteness (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import lm
from repro.models.sharding import Axes


def _batch(cfg, rng, b=2, t=32):
    batch = {"tokens": jax.random.randint(rng, (b, t + 1), 0, cfg.vocab),
             "loss_mask": jnp.ones((b, t), jnp.float32)}
    if cfg.frontend == "patch":
        batch["patch_embeds"] = jax.random.normal(
            rng, (b, cfg.frontend_len, cfg.d_model))
    if cfg.frontend == "frame":
        batch["src_embeds"] = jax.random.normal(rng, (b, 8, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke(arch, mesh11):
    cfg = reduced(get_config(arch))
    axes = Axes.from_mesh(mesh11)
    rng = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, rng)
    batch = _batch(cfg, rng)

    loss, metrics = jax.jit(
        lambda p, b: lm.loss_fn(p, cfg, b, mesh=mesh11, axes=axes))(
        params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    assert bool(jnp.isfinite(metrics["nll"]))

    # one SGD-flavor step moves the loss (gradient sanity)
    grads = jax.jit(jax.grad(
        lambda p: lm.loss_fn(p, cfg, batch, mesh=mesh11, axes=axes)[0]))(
        params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_serve_smoke(arch, mesh11):
    cfg = reduced(get_config(arch))
    axes = Axes.from_mesh(mesh11)
    rng = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, rng)
    b, t = 2, 16
    batch = _batch(cfg, rng, b, t)
    pf = {k: v for k, v in batch.items() if k != "loss_mask"}
    pf["tokens"] = batch["tokens"][:, :t]

    cache, logits = jax.jit(lambda p, bb: lm.prefill(
        p, cfg, bb, cache_len=t + 4, mesh=mesh11, axes=axes))(params, pf)
    assert logits.shape[0] == b
    assert bool(jnp.isfinite(logits).all()), f"{arch}: prefill logits"

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, c, tt: lm.decode_step(
        p, cfg, c, tt, mesh=mesh11, axes=axes))
    for _ in range(3):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: decode logits"
    # prefill advanced by t (+ image patches for VLM frontends), then 3
    n_prefix = cfg.frontend_len if cfg.frontend == "patch" else 0
    assert int(cache["pos"]) == t + n_prefix + 3


def test_prefill_decode_consistency(mesh11):
    """Greedy decode after prefill == teacher forcing on the same tokens."""
    cfg = reduced(get_config("qwen3-4b"))
    axes = Axes.from_mesh(mesh11)
    rng = jax.random.PRNGKey(2)
    params = lm.init_params(cfg, rng)
    b, t = 1, 12
    toks = jax.random.randint(rng, (b, t), 0, cfg.vocab)

    # full forward logits at the last position
    h, _, _, _ = lm.forward(params, cfg, toks, mesh=mesh11, axes=axes)
    full_logits = jnp.einsum("bd,vd->bv", h[:, -1],
                             lm.head_table(params, cfg))

    # prefill t-1 tokens then decode token t-1
    cache, _ = lm.prefill(params, cfg, {"tokens": toks[:, :t - 1]},
                          cache_len=t + 2, mesh=mesh11, axes=axes)
    logits, cache = lm.decode_step(params, cfg, cache, toks[:, t - 1:t],
                                   mesh=mesh11, axes=axes)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, :cfg.vocab]),
        np.asarray(logits[:, :cfg.vocab]), atol=2e-2, rtol=2e-2)


def test_scan_matches_unrolled(mesh11):
    """scan-over-layers == unrolled layers (same params, same output)."""
    import dataclasses
    cfg_s = reduced(get_config("stablelm-1.6b"), n_layers=4)
    axes = Axes.from_mesh(mesh11)
    rng = jax.random.PRNGKey(3)
    params = lm.init_params(cfg_s, rng)
    toks = jax.random.randint(rng, (2, 16), 0, cfg_s.vocab)
    h1, _, _, _ = lm.forward(params, cfg_s, toks, mesh=mesh11, axes=axes)

    # rebuild as a 1-unit scan of pattern 'gggg' with identical weights
    cfg_u = dataclasses.replace(cfg_s, layer_pattern="gggg")
    stack = params["stack"]
    params_u = {k: v for k, v in params.items() if k != "stack"}
    params_u["stack"] = {f"p{i}": jax.tree_util.tree_map(
        lambda x, i=i: x[i:i + 1], stack["p0"]) for i in range(4)}
    h2, _, _, _ = lm.forward(params_u, cfg_u, toks, mesh=mesh11, axes=axes)
    np.testing.assert_allclose(np.asarray(h1, dtype=np.float32),
                               np.asarray(h2, dtype=np.float32),
                               atol=1e-4, rtol=1e-4)


def test_param_counts_reasonable():
    """Full-size configs land near their nameplate parameter counts."""
    expectations = {
        "stablelm-1.6b": (1.2e9, 2.2e9),
        "qwen3-4b": (3e9, 5.5e9),
        "nemotron-4-15b": (12e9, 18e9),
        "internvl2-76b": (6.5e10, 8.5e10),
        "arctic-480b": (4.0e11, 5.5e11),
        "deepseek-v3-671b": (6.0e11, 7.4e11),
        "rwkv6-1.6b": (1.2e9, 2.4e9),
        "zamba2-7b": (5.5e9, 9e9),
    }
    for arch, (lo, hi) in expectations.items():
        n = lm.param_count_exact(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:,} outside [{lo:.1e},{hi:.1e}]"


def test_moe_active_params():
    cfg = get_config("deepseek-v3-671b")
    total = lm.param_count_exact(cfg)
    active = lm.active_param_count_exact(cfg)
    assert active < 0.12 * total          # ~37B of ~671B
