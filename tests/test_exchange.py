"""Exchange engine tests (serial backend; SPMD runs in test_multidevice)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import costs, get_backend, route
from repro.core.exchange import exchange_capacity, reply


def test_route_serial_identity():
    bk = get_backend(None)
    pay = jnp.arange(10, dtype=jnp.uint32)
    res = route(bk, pay, jnp.zeros(10, jnp.int32), capacity=10)
    got = np.sort(np.asarray(res.payload[res.valid][:, 0]))
    assert np.array_equal(got, np.arange(10))
    assert int(res.dropped) == 0


def test_route_overflow_counted():
    bk = get_backend(None)
    pay = jnp.arange(10, dtype=jnp.uint32)
    res = route(bk, pay, jnp.zeros(10, jnp.int32), capacity=4)
    assert int(res.dropped) == 6
    assert int(res.valid.sum()) == 4


def test_route_respects_valid_mask():
    bk = get_backend(None)
    pay = jnp.arange(10, dtype=jnp.uint32)
    valid = jnp.asarray([True, False] * 5)
    res = route(bk, pay, jnp.zeros(10, jnp.int32), capacity=10, valid=valid)
    assert int(res.valid.sum()) == 5
    got = set(np.asarray(res.payload[res.valid][:, 0]).tolist())
    assert got == {0, 2, 4, 6, 8}


def test_reply_roundtrip():
    bk = get_backend(None)
    pay = jnp.arange(16, dtype=jnp.uint32)
    res = route(bk, pay, jnp.zeros(16, jnp.int32), capacity=16)
    out, answered = reply(bk, res, res.payload[:, 0] * 3, orig_n=16)
    assert bool(answered.all())
    assert np.array_equal(np.asarray(out[:, 0]), np.arange(16) * 3)


def test_cost_recording():
    bk = get_backend(None)
    with costs.recording() as log:
        route(bk, jnp.zeros(8, jnp.uint32), jnp.zeros(8, jnp.int32),
              capacity=8, op_name="myop")
    c = log.by_op("myop")
    assert c.collectives == 1 and c.bytes_moved > 0


def test_capacity_heuristic():
    assert exchange_capacity(1024, 1) == 1024
    c = exchange_capacity(1024, 16)
    assert c >= 64 and c <= 1024


@given(st.lists(st.integers(0, 3), min_size=1, max_size=64),
       st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_route_multiset_preserved(dests, ncopies):
    """Property: with enough capacity, routing preserves the multiset."""
    bk = get_backend(None)
    n = len(dests)
    pay = jnp.arange(n, dtype=jnp.uint32) * ncopies
    res = route(bk, pay, jnp.zeros(n, jnp.int32), capacity=n)
    got = sorted(np.asarray(res.payload[res.valid][:, 0]).tolist())
    assert got == sorted(np.asarray(pay).tolist())
