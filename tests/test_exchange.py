"""Exchange engine tests (serial backend; SPMD runs in test_multidevice).

Hypothesis-based property tests live in test_props.py (guarded by
``pytest.importorskip``); this module stays dependency-free so the core
exchange coverage always collects.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import costs, get_backend, route, suggest_rounds
from repro.core.exchange import exchange_capacity, reply


def test_route_serial_identity():
    bk = get_backend(None)
    pay = jnp.arange(10, dtype=jnp.uint32)
    res = route(bk, pay, jnp.zeros(10, jnp.int32), capacity=10)
    got = np.sort(np.asarray(res.payload[res.valid][:, 0]))
    assert np.array_equal(got, np.arange(10))
    assert int(res.dropped) == 0


def test_route_overflow_counted():
    bk = get_backend(None)
    pay = jnp.arange(10, dtype=jnp.uint32)
    res = route(bk, pay, jnp.zeros(10, jnp.int32), capacity=4)
    assert int(res.dropped) == 6
    assert int(res.valid.sum()) == 4


def test_route_respects_valid_mask():
    bk = get_backend(None)
    pay = jnp.arange(10, dtype=jnp.uint32)
    valid = jnp.asarray([True, False] * 5)
    res = route(bk, pay, jnp.zeros(10, jnp.int32), capacity=10, valid=valid)
    assert int(res.valid.sum()) == 5
    got = set(np.asarray(res.payload[res.valid][:, 0]).tolist())
    assert got == {0, 2, 4, 6, 8}


def test_reply_roundtrip():
    bk = get_backend(None)
    pay = jnp.arange(16, dtype=jnp.uint32)
    res = route(bk, pay, jnp.zeros(16, jnp.int32), capacity=16)
    out, answered = reply(bk, res, res.payload[:, 0] * 3, orig_n=16)
    assert bool(answered.all())
    assert np.array_equal(np.asarray(out[:, 0]), np.arange(16) * 3)


def test_reply_skips_dropped_and_invalid():
    bk = get_backend(None)
    pay = jnp.arange(12, dtype=jnp.uint32)
    valid = jnp.asarray([True, False] * 6)
    res = route(bk, pay, jnp.zeros(12, jnp.int32), capacity=4, valid=valid)
    out, answered = reply(bk, res, res.payload[:, 0] + 1, orig_n=12)
    # 6 valid items, capacity 4 -> first 4 valid items answered
    ans = np.asarray(answered)
    assert ans.sum() == 4
    assert np.array_equal(np.nonzero(ans)[0], np.array([0, 2, 4, 6]))
    assert np.array_equal(np.asarray(out[:, 0])[ans], np.array([1, 3, 5, 7]))


def test_cost_recording():
    bk = get_backend(None)
    with costs.recording() as log:
        route(bk, jnp.zeros(8, jnp.uint32), jnp.zeros(8, jnp.int32),
              capacity=8, op_name="myop")
    c = log.by_op("myop")
    assert c.collectives == 1 and c.bytes_moved > 0
    assert c.rounds == 1 and c.bytes_out == c.bytes_moved and c.bytes_in == 0


def test_reply_rejects_non_dense_transport():
    """The standalone reply is the dense inverse permutation; a flow
    routed hierarchically must reply through CommittedPlan.finish (which
    holds the transport's inverse hop state) — asking the one-shot
    helper for it is an error that NAMES the op, never a silent
    mis-permutation."""
    bk = get_backend(None)
    pay = jnp.arange(8, dtype=jnp.uint32)
    res = route(bk, pay, jnp.zeros(8, jnp.int32), capacity=8)
    with pytest.raises(ValueError, match="reply\\('myop'\\)"):
        reply(bk, res, res.payload[:, 0], orig_n=8, op_name="myop",
              transport="hier")


def test_reply_explicit_dense_transport_matches_default():
    bk = get_backend(None)
    pay = jnp.arange(16, dtype=jnp.uint32)
    res = route(bk, pay, jnp.zeros(16, jnp.int32), capacity=16)
    out_d, ans_d = reply(bk, res, res.payload[:, 0] * 7, orig_n=16)
    out_e, ans_e = reply(bk, res, res.payload[:, 0] * 7, orig_n=16,
                         transport="dense")
    assert np.array_equal(np.asarray(out_d), np.asarray(out_e))
    assert np.array_equal(np.asarray(ans_d), np.asarray(ans_e))


def test_capacity_heuristic():
    assert exchange_capacity(1024, 1) == 1024
    c = exchange_capacity(1024, 16)
    assert c >= 64 and c <= 1024


def test_suggest_rounds_heuristic():
    """The adaptive-rounds pick (ROADMAP): smallest R whose effective
    capacity R*C covers the hottest observed bucket load."""
    # scalar and trajectory forms
    assert suggest_rounds(0, 8) == 1
    assert suggest_rounds(8, 8) == 1
    assert suggest_rounds(9, 8) == 2
    assert suggest_rounds([3, 10, 40], 8) == 5
    # slack inflates the peak before covering it
    assert suggest_rounds([40], 8, slack=1.5) == 8
    # clamp: a pathological trajectory cannot demand unbounded launches
    assert suggest_rounds([10_000], 4, limit=6) == 6
    with pytest.raises(ValueError, match="capacity"):
        suggest_rounds([4], 0)
    # the pick actually covers: route at that R is lossless
    bk = get_backend(None)
    n, cap = 40, 6
    r = suggest_rounds([n], cap)
    res = route(bk, jnp.arange(n, dtype=jnp.uint32),
                jnp.zeros(n, jnp.int32), capacity=cap, max_rounds=r)
    assert int(res.dropped) == 0


@pytest.mark.parametrize("dests,ncopies", [
    ([0, 0, 0, 0], 1),
    ([0, 1, 2, 3, 2, 1, 0], 2),
    ([3] * 10, 3),
    ([0, 3, 0, 3, 1, 2] * 8, 4),
])
def test_route_multiset_preserved(dests, ncopies):
    """With enough capacity, routing preserves the multiset (the
    hypothesis-randomized version lives in test_props.py)."""
    bk = get_backend(None)
    n = len(dests)
    pay = jnp.arange(n, dtype=jnp.uint32) * ncopies
    res = route(bk, pay, jnp.zeros(n, jnp.int32), capacity=n)
    got = sorted(np.asarray(res.payload[res.valid][:, 0]).tolist())
    assert got == sorted(np.asarray(pay).tolist())
